//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Loads the trained model artifacts, serves a Poisson stream of batched
//! reasoning requests through the full stack — rust coordinator →
//! PJRT-executed JAX graphs → Pallas kernels — and reports latency,
//! throughput and accuracy. Proves all three layers compose with Python
//! off the request path.
//!
//!     cargo run --release --example serve_workload
//!     cargo run --release --example serve_workload -- \
//!         --model r1mini-small --method sart:8 --requests 32 --rate 2
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use sart::config::{Args, EngineChoice, Method, PrmChoice, ServeSpec};
use sart::metrics::ServeReport;
use sart::server;
use sart::util::stats::render_table;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut spec = ServeSpec::from_args(&args)?;
    if args.get("engine").is_none() {
        spec.engine = EngineChoice::Hlo {
            model: args.get_or("model", "r1mini-tiny"),
            fused: !args.flag("stepwise"),
        };
        spec.prm = PrmChoice::Hlo;
    }
    spec.method = Method::parse(&args.get_or("method", "sart:8"), &args)?;
    spec.n_requests = args.usize_or("requests", 24)?;
    spec.rate = args.f64_or("rate", 1.0)?;
    spec.slots = args.usize_or("slots", 8)?;
    spec.kv_capacity_tokens = args.usize_or("kv-tokens", 4096)?;

    eprintln!("# spec: {spec:?}");
    let t0 = std::time::Instant::now();
    let out = server::run(&spec)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("== end-to-end serve: {} ==", out.engine_desc);
    println!(
        "{}",
        render_table(&ServeReport::ROW_HEADERS, &[out.report.row()])
    );
    let total_tokens = out.report.total_tokens;
    println!(
        "requests {} | accuracy {:.3} | answered {:.3}",
        out.report.n_requests, out.report.accuracy, out.report.answered
    );
    println!(
        "tokens generated {} | wall {:.1}s | throughput {:.0} tok/s \
         ({:.2} req/s)",
        total_tokens,
        wall,
        total_tokens as f64 / wall,
        out.report.n_requests as f64 / wall
    );
    println!(
        "latency e2e   p50 {:.2}s  p90 {:.2}s  p97 {:.2}s  p99 {:.2}s",
        out.report.e2e.p50, out.report.e2e.p90, out.report.e2e.p97,
        out.report.e2e.p99
    );
    println!(
        "latency queue p50 {:.2}s  p90 {:.2}s | inference p50 {:.2}s",
        out.report.queue.p50, out.report.queue.p90, out.report.inference.p50
    );
    println!(
        "branches/req {:.2} | pruned/req {:.2} | peak running branches {}",
        out.report.branches_started_per_request,
        out.report.branches_pruned_per_request,
        out.timeline.peak_branches()
    );
    Ok(())
}
