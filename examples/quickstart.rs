//! Quickstart: load the AOT artifacts, serve a handful of reasoning
//! requests with SART on the real (HLO) engine, and print the reasoning
//! traces + final answers.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --engine sim   # no artifacts
//!
//! Flags: --model r1mini-tiny|r1mini-small, --requests INT, --seed INT.

use anyhow::Result;
use sart::config::{Args, Method, ServeSpec};
use sart::server;
use sart::tokenizer as tok;
use sart::workload::{Question, TaskSpec};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut spec = ServeSpec::from_args(&args)?;
    // Quickstart defaults: small SART run on the HLO engine unless the
    // user asked for sim.
    if args.get("engine").is_none() {
        spec.engine = sart::config::EngineChoice::Hlo {
            model: args.get_or("model", "r1mini-tiny"),
            fused: !args.flag("stepwise"),
        };
        spec.prm = sart::config::PrmChoice::Hlo;
    }
    spec.method = Method::parse(&args.get_or("method", "sart:4"), &args)?;
    spec.n_requests = args.usize_or("requests", 4)?;
    spec.rate = args.f64_or("rate", 0.0)?; // batch arrival
    spec.slots = args.usize_or("slots", 8)?;

    println!("== SART quickstart ==");
    println!("engine: {:?}  method: {}", spec.engine, spec.method.label());

    // Show one raw branch sample first, so the reasoning format is visible.
    let mut engine = server::build_engine(&spec)?;
    let task = TaskSpec::by_name(&spec.dataset)?;
    let mut rng = sart::util::rng::Rng::new(spec.seed);
    let q = Question::sample(&task, &mut rng);
    println!("\n-- one question, three sampled branches --");
    println!("prompt: {}", tok::detokenize(&q.prompt_tokens()));
    println!("ground-truth answer: {}", q.answer());
    let samples =
        server::sample_branches(engine.as_mut(), &q, 3, spec.temperature, 7)?;
    for (i, s) in samples.iter().enumerate() {
        let ans = tok::extract_answer(s);
        println!(
            "branch {i}: len={:3} answer={:?} correct={}",
            s.len(),
            ans,
            ans == Some(q.answer())
        );
        println!("  {}", tok::detokenize(s));
    }
    drop(engine);

    // Now a real serve run through the full coordinator.
    println!("\n-- serving {} requests with {} --", spec.n_requests,
             spec.method.label());
    let out = server::run(&spec)?;
    for o in &out.outcomes {
        println!(
            "request {:2} [{}]: answer={:?} truth={} correct={} \
             e2e={:.2}s (queue {:.2}s) branches={} pruned={}",
            o.id,
            o.dataset,
            o.answer,
            o.truth,
            o.correct(),
            o.e2e_latency(),
            o.queue_latency(),
            o.branches_started,
            o.branches_pruned,
        );
    }
    println!(
        "\naccuracy {:.2} | e2e p50 {:.2}s p97 {:.2}s | engine {}",
        out.report.accuracy, out.report.e2e.p50, out.report.e2e.p97,
        out.engine_desc
    );
    Ok(())
}
