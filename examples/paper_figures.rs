//! Regenerate every figure in the paper's evaluation (DESIGN.md §5).
//!
//!     cargo run --release --example paper_figures -- --figure 2
//!     cargo run --release --example paper_figures -- --figure 3
//!     cargo run --release --example paper_figures -- --figure 5
//!     cargo run --release --example paper_figures -- --figure 6
//!     cargo run --release --example paper_figures -- --figure 7
//!     cargo run --release --example paper_figures -- --lemma1
//!     cargo run --release --example paper_figures -- --all
//!
//! Default engine is the virtual-time simulation (full paper scale,
//! deterministic); pass `--engine hlo [--model r1mini-tiny]` to drive the
//! real AOT-compiled model instead (use a smaller `--requests`). Numbers
//! land in EXPERIMENTS.md; we reproduce the *shapes* (who wins, by what
//! factor, where crossovers fall), not the authors' absolute numbers.

use anyhow::Result;
use sart::config::{Args, Method, ServeSpec};
use sart::metrics::ServeReport;
use sart::server;
use sart::tokenizer as tok;
use sart::util::stats::{percentile, render_table, Histogram};
use sart::workload::{Question, TaskSpec};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let which = args.get_or("figure", "");
    let all = args.flag("all");
    if all || which == "2" {
        figure2(&args)?;
    }
    if all || which == "3" {
        figure3(&args)?;
    }
    if all || which == "5" {
        figure5(&args)?;
    }
    if all || which == "6" {
        figure6(&args)?;
    }
    if all || which == "7" {
        figure7(&args)?;
    }
    if all || args.flag("lemma1") {
        lemma1(&args)?;
    }
    if !all && which.is_empty() && !args.flag("lemma1") {
        eprintln!("usage: paper_figures --figure 2|3|5|6|7 | --lemma1 | --all");
    }
    Ok(())
}

fn base_spec(args: &Args) -> Result<ServeSpec> {
    ServeSpec::from_args(args)
}

/// Fig. 2 — response length vs correctness for 3 questions × 64 samples:
/// the weak length/quality correlation (Observation 1).
fn figure2(args: &Args) -> Result<()> {
    println!("\n=== Figure 2: correct/wrong responses per length bucket ===");
    let spec = base_spec(args)?;
    let task = TaskSpec::by_name(&spec.dataset)?;
    let samples = args.usize_or("samples", 64)?;
    let bucket = args.f64_or("bucket", 24.0)?;
    let mut rng = sart::util::rng::Rng::new(spec.seed ^ 2);
    for qi in 0..3 {
        let q = Question::sample(&task, &mut rng);
        let mut engine = server::build_engine(&spec)?;
        let gens = server::sample_branches(
            engine.as_mut(), &q, samples, spec.temperature,
            spec.seed ^ (qi as u64 + 1))?;
        let mut correct = Histogram::new(bucket, 10);
        let mut wrong = Histogram::new(bucket, 10);
        for g in &gens {
            let ok = tok::extract_answer(g) == Some(q.answer());
            if ok {
                correct.add(g.len() as f64);
            } else {
                wrong.add(g.len() as f64);
            }
        }
        let rows: Vec<Vec<String>> = (0..correct.counts.len())
            .filter(|&i| correct.counts[i] + wrong.counts[i] > 0)
            .map(|i| {
                let c = correct.counts[i] as f64;
                let w = wrong.counts[i] as f64;
                vec![
                    format!("{}-{}K'", (i as f64 * bucket) as usize,
                            ((i + 1) as f64 * bucket) as usize),
                    format!("{}", c as u64),
                    format!("{}", w as u64),
                    format!("{:.2}", c / (c + w)),
                ]
            })
            .collect();
        println!("\nquestion {} (hops={}, truth={}):", qi + 1, q.hops,
                 q.answer());
        println!("{}", render_table(
            &["len-range", "correct", "wrong", "frac-correct"], &rows));
    }
    println!("(expected shape: fraction-correct roughly flat across length \
              buckets — length and quality weakly correlated)");
    Ok(())
}

/// Fig. 3 — running branches & tokens over time for one request,
/// with vs without pruning (N=8, M=4).
fn figure3(args: &Args) -> Result<()> {
    println!("\n=== Figure 3: running branches/tokens, ± pruning (N=8,M=4) ===");
    let mut spec = base_spec(args)?;
    spec.n_requests = args.usize_or("requests", 1)?;
    spec.rate = 0.0;
    spec.slots = spec.slots.max(8);
    let trace = server::trace_for(&spec)?;
    for (label, method) in [
        ("without pruning", Method::SartNoPrune { n: 8, m: 4 }),
        ("with pruning", Method::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 }),
    ] {
        let mut s = spec.clone();
        s.method = method;
        let out = server::run_on_trace(&s, &trace)?;
        println!("\n-- {label} --");
        let rows: Vec<Vec<String>> = out
            .timeline
            .downsample(16)
            .iter()
            .map(|p| {
                vec![
                    format!("{:.2}", p.t),
                    format!("{}", p.running_branches),
                    format!("{}", p.running_tokens),
                    format!("{}", p.kv_pages_used),
                ]
            })
            .collect();
        println!("{}", render_table(
            &["t(s)", "branches", "tokens", "kv-pages"], &rows));
        println!(
            "finish={:.2}s  mean-branches={:.2}  peak-tokens={}",
            out.outcomes[0].finished_at,
            out.timeline.mean_branches(),
            out.timeline.peak_tokens()
        );
    }
    println!("(expected shape: pruning releases branches/tokens much \
              earlier; without pruning they are held until late)");
    Ok(())
}

/// Fig. 5 — E2E latency + accuracy vs N for all methods, datasets × rates.
fn figure5(args: &Args) -> Result<()> {
    println!("\n=== Figure 5: E2E latency & accuracy vs N (all methods) ===");
    let spec0 = base_spec(args)?;
    let requests = args.usize_or("requests", 48)?;
    let ns: Vec<usize> = vec![2, 4, 8];
    let datasets = ["synth-gaokao", "synth-gpqa"];
    let rates = [1.0, 4.0];
    let mut headline_max: f64 = 0.0;
    let mut headline: Vec<f64> = Vec::new();
    for dataset in datasets {
        for rate in rates {
            let mut spec = spec0.clone();
            spec.dataset = dataset.to_string();
            spec.rate = rate;
            spec.n_requests = requests;
            let trace = server::trace_for(&spec)?;
            let van = {
                let mut s = spec.clone();
                s.method = Method::Vanilla;
                server::run_on_trace(&s, &trace)?.report
            };
            let mut rows = vec![vec![
                "vanilla".to_string(),
                "-".into(),
                format!("{:.3}", van.accuracy),
                format!("{:.2}", van.e2e.p97),
                "1.00".into(),
            ]];
            let mut sart_by_n: Vec<(usize, ServeReport)> = Vec::new();
            for &n in &ns {
                let m = (n / 2).max(1);
                for method in [
                    Method::SelfConsistency { n },
                    Method::Rebase { n },
                    Method::Sart { n, m, alpha: 0.5, beta: m },
                ] {
                    let mut s = spec.clone();
                    s.method = method;
                    let rep = server::run_on_trace(&s, &trace)?.report;
                    rows.push(vec![
                        rep.label.clone(),
                        format!("{n}"),
                        format!("{:.3}", rep.accuracy),
                        format!("{:.2}", rep.e2e.p97),
                        format!("{:.2}", rep.e2e.p97 / van.e2e.p97),
                    ]);
                    if matches!(method, Method::Sart { .. }) {
                        sart_by_n.push((n, rep));
                    }
                }
            }
            println!("\n-- dataset={dataset} rate={rate}/s requests={requests} --");
            println!("{}", render_table(
                &["method", "N", "acc", "e2e-p97(s)", "vs-vanilla"], &rows));
            // Headline speedups at N=8: SC/Rebase p97 over SART p97.
            if let Some((_, sart8)) =
                sart_by_n.iter().find(|(n, _)| *n == 8)
            {
                for r in rows.iter().filter(|r| {
                    (r[0].starts_with("self-consistency")
                        || r[0].starts_with("rebase"))
                        && r[1] == "8"
                }) {
                    let p97: f64 = r[3].parse().unwrap_or(f64::NAN);
                    let ratio = p97 / sart8.e2e.p97;
                    headline.push(ratio);
                    headline_max = headline_max.max(ratio);
                }
            }
        }
    }
    if !headline.is_empty() {
        let avg = headline.iter().sum::<f64>() / headline.len() as f64;
        println!(
            "\nheadline (N=8): SART outperforms branch-sampling baselines \
             by up to {headline_max:.1}x and on average {avg:.1}x (paper: \
             28.2x / 15.7x on its H100 testbed)"
        );
    }
    println!("(expected shape: SC/Rebase latency grows with N; SART flat in \
              N and at/below vanilla; SART acc ≈ SC acc > vanilla; Rebase \
              scales worst)");
    Ok(())
}

/// Fig. 6 — ablations: length & queue distributions; E2E/accuracy with the
/// no-pruning variant.
fn figure6(args: &Args) -> Result<()> {
    println!("\n=== Figure 6: ablation studies (synth-gaokao) ===");
    let mut spec = base_spec(args)?;
    spec.dataset = args.get_or("dataset", "synth-gaokao");
    spec.rate = args.f64_or("rate", 2.0)?;
    spec.n_requests = args.usize_or("requests", 48)?;
    let trace = server::trace_for(&spec)?;

    // Left plots: response-length and queuing-time distributions.
    let mut dist_rows = Vec::new();
    for method in [
        Method::SelfConsistency { n: 4 },
        Method::SartNoPrune { n: 8, m: 4 },
        Method::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
    ] {
        let mut s = spec.clone();
        s.method = method;
        let rep = server::run_on_trace(&s, &trace)?.report;
        dist_rows.push(vec![
            rep.label.clone(),
            format!("{:.1}", percentile(&rep.response_lengths, 50.0)),
            format!("{:.1}", percentile(&rep.response_lengths, 95.0)),
            format!("{:.2}", percentile(&rep.queue_latencies, 50.0)),
            format!("{:.2}", percentile(&rep.queue_latencies, 95.0)),
            format!("{:.3}", rep.accuracy),
        ]);
    }
    println!("{}", render_table(
        &["method", "len-p50", "len-p95", "queue-p50(s)", "queue-p95(s)",
          "acc"],
        &dist_rows));
    println!("(expected: SART lengths < SC lengths; w/o pruning queuing \
              grows; pruning shrinks queue at stable accuracy)");

    // Right plots: E2E + accuracy sweep over N.
    let mut rows = Vec::new();
    for n in [2usize, 4, 8] {
        let m = (n / 2).max(1);
        for method in [
            Method::SelfConsistency { n },
            Method::SartNoPrune { n, m },
            Method::Sart { n, m, alpha: 0.5, beta: m },
        ] {
            let mut s = spec.clone();
            s.method = method;
            let rep = server::run_on_trace(&s, &trace)?.report;
            rows.push(vec![
                rep.label.clone(),
                format!("{n}"),
                format!("{:.2}", rep.e2e.p50),
                format!("{:.2}", rep.e2e.p97),
                format!("{:.3}", rep.accuracy),
            ]);
        }
    }
    println!("{}", render_table(
        &["method", "N", "e2e-p50(s)", "e2e-p97(s)", "acc"], &rows));
    Ok(())
}

/// Fig. 7 — sensitivity to N: E2E vs inference latency percentiles.
fn figure7(args: &Args) -> Result<()> {
    println!("\n=== Figure 7: sensitivity to N (SART) ===");
    let mut spec = base_spec(args)?;
    spec.rate = args.f64_or("rate", 2.0)?;
    spec.n_requests = args.usize_or("requests", 48)?;
    let trace = server::trace_for(&spec)?;
    let mut rows = Vec::new();
    for n in [1usize, 2, 4, 8] {
        let m = (n / 2).max(1);
        let mut s = spec.clone();
        s.method = if n == 1 {
            Method::Vanilla
        } else {
            Method::Sart { n, m, alpha: 0.5, beta: m }
        };
        let rep = server::run_on_trace(&s, &trace)?.report;
        rows.push(vec![
            format!("{n}"),
            format!("{:.2}", rep.e2e.p50),
            format!("{:.2}", rep.e2e.p90),
            format!("{:.2}", rep.e2e.p97),
            format!("{:.2}", rep.e2e.p99),
            format!("{:.2}", rep.inference.p50),
            format!("{:.2}", rep.inference.p97),
            format!("{:.3}", rep.accuracy),
        ]);
    }
    println!("{}", render_table(
        &["N", "e2e-p50", "e2e-p90", "e2e-p97", "e2e-p99", "inf-p50",
          "inf-p97", "acc"],
        &rows));
    println!("(expected shape: tail latencies (p97/p99) drop for N∈{{4,8}}; \
              inference latency lower at N=8 than N=4 but e2e slightly \
              higher from queuing)");
    Ok(())
}

/// Lemma 1 — analytic order-statistic CDF vs Monte-Carlo, and expected
/// M-th completion time shrinking with N.
fn lemma1(args: &Args) -> Result<()> {
    println!("\n=== Lemma 1: order statistics of redundant sampling ===");
    let m = args.usize_or("m", 4)?;
    let mut rows = Vec::new();
    for n in [m, m + 2, m + 4, m + 8, m + 12] {
        // Lengths ~ lognormal (heavy tail like Fig. 2); threshold at the
        // base distribution's median.
        let median = (4.0f64).exp();
        let f_at_median = 0.5;
        let analytic = sart::analysis::order_statistic_cdf(
            f_at_median, m as u64, n as u64);
        let empirical = sart::analysis::empirical_order_cdf(
            |rng| rng.lognormal(4.0, 0.8),
            m,
            n,
            median,
            40_000,
            9,
        );
        let e_steps = sart::analysis::expected_mth_completion(
            |rng| rng.lognormal(4.0, 0.8),
            m,
            n,
            40_000,
            11,
        );
        rows.push(vec![
            format!("{n}"),
            format!("{m}"),
            format!("{analytic:.4}"),
            format!("{empirical:.4}"),
            format!("{e_steps:.1}"),
        ]);
    }
    println!("{}", render_table(
        &["N", "M", "F_X(M)(median;N)", "monte-carlo", "E[steps to M]"],
        &rows));
    println!("(expected: CDF increases with N; expected steps to M \
              completions decrease with N)");
    Ok(())
}
