//! Compare all serving methods on one shared workload trace.
//!
//! Runs Vanilla, Self-Consistency, Rebase, SART (w/o pruning) and SART on
//! exactly the same request trace and prints the comparison table plus
//! headline speedups — the same-accuracy efficiency claim of §5.2.
//!
//!     cargo run --release --example compare_methods                 # sim
//!     cargo run --release --example compare_methods -- --engine hlo \
//!         --model r1mini-tiny --requests 12 --rate 1 --n 4

use anyhow::Result;
use sart::config::{Args, Method, ServeSpec};
use sart::metrics::ServeReport;
use sart::server;
use sart::util::stats::render_table;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let spec = ServeSpec::from_args(&args)?;
    let n = args.usize_or("n", 8)?;
    let m = (n / 2).max(1);
    let trace = server::trace_for(&spec)?;

    let methods = vec![
        Method::Vanilla,
        Method::SelfConsistency { n },
        Method::Rebase { n },
        Method::SartNoPrune { n, m },
        Method::Sart { n, m, alpha: 0.5, beta: m },
    ];
    let mut rows = Vec::new();
    let mut reports: Vec<ServeReport> = Vec::new();
    for method in methods {
        let mut s = spec.clone();
        s.method = method;
        eprintln!("# running {} ...", method.label());
        let out = server::run_on_trace(&s, &trace)?;
        rows.push(out.report.row());
        reports.push(out.report);
    }
    println!("{}", render_table(&ServeReport::ROW_HEADERS, &rows));

    // Headline: SART speedup vs each baseline at P97 (paper's metric).
    let sart = reports.last().unwrap();
    println!("SART speedups at P97 (same workload):");
    for r in &reports[..reports.len() - 1] {
        println!(
            "  vs {:<24} {:>6.2}x   (acc {:+.3})",
            r.label,
            r.e2e.p97 / sart.e2e.p97,
            sart.accuracy - r.accuracy
        );
    }
    Ok(())
}
