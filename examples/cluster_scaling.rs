//! Horizontal-scaling demo: the same Poisson workload served by 1, 2 and
//! 4 engine replicas under each dispatch policy (virtual time, sim
//! engine), printing cluster-level latency and per-replica skew.
//!
//! The interesting comparisons:
//! * `--replicas 1` rows reproduce the single-engine path exactly;
//! * at fixed replica count, load-aware policies (jsq/p2c) vs blind
//!   round-robin on p99 — the dispatch layer's contribution to the tail;
//! * occupancy skew: how unevenly the replicas ended up loaded.
//!
//!     cargo run --release --example cluster_scaling
//!     cargo run --release --example cluster_scaling -- \
//!         --method sart:4 --requests 96 --rate 6 --dataset synth-gpqa
//!
//! The workload is held fixed across all rows (same trace), so rows are
//! directly comparable.

use anyhow::Result;
use sart::cluster::LbPolicy;
use sart::config::{Args, Method, ServeSpec};
use sart::server;
use sart::util::stats::render_table;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut base = ServeSpec::from_args(&args)?;
    base.method = Method::parse(&args.get_or("method", "sart:4"), &args)?;
    base.n_requests = args.usize_or("requests", 64)?;
    base.rate = args.f64_or("rate", 4.0)?;
    base.slots = args.usize_or("slots", 8)?;
    base.kv_capacity_tokens = args.usize_or("kv-tokens", 8192)?;

    let trace = server::trace_for(&base)?;
    eprintln!(
        "# {} requests @ {:.1}/s, {} slots/replica, method {}",
        base.n_requests,
        base.rate,
        base.slots,
        base.method.label()
    );

    let headers = [
        "replicas", "lb", "acc", "e2e-p50", "e2e-p99", "queue-p50",
        "occ-skew", "req/replica",
    ];
    let mut rows = Vec::new();
    for replicas in [1usize, 2, 4] {
        let policies: &[LbPolicy] = if replicas == 1 {
            &[LbPolicy::RoundRobin] // policy is irrelevant at R = 1
        } else {
            &LbPolicy::ALL
        };
        for &lb in policies {
            let mut s = base.clone();
            s.replicas = replicas;
            s.lb = lb;
            let out = server::run_on_trace(&s, &trace)?;
            let (skew, per_replica) = match &out.cluster {
                Some(c) => (
                    format!("{:.2}", c.occupancy_skew),
                    format!("{:?}", c.per_replica_requests),
                ),
                None => ("-".into(), format!("[{}]", out.report.n_requests)),
            };
            rows.push(vec![
                format!("{replicas}"),
                lb.label().to_string(),
                format!("{:.3}", out.report.accuracy),
                format!("{:.2}", out.report.e2e.p50),
                format!("{:.2}", out.report.e2e.p99),
                format!("{:.2}", out.report.queue.p50),
                skew,
                per_replica,
            ]);
        }
    }
    println!("{}", render_table(&headers, &rows));
    Ok(())
}
