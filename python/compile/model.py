"""L2: the reasoning LM — a decoder-only transformer in JAX.

Three entry points are AOT-exported per model size (see ``aot.py``):

* ``prefill_into_slots`` — batched prompt prefill that writes prompt KV
  into a *subset* of slots of the fixed-shape KV cache (slot_mask selects
  which slots are being (re)initialized; other slots' cache is preserved).
  This is how continuous batching admits new branches mid-flight with
  fixed-shape AOT executables.
* ``decode_step`` — one batched decode step over all slots: embeds the
  sampled tokens, updates the KV cache in place (functionally), and
  returns next-token logits. Sampling itself is host-side (rust), so the
  per-branch RNG is owned by the coordinator.
* ``lm_forward`` — full-sequence logits; used by the build-time trainer
  and the PRM trunk, never exported for serving.

The KV cache layout is a single packed tensor ``[L, 2, B, H, S, Dh]``
(layers × k/v × slot × head × position × head-dim) that lives in a
device-resident PJRT buffer on the rust side and is threaded through
``execute_b`` calls without host round-trips.

All compute-heavy ops route through the L1 Pallas kernels when
``use_pallas=True`` (the exported path); the trainer uses the pure-jnp
references (``kernels/ref.py``) for speed, and the kernel test suite
establishes their equivalence.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import vocab as V
from .kernels import ref
from .kernels.decode_attention import decode_attention as pl_decode_attention
from .kernels.ffn import ffn as pl_ffn
from .kernels.prefill_attention import prefill_attention as pl_prefill_attention
from .kernels.rmsnorm import rmsnorm as pl_rmsnorm

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of one model size."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab_size: int = V.VOCAB_SIZE
    max_seq: int = 256  # KV cache positions per slot (S)
    prompt_len: int = 32  # prefill bucket (Sp)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self, params: Params) -> int:
        return sum(int(p.size) for p in params.values())

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["d_head"] = self.d_head
        return d


# The two serving model sizes (paper: R1-Distill 14B and 70B).
TINY = ModelConfig(name="r1mini-tiny", d_model=64, n_layers=2, n_heads=2,
                   d_ff=256)
SMALL = ModelConfig(name="r1mini-small", d_model=128, n_layers=4, n_heads=4,
                    d_ff=512)
MODELS = {m.name: m for m in (TINY, SMALL)}


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Scaled-normal initialization; output head is tied to tok_emb."""
    key = jax.random.PRNGKey(seed)
    params: Params = {}

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(jnp.float32)

    n_mats = 6 * cfg.n_layers + 2
    keys = jax.random.split(key, n_mats)
    ki = iter(range(n_mats))
    d, f = cfg.d_model, cfg.d_ff
    params["tok_emb"] = nrm(keys[next(ki)], (cfg.vocab_size, d), 0.02)
    params["pos_emb"] = nrm(keys[next(ki)], (cfg.max_seq, d), 0.02)
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        params[p + "ln1_w"] = jnp.ones((d,), jnp.float32)
        params[p + "wq"] = nrm(keys[next(ki)], (d, d), d ** -0.5)
        params[p + "wk"] = nrm(keys[next(ki)], (d, d), d ** -0.5)
        params[p + "wv"] = nrm(keys[next(ki)], (d, d), d ** -0.5)
        params[p + "wo"] = nrm(keys[next(ki)], (d, d),
                               (d ** -0.5) / (2 * cfg.n_layers) ** 0.5)
        params[p + "ln2_w"] = jnp.ones((d,), jnp.float32)
        params[p + "w1"] = nrm(keys[next(ki)], (d, f), d ** -0.5)
        params[p + "b1"] = jnp.zeros((f,), jnp.float32)
        params[p + "w2"] = nrm(keys[next(ki)], (f, d),
                               (f ** -0.5) / (2 * cfg.n_layers) ** 0.5)
        params[p + "b2"] = jnp.zeros((d,), jnp.float32)
    params["lnf_w"] = jnp.ones((d,), jnp.float32)
    return params


def flatten_params(params: Params) -> Tuple[List[str], Tuple[jax.Array, ...]]:
    """Deterministic (sorted-name) flattening; this order IS the HLO
    argument order and the `params.bin` layout the rust runtime loads."""
    names = sorted(params.keys())
    return names, tuple(params[n] for n in names)


def unflatten_params(names: List[str], flat) -> Params:
    return dict(zip(names, flat))


def kv_shape(cfg: ModelConfig, batch: int) -> Tuple[int, ...]:
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.d_head)


def _ops(use_pallas: bool):
    if use_pallas:
        return (pl_rmsnorm, pl_ffn, pl_decode_attention, pl_prefill_attention)
    return (ref.rmsnorm, ref.ffn, ref.decode_attention, ref.prefill_attention)


def _split_heads(x, cfg: ModelConfig):
    """[..., D] -> [..., H, Dh] -> moved to [B, H, ..., Dh]."""
    b = x.shape[0]
    if x.ndim == 2:  # [B, D] -> [B, H, Dh]
        return x.reshape(b, cfg.n_heads, cfg.d_head)
    s = x.shape[1]  # [B, S, D] -> [B, H, S, Dh]
    return x.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def decode_step(params: Params, cfg: ModelConfig, kv, tokens, lengths,
                *, use_pallas: bool = True):
    """One batched decode step.

    Args:
      kv: [L, 2, B, H, S, Dh] cache; positions >= lengths[b] are garbage.
      tokens: [B] int32 token sampled for each slot (PAD for idle slots).
      lengths: [B] int32 number of tokens already in the cache — i.e. the
        position index this step writes.

    Returns (logits [B, V], updated kv). Idle slots produce garbage logits
    and write garbage KV at their current position; the coordinator never
    reads either (a slot is re-prefilled before reuse).
    """
    rmsnorm, ffn, dec_attn, _ = _ops(use_pallas)
    b = tokens.shape[0]
    s = cfg.max_seq
    pos = jnp.clip(lengths, 0, s - 1)
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]  # [B, D]
    slot_idx = jnp.arange(b)
    new_kv = []
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h = rmsnorm(x, params[p + "ln1_w"])
        q = _split_heads(h @ params[p + "wq"], cfg)  # [B, H, Dh]
        k_new = _split_heads(h @ params[p + "wk"], cfg)
        v_new = _split_heads(h @ params[p + "wv"], cfg)
        # Scatter the new position into the cache (lowers to an in-place
        # update under buffer donation, unlike a full-tensor select).
        k_cache = kv[l, 0].at[slot_idx, :, pos, :].set(k_new)
        v_cache = kv[l, 1].at[slot_idx, :, pos, :].set(v_new)
        new_kv.append(jnp.stack([k_cache, v_cache]))
        attn = dec_attn(q, k_cache, v_cache, pos + 1)  # [B, H, Dh]
        x = x + attn.reshape(b, cfg.d_model) @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2_w"])
        x = x + ffn(h, params[p + "w1"], params[p + "b1"],
                    params[p + "w2"], params[p + "b2"])
    x = rmsnorm(x, params["lnf_w"])
    logits = x @ params["tok_emb"].T
    return logits, jnp.stack(new_kv)


def prefill_into_slots(params: Params, cfg: ModelConfig, kv, tokens, lengths,
                       slot_mask, *, use_pallas: bool = True):
    """Prefill prompts into the selected slots of the KV cache.

    Args:
      kv: [L, 2, B, H, S, Dh] existing cache.
      tokens: [B, Sp] padded prompt tokens (rows of unselected slots are
        ignored — conventionally PAD).
      lengths: [B] int32 prompt length per slot (>= 1 for selected slots).
      slot_mask: [B] bool/int32; 1 = (re)initialize this slot.

    Returns (last_logits [B, V], updated kv): logits at each selected
    slot's last prompt position. Unselected slots keep their cache and get
    garbage logits. The computation runs for all B rows (masked select at
    the end) — batch-dense prefill keeps the executable shape fixed.
    """
    rmsnorm, ffn, _, pre_attn = _ops(use_pallas)
    b, sp = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:sp][None]  # [B,Sp,D]
    computed_kv = []  # per layer [2, B, H, Sp, Dh]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h = rmsnorm(x, params[p + "ln1_w"])
        q = _split_heads(h @ params[p + "wq"], cfg)  # [B, H, Sp, Dh]
        k = _split_heads(h @ params[p + "wk"], cfg)
        v = _split_heads(h @ params[p + "wv"], cfg)
        computed_kv.append(jnp.stack([k, v]))
        attn = pre_attn(q, k, v, lengths)  # [B, H, Sp, Dh]
        attn = attn.transpose(0, 2, 1, 3).reshape(b, sp, cfg.d_model)
        x = x + attn @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2_w"])
        x = x + ffn(h, params[p + "w1"], params[p + "b1"],
                    params[p + "w2"], params[p + "b2"])
    x = rmsnorm(x, params["lnf_w"])
    last = jnp.take_along_axis(
        x, jnp.clip(lengths - 1, 0, sp - 1)[:, None, None], axis=1)[:, 0]
    logits = last @ params["tok_emb"].T  # [B, V]

    # Merge computed prompt KV into the cache for selected slots.
    new = jnp.stack(computed_kv)  # [L, 2, B, H, Sp, Dh]
    sel = slot_mask.astype(bool)[None, None, :, None, None, None]
    head = jnp.where(sel, new, kv[:, :, :, :, :sp, :])
    kv = jnp.concatenate([head, kv[:, :, :, :, sp:, :]], axis=4)
    return logits, kv


# ---------------------------------------------------------------------------
# Packed serving state.
#
# The rust runtime executes AOT HLO via PJRT, whose rust binding returns
# multi-output (tuple-rooted) executables as a single opaque tuple buffer
# that cannot be re-fed as an input. Every serving executable therefore
# takes and returns ONE packed f32 "state" array holding all mutable
# engine state; rust threads the device buffer through `execute_b` calls
# and reads back only the small control segments (tokens/logits/lengths/
# alive) via partial `copy_raw_to_host_sync`. The KV cache — by far the
# largest segment — never crosses the host boundary.
# ---------------------------------------------------------------------------


def state_layout(cfg: ModelConfig, batch: int, chunk_t: int):
    """Ordered (name, num_elements) segments of the packed state array."""
    kv_elems = 1
    for d in kv_shape(cfg, batch):
        kv_elems *= d
    return [
        ("tokens_out", batch * chunk_t),
        ("logits", batch * cfg.vocab_size),
        ("lengths", batch),
        ("alive", batch),
        ("kv", kv_elems),
    ]


def state_size(cfg: ModelConfig, batch: int, chunk_t: int) -> int:
    return sum(n for _, n in state_layout(cfg, batch, chunk_t))


def state_offsets(cfg: ModelConfig, batch: int, chunk_t: int):
    out = {}
    off = 0
    for name, n in state_layout(cfg, batch, chunk_t):
        out[name] = (off, n)
        off += n
    return out


def _unpack_state(state, cfg: ModelConfig, batch: int, chunk_t: int):
    offs = state_offsets(cfg, batch, chunk_t)
    seg = {name: state[o:o + n] for name, (o, n) in offs.items()}
    return {
        "tokens_out": seg["tokens_out"].reshape(batch, chunk_t),
        "logits": seg["logits"].reshape(batch, cfg.vocab_size),
        "lengths": seg["lengths"].astype(jnp.int32),
        "alive": seg["alive"].astype(jnp.int32),
        "kv": seg["kv"].reshape(kv_shape(cfg, batch)),
    }


def _pack_state(parts, cfg: ModelConfig, batch: int, chunk_t: int):
    return jnp.concatenate([
        parts["tokens_out"].astype(jnp.float32).reshape(-1),
        parts["logits"].astype(jnp.float32).reshape(-1),
        parts["lengths"].astype(jnp.float32).reshape(-1),
        parts["alive"].astype(jnp.float32).reshape(-1),
        parts["kv"].reshape(-1),
    ])


def serve_prefill(params: Params, cfg: ModelConfig, state, tokens, lengths,
                  slot_mask, *, chunk_t: int, use_pallas: bool = True):
    """State-based prefill: (re)initialize the selected slots."""
    batch = tokens.shape[0]
    st = _unpack_state(state, cfg, batch, chunk_t)
    logits_new, kv = prefill_into_slots(params, cfg, st["kv"], tokens,
                                        lengths, slot_mask,
                                        use_pallas=use_pallas)
    mask = slot_mask.astype(bool)
    st["logits"] = jnp.where(mask[:, None], logits_new, st["logits"])
    st["lengths"] = jnp.where(mask, lengths, st["lengths"])
    st["alive"] = jnp.where(mask, 1, st["alive"])
    st["kv"] = kv
    return _pack_state(st, cfg, batch, chunk_t)


def serve_decode(params: Params, cfg: ModelConfig, state, tokens, active,
                 *, chunk_t: int, use_pallas: bool = True):
    """State-based single decode step; host samples from the logits."""
    batch = tokens.shape[0]
    st = _unpack_state(state, cfg, batch, chunk_t)
    logits_new, kv = decode_step(params, cfg, st["kv"], tokens,
                                 st["lengths"], use_pallas=use_pallas)
    act = active.astype(bool)
    st["logits"] = jnp.where(act[:, None], logits_new, st["logits"])
    st["lengths"] = jnp.where(
        act, jnp.minimum(st["lengths"] + 1, cfg.max_seq - 1), st["lengths"])
    st["kv"] = kv  # alive is host-managed in single-step mode
    return _pack_state(st, cfg, batch, chunk_t)


def serve_decode_chunk(params: Params, cfg: ModelConfig, state, active, key,
                       inv_temp, *, chunk_t: int, use_pallas: bool = True):
    """Fused T-step decode with in-graph sampling (the hot path).

    Per step: gumbel-sample from the current logits, freeze slots that have
    emitted EOS, run one decode step for the rest. The sampled tokens land
    in the `tokens_out` segment (PAD after a slot's EOS); host reads
    tokens/lengths/alive back and re-derives completions.
    """
    from . import vocab as V

    batch = active.shape[0]
    st = _unpack_state(state, cfg, batch, chunk_t)

    def step(carry, subkey):
        kv, logits, lengths, alive = carry
        g = -jnp.log(-jnp.log(
            jax.random.uniform(subkey, logits.shape, minval=1e-9,
                               maxval=1.0)))
        # PAD is never a legal generation (it is only loss-masked filler at
        # training time), so exclude it from sampling — mirrors the host
        # sampler's mask in rust/src/sampler.
        masked = logits.at[:, V.PAD].set(-1e30)
        tok = jnp.argmax(masked * inv_temp + g, axis=-1).astype(jnp.int32)
        tok = jnp.where(alive, tok, V.PAD)
        new_logits, new_kv = decode_step(params, cfg, kv, tok, lengths,
                                         use_pallas=use_pallas)
        logits = jnp.where(alive[:, None], new_logits, logits)
        lengths = jnp.where(alive & (tok != V.PAD),
                            jnp.minimum(lengths + 1, cfg.max_seq - 1),
                            lengths)
        alive = alive & (tok != V.EOS)
        return (new_kv, logits, lengths, alive), tok

    keys = jax.random.split(jax.random.wrap_key_data(key), chunk_t)
    alive0 = active.astype(bool)
    (kv, logits, lengths, alive), toks = jax.lax.scan(
        step, (st["kv"], st["logits"], st["lengths"], alive0), keys)
    st.update(tokens_out=toks.T, logits=logits, lengths=lengths,
              alive=alive.astype(jnp.int32), kv=kv)
    return _pack_state(st, cfg, batch, chunk_t)


def lm_forward(params: Params, cfg: ModelConfig, tokens, lengths,
               *, use_pallas: bool = False):
    """Full-sequence logits [B, S, V] (training / PRM trunk path)."""
    rmsnorm, ffn, _, pre_attn = _ops(use_pallas)
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h = rmsnorm(x, params[p + "ln1_w"])
        q = _split_heads(h @ params[p + "wq"], cfg)
        k = _split_heads(h @ params[p + "wk"], cfg)
        v = _split_heads(h @ params[p + "wv"], cfg)
        attn = pre_attn(q, k, v, lengths)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + attn @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2_w"])
        x = x + ffn(h, params[p + "w1"], params[p + "b1"],
                    params[p + "w2"], params[p + "b2"])
    x = rmsnorm(x, params["lnf_w"])
    return x @ params["tok_emb"].T
