"""L2: the Process Reward Model (PRM).

Stand-in for Qwen2.5-Math-PRM-7B (see DESIGN.md §2): a small transformer
trunk over the branch's token prefix, mean-pooled over valid positions,
followed by a 2-layer MLP head with a sigmoid — producing a scalar
"this reasoning process will end correctly" reward in [0, 1].

The serving-side contract matches the paper's: the coordinator calls
``prm_score(prefix_tokens, prefix_len) -> reward`` in batch every T decode
steps and compares rewards against the dynamic pruning threshold.

Trained at build time on trajectory-level labels (prefix of a trajectory
whose final answer is correct → 1, else 0) — the common approximation when
per-step labels are unavailable. Exported as its own HLO executable.
"""

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from . import model as M
from . import vocab as V

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class PrmConfig:
    """PRM trunk + head hyper-parameters."""

    name: str = "prm-mini"
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 256
    d_head_hidden: int = 64  # MLP head hidden width
    vocab_size: int = V.VOCAB_SIZE
    max_seq: int = 256

    def trunk(self) -> M.ModelConfig:
        return M.ModelConfig(
            name=self.name + "-trunk", d_model=self.d_model,
            n_layers=self.n_layers, n_heads=self.n_heads, d_ff=self.d_ff,
            vocab_size=self.vocab_size, max_seq=self.max_seq)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


PRM_MINI = PrmConfig()


def init_params(cfg: PrmConfig, seed: int = 1) -> Params:
    params = M.init_params(cfg.trunk(), seed=seed)
    key = jax.random.PRNGKey(seed + 1000)
    k1, k2 = jax.random.split(key)
    d, dh = cfg.d_model, cfg.d_head_hidden
    params["head.w1"] = (jax.random.normal(k1, (d, dh)) * d ** -0.5
                         ).astype(jnp.float32)
    params["head.b1"] = jnp.zeros((dh,), jnp.float32)
    params["head.w2"] = (jax.random.normal(k2, (dh, 1)) * dh ** -0.5
                         ).astype(jnp.float32)
    params["head.b2"] = jnp.zeros((1,), jnp.float32)
    return params


def _trunk_hidden(params: Params, cfg: PrmConfig, tokens, lengths,
                  *, use_pallas: bool):
    """Mean-pooled trunk representation [B, D] over valid positions."""
    trunk_cfg = cfg.trunk()
    rmsnorm, ffn, _, pre_attn = M._ops(use_pallas)
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None]
    for l in range(trunk_cfg.n_layers):
        p = f"layer{l}."
        h = rmsnorm(x, params[p + "ln1_w"])
        q = M._split_heads(h @ params[p + "wq"], trunk_cfg)
        k = M._split_heads(h @ params[p + "wk"], trunk_cfg)
        v = M._split_heads(h @ params[p + "wv"], trunk_cfg)
        attn = pre_attn(q, k, v, lengths)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, trunk_cfg.d_model)
        x = x + attn @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2_w"])
        x = x + ffn(h, params[p + "w1"], params[p + "b1"],
                    params[p + "w2"], params[p + "b2"])
    x = rmsnorm(x, params["lnf_w"])  # [B, S, D]
    valid = (jnp.arange(s)[None, :] < lengths[:, None]).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1.0)
    return jnp.sum(x * valid[:, :, None], axis=1) / denom


def prm_logit(params: Params, cfg: PrmConfig, tokens, lengths,
              *, use_pallas: bool = False):
    """Pre-sigmoid score [B] (training objective uses the logit)."""
    pooled = _trunk_hidden(params, cfg, tokens, lengths,
                           use_pallas=use_pallas)
    h = jax.nn.gelu(pooled @ params["head.w1"] + params["head.b1"],
                    approximate=True)
    return (h @ params["head.w2"] + params["head.b2"])[:, 0]


def prm_score(params: Params, cfg: PrmConfig, tokens, lengths,
              *, use_pallas: bool = True):
    """Reward in [0, 1] per branch prefix — the exported serving entry."""
    return jax.nn.sigmoid(
        prm_logit(params, cfg, tokens, lengths, use_pallas=use_pallas))
