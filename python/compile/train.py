"""Build-time trainer for the reasoning LM and the PRM.

Runs once inside ``make artifacts`` (CPU, a few minutes): trains each model
size on the SynthMath corpus with a hand-rolled AdamW (optax is not in the
image), trains the PRM on trajectory-labelled prefixes, evaluates the
serving-relevant properties (completion rate, greedy/sampled accuracy,
response-length distribution), and saves parameters as ``.npz``.

Python never runs at serving time: ``aot.py`` turns the trained parameters
+ the L2 graphs into HLO text artifacts the rust runtime loads.
"""

import argparse
import time
from typing import Callable, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import prm as P
from . import vocab as V

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Hand-rolled AdamW (tree-mapped over the params dict).
# ---------------------------------------------------------------------------

def adamw_init(params: Params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state, lr,
                 b1=0.9, b2=0.98, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k])
         for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new_params = {}
    for k in params:
        update = (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps)
        new_params[k] = params[k] - lr * (update + wd * params[k])
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total_steps, peak, warmup=50):
    warm = peak * (step + 1) / warmup
    progress = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
    cos = peak * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup, warm, cos)


# ---------------------------------------------------------------------------
# LM training.
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg: M.ModelConfig, tokens, lengths):
    """Next-token CE over valid (non-pad) target positions."""
    logits = M.lm_forward(params, cfg, tokens, lengths, use_pallas=False)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (jnp.arange(tokens.shape[1] - 1)[None, :] + 1
            < lengths[:, None]).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _batches(tokens: np.ndarray, lengths: np.ndarray, bs: int,
             seed: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(lengths)
    while True:
        idx = rng.integers(0, n, size=bs)
        yield tokens[idx], lengths[idx]


def train_lm(cfg: M.ModelConfig, corpus: D.Corpus, steps: int, bs: int = 32,
             peak_lr: float = 1e-3, seed: int = 0,
             log: Callable[[str], None] = print) -> Params:
    tokens = np.asarray(corpus.tokens, np.int32)
    lengths = np.asarray(corpus.lengths, np.int32)
    params = M.init_params(cfg, seed=seed)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lens, step):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, toks, lens)
        lr = cosine_lr(step, steps, peak_lr)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    it = _batches(tokens, lengths, bs, seed)
    t0 = time.time()
    for s in range(steps):
        toks, lens = next(it)
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks),
                                    jnp.asarray(lens), jnp.asarray(s))
        if s % max(steps // 10, 1) == 0 or s == steps - 1:
            log(f"[{cfg.name}] step {s:5d}/{steps} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    return params


# ---------------------------------------------------------------------------
# PRM training.
# ---------------------------------------------------------------------------

def prm_loss(params: Params, cfg: P.PrmConfig, tokens, lengths, labels):
    logit = P.prm_logit(params, cfg, tokens, lengths, use_pallas=False)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * labels + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def train_prm(cfg: P.PrmConfig, corpus: D.Corpus, steps: int, bs: int = 32,
              peak_lr: float = 1e-3, seed: int = 1, per_traj: int = 3,
              log: Callable[[str], None] = print) -> Params:
    xs, ls, ys = D.prm_examples(corpus, per_traj=per_traj, seed=seed)
    xs = np.asarray(xs, np.int32)
    ls = np.asarray(ls, np.int32)
    ys = np.asarray(ys, np.float32)
    params = P.init_params(cfg, seed=seed)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lens, labels, step):
        loss, grads = jax.value_and_grad(prm_loss)(params, cfg, toks, lens,
                                                   labels)
        lr = cosine_lr(step, steps, peak_lr)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, len(ys), size=bs)
        params, opt, loss = step_fn(
            params, opt, jnp.asarray(xs[idx]), jnp.asarray(ls[idx]),
            jnp.asarray(ys[idx]), jnp.asarray(s))
        if s % max(steps // 10, 1) == 0 or s == steps - 1:
            log(f"[{cfg.name}] step {s:5d}/{steps} bce {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    return params


def prm_auc(params: Params, cfg: P.PrmConfig, corpus: D.Corpus,
            n: int = 512, seed: int = 7) -> float:
    """ROC-AUC of the trained PRM on held-out full trajectories."""
    xs, ls, ys = D.prm_examples(corpus, per_traj=1, seed=seed)
    xs, ls, ys = (np.asarray(xs[:n], np.int32), np.asarray(ls[:n], np.int32),
                  np.asarray(ys[:n]))
    scores = np.asarray(P.prm_score(params, cfg, jnp.asarray(xs),
                                    jnp.asarray(ls), use_pallas=False))
    pos, neg = scores[ys == 1], scores[ys == 0]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    wins = (pos[:, None] > neg[None, :]).mean()
    ties = (pos[:, None] == neg[None, :]).mean()
    return float(wins + 0.5 * ties)


# ---------------------------------------------------------------------------
# Serving-property evaluation (sampled generation with the decode path).
# ---------------------------------------------------------------------------

def sample_responses(params: Params, cfg: M.ModelConfig,
                     questions, samples_per_q: int, temp: float = 1.0,
                     seed: int = 0, max_new: int = 224):
    """Batch-sample responses via the decode path (ref ops, jitted).

    Returns list of (question_idx, gen_tokens, completed) — used by the
    build-time eval and by `test_train.py` to verify the trained model has
    the serving-relevant properties the experiments rely on.
    """
    jobs = [(qi, s) for qi in range(len(questions))
            for s in range(samples_per_q)]
    b = min(64, len(jobs))
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def prefill_fn(params, kv, toks, lens, mask):
        return M.prefill_into_slots(params, cfg, kv, toks, lens, mask,
                                    use_pallas=False)

    @jax.jit
    def decode_fn(params, kv, toks, lens):
        return M.decode_step(params, cfg, kv, toks, lens, use_pallas=False)

    results = []
    for start in range(0, len(jobs), b):
        chunk = jobs[start:start + b]
        nb = len(chunk)
        kv = jnp.zeros(M.kv_shape(cfg, b), jnp.float32)
        toks = np.zeros((b, cfg.prompt_len), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, (qi, _) in enumerate(chunk):
            pt = questions[qi].prompt_tokens()
            toks[i, :len(pt)] = pt
            lens[i] = len(pt)
        lens_j = jnp.asarray(np.maximum(lens, 1))
        logits, kv = prefill_fn(params, kv, jnp.asarray(toks), lens_j,
                                jnp.ones((b,), jnp.int32))
        gen = [[] for _ in range(nb)]
        done = np.zeros(b, bool)
        done[nb:] = True
        cur_len = lens.copy()
        for step in range(max_new):
            key, sk = jax.random.split(key)
            next_tok = jax.random.categorical(sk, logits / temp, axis=-1)
            next_tok = np.asarray(next_tok, np.int32)
            for i in range(nb):
                if not done[i]:
                    gen[i].append(int(next_tok[i]))
                    if next_tok[i] == V.EOS or cur_len[i] + 1 >= cfg.max_seq:
                        done[i] = True
            if done.all():
                break
            logits, kv = decode_fn(params, kv, jnp.asarray(next_tok),
                                   jnp.asarray(cur_len))
            cur_len = np.minimum(cur_len + 1, cfg.max_seq - 1)
        for i, (qi, _) in enumerate(chunk):
            completed = bool(gen[i]) and gen[i][-1] == V.EOS
            results.append((qi, gen[i], completed))
    return results


def eval_serving_properties(params: Params, cfg: M.ModelConfig,
                            spec: D.TaskSpec, n_questions: int = 16,
                            samples_per_q: int = 8, temp: float = 1.0,
                            seed: int = 3) -> dict:
    qs = D.build_eval_questions(spec, n_questions, seed=seed)
    res = sample_responses(params, cfg, qs, samples_per_q, temp=temp,
                           seed=seed)
    lengths = [len(g) for _, g, _ in res]
    completed = [c for _, _, c in res]
    correct = []
    for qi, g, c in res:
        ans = D.extract_answer(g)
        correct.append(bool(c) and ans == qs[qi].answer)
    # Majority vote per question (the Self-Consistency decision rule).
    votes = {}
    for qi, g, c in res:
        ans = D.extract_answer(g) if c else None
        votes.setdefault(qi, []).append(ans)
    maj_correct = 0
    for qi, vs in votes.items():
        vs = [v for v in vs if v is not None]
        if not vs:
            continue
        best = max(set(vs), key=vs.count)
        maj_correct += int(best == qs[qi].answer)
    return {
        "dataset": spec.name,
        "completion_rate": float(np.mean(completed)),
        "sample_accuracy": float(np.mean(correct)),
        "majority_accuracy": maj_correct / len(qs),
        "len_mean": float(np.mean(lengths)),
        "len_p50": float(np.percentile(lengths, 50)),
        "len_p95": float(np.percentile(lengths, 95)),
        "len_max": int(np.max(lengths)),
    }


# ---------------------------------------------------------------------------
# Entry point (invoked by aot.py / Makefile).
# ---------------------------------------------------------------------------

def save_params(path: str, params: Params) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> Params:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--lm-steps", type=int, default=1400)
    ap.add_argument("--prm-steps", type=int, default=700)
    ap.add_argument("--corpus-size", type=int, default=16000)
    ap.add_argument("--models", nargs="*", default=list(M.MODELS))
    args = ap.parse_args()

    corpus = D.build_corpus(args.corpus_size, seed=0)
    import os
    os.makedirs(args.out_dir, exist_ok=True)

    for name in args.models:
        cfg = M.MODELS[name]
        params = train_lm(cfg, corpus, steps=args.lm_steps)
        save_params(f"{args.out_dir}/{cfg.name}.params.npz", params)
        for spec in (D.SYNTH_GAOKAO, D.SYNTH_GPQA):
            stats = eval_serving_properties(params, cfg, spec)
            print(f"[{cfg.name}] {stats}")

    prm_cfg = P.PRM_MINI
    prm_params = train_prm(prm_cfg, corpus, steps=args.prm_steps)
    print(f"[{prm_cfg.name}] held-out AUC: "
          f"{prm_auc(prm_params, prm_cfg, corpus):.3f}")
    save_params(f"{args.out_dir}/{prm_cfg.name}.params.npz", prm_params)


if __name__ == "__main__":
    main()
