"""Procedural SynthHop corpus: questions, reasoning trajectories, datasets.

A *question* is a multi-hop pointer-chasing problem (the multi-hop QA
setting the paper's introduction motivates): the prompt lists a key→value
map over digits plus a start digit and a hop count,

    <q> k1 v1 k2 v2 ... k10 v10 + start hops </q>

and the answer is the digit reached after following the map `hops` times
from `start`. A *trajectory* derives the answer one hop per step:

    <bos> <question> <think>
        <step> cur = next  <step> cur' = next' ...
        [<recheck> ...full re-derivation...]*      # over-thinking loops
    </think> <ans> final <eos>

Each hop is an in-context key lookup — learnable by a tiny 2-layer
attention model on a 1-core build budget (unlike mod-10 arithmetic, which
exhibits grokking-scale training times; see DESIGN.md §2).

Two knobs make the corpus reproduce the phenomena SART exploits:

* ``p_err``  — per-hop probability of an off-by-one slip that is carried
  forward; the trajectory's *final* answer comes from the last derivation,
  so correctness is (approximately) independent of how many <recheck>
  loops happened → the paper's Observation 1 (weak length/quality
  correlation).
* ``p_rethink`` / ``p_continue`` — geometric number of full re-derivations
  → heavy-tailed response lengths → the over-thinking dilemma that
  redundant sampling with early stopping (Lemma 1) addresses.

Dataset presets mirror the paper's two benchmarks: ``synth-gaokao``
(moderate) and ``synth-gpqa`` (hard: more hops, more re-thinking, higher
slip rate).
"""

import dataclasses
import random
from typing import List, Optional, Tuple

from . import vocab as V

NUM_KEYS = 10  # keys are the digits 0..9, each present exactly once


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """Difficulty profile of a dataset (mirrored by rust/src/workload)."""

    name: str
    min_hops: int
    max_hops: int
    p_err: float
    p_rethink: float
    p_continue: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# The two evaluation datasets (paper: GAOKAO and GPQA).
SYNTH_GAOKAO = TaskSpec(
    name="synth-gaokao",
    min_hops=3,
    max_hops=5,
    p_err=0.08,
    p_rethink=0.35,
    p_continue=0.55,
)
SYNTH_GPQA = TaskSpec(
    name="synth-gpqa",
    min_hops=5,
    max_hops=8,
    p_err=0.13,
    p_rethink=0.6,
    p_continue=0.6,
)
DATASETS = {s.name: s for s in (SYNTH_GAOKAO, SYNTH_GPQA)}


@dataclasses.dataclass(frozen=True)
class Question:
    """A single request: digit map, start digit, hop count."""

    mapping: Tuple[int, ...]  # mapping[k] = value of key k, len 10
    start: int
    hops: int

    @property
    def answer(self) -> int:
        cur = self.start
        for _ in range(self.hops):
            cur = self.mapping[cur]
        return cur

    def tokens(self) -> List[int]:
        """``<q> k v k v ... + start hops </q>`` (keys in shuffled order —
        the shuffle is part of the instance, derived from the mapping)."""
        out = [V.Q]
        # Deterministic per-instance key order: sort keys by (value, key)
        # hash-ish permutation so the key order varies across instances
        # without storing extra state.
        order = sorted(range(NUM_KEYS),
                       key=lambda k: (self.mapping[k] * 7 + k * 3) % NUM_KEYS)
        for k in order:
            out.append(V.digit(k))
            out.append(V.digit(self.mapping[k]))
        out.append(V.PLUS)
        out.append(V.digit(self.start))
        out.append(V.digit(self.hops % 10))
        out.append(V.EQ)
        return out

    def prompt_tokens(self) -> List[int]:
        """Serving prompt: ``<bos> <question> <think>``."""
        return [V.BOS] + self.tokens() + [V.THINK]


def sample_question(spec: TaskSpec, rng: random.Random) -> Question:
    mapping = tuple(rng.randrange(10) for _ in range(NUM_KEYS))
    start = rng.randrange(10)
    hops = rng.randint(spec.min_hops, spec.max_hops)
    return Question(mapping=mapping, start=start, hops=hops)


def _derivation(
    q: Question, spec: TaskSpec, rng: random.Random
) -> Tuple[List[int], int]:
    """One full hop-by-hop derivation with stochastic off-by-one slips.

    Returns (tokens, derived_answer). Tokens per hop:
    ``<step> cur = next`` (4 tokens).
    """
    toks: List[int] = []
    cur = q.start
    for _ in range(q.hops):
        nxt = q.mapping[cur]
        if rng.random() < spec.p_err:
            nxt = (nxt + rng.choice((-1, 1))) % 10  # carried slip
        toks += [V.STEP, V.digit(cur), V.EQUALS, V.digit(nxt)]
        cur = nxt
    return toks, cur


def sample_trajectory(
    q: Question,
    spec: TaskSpec,
    rng: random.Random,
    max_len: int = 256,
) -> Tuple[List[int], int, int]:
    """Sample one full training trajectory for question ``q``.

    Returns (tokens, final_answer, num_rechecks). The sequence always fits
    in ``max_len`` (re-think loops are truncated to fit, mirroring a
    context-length cap).
    """
    prefix = [V.BOS] + q.tokens() + [V.THINK]
    deriv, ans = _derivation(q, spec, rng)
    body = list(deriv)
    # Over-thinking: geometric number of full re-derivations.
    rechecks = 0
    if rng.random() < spec.p_rethink:
        while True:
            extra, ans2 = _derivation(q, spec, rng)
            candidate = body + [V.RECHECK] + extra
            # +4: </think> <ans> digit <eos>.
            if len(prefix) + len(candidate) + 4 > max_len:
                break
            body = candidate
            ans = ans2
            rechecks += 1
            if rng.random() >= spec.p_continue:
                break
    tokens = prefix + body + [V.ETHINK, V.ANS, V.digit(ans), V.EOS]
    assert len(tokens) <= max_len, (len(tokens), max_len)
    return tokens, ans, rechecks


def extract_answer(tokens: List[int]) -> Optional[int]:
    """Parse the answered digit out of a (generated) token sequence.

    Mirrors rust/src/tokenizer answer extraction: the digit following the
    *last* ``<ans>`` marker. Returns None if absent/malformed.
    """
    ans_pos = None
    for i, t in enumerate(tokens):
        if t == V.ANS:
            ans_pos = i
    if ans_pos is None or ans_pos + 1 >= len(tokens):
        return None
    nxt = tokens[ans_pos + 1]
    return V.digit_value(nxt) if V.is_digit(nxt) else None


@dataclasses.dataclass
class Corpus:
    """Padded training batch material."""

    tokens: "list"  # List[List[int]] padded to max_len with PAD
    lengths: List[int]
    answers: List[int]  # derived (possibly wrong) final answer per traj
    truths: List[int]  # ground-truth answer per traj
    rechecks: List[int]

    def __len__(self) -> int:
        return len(self.lengths)


def build_corpus(
    n: int,
    specs: Tuple[TaskSpec, ...] = (SYNTH_GAOKAO, SYNTH_GPQA),
    seed: int = 0,
    max_len: int = 256,
) -> Corpus:
    """Mixed-difficulty corpus the LM is trained on."""
    rng = random.Random(seed)
    toks, lens, answers, truths, rc = [], [], [], [], []
    for i in range(n):
        spec = specs[i % len(specs)]
        q = sample_question(spec, rng)
        t, ans, r = sample_trajectory(q, spec, rng, max_len=max_len)
        lens.append(len(t))
        toks.append(t + [V.PAD] * (max_len - len(t)))
        answers.append(ans)
        truths.append(q.answer)
        rc.append(r)
    return Corpus(tokens=toks, lengths=lens, answers=answers, truths=truths,
                  rechecks=rc)


def build_eval_questions(spec: TaskSpec, n: int, seed: int) -> List[Question]:
    rng = random.Random(seed)
    return [sample_question(spec, rng) for _ in range(n)]


def prm_examples(
    corpus: Corpus, per_traj: int, seed: int, max_len: int = 256
) -> Tuple[list, list, list]:
    """(prefix_tokens, prefix_len, label) triples for PRM training.

    Prefixes are cut at <step>/<recheck> boundaries (the natural "process"
    granularity); label = 1 iff the trajectory's final answer equals ground
    truth. This matches how trajectory-level supervision is commonly used to
    train PRMs when step labels are unavailable.
    """
    rng = random.Random(seed)
    xs, ls, ys = [], [], []
    for toks, length, ans, truth in zip(
        corpus.tokens, corpus.lengths, corpus.answers, corpus.truths
    ):
        seq = toks[:length]
        cuts = [i for i, t in enumerate(seq) if t in (V.STEP, V.RECHECK)]
        cuts.append(length)  # include the full trajectory
        chosen = rng.sample(cuts, min(per_traj, len(cuts)))
        for c in chosen:
            prefix = seq[:c] if c < length else seq
            xs.append(prefix + [V.PAD] * (max_len - len(prefix)))
            ls.append(len(prefix))
            ys.append(1.0 if ans == truth else 0.0)
    return xs, ls, ys
