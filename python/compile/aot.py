"""AOT exporter: trained L2 graphs → HLO text artifacts for the rust runtime.

Emits HLO *text*, NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``:
the image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids, ``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:

    tokenizer.json            vocab + dataset task specs (rust mirrors these)
    manifest.json             models, param layout, executable inventory
    <model>.params.npz        trainer checkpoint (python-side only)
    <model>/params.bin        f32 little-endian concat, sorted-name order
    <model>/decode_b{B}.hlo.txt    one batched decode step
    <model>/prefill_b{B}.hlo.txt   prompt prefill into selected slots
    <model>/decode_chunk_b{B}_t{T}.hlo.txt  fused T-step decode (perf path)
    prm-mini/score_b{B}.hlo.txt    PRM reward scoring

Incremental: skipped when the output is newer than its inputs (the
Makefile additionally guards the whole step).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import prm as P
from . import train as T
from . import vocab as V

DEFAULT_BATCHES = (1, 2, 4, 8, 16)
DEFAULT_PRM_BATCHES = (8,)
PRM_SEQ_BUCKETS = (64, 128, 256)
DEFAULT_CHUNK_T = 16


def to_hlo_text(lowered) -> str:
    """Lower a jitted computation to HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: every serving executable returns exactly ONE
    # array (the packed state / the reward vector). A tuple root would come
    # back from PJRT as a single opaque tuple buffer that cannot be re-fed
    # as an input (the rust binding has no get_tuple_element).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def _write(path: str, text: str, log) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    log(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def export_params_bin(params, out_path: str):
    """Flatten params (sorted names) into one f32 LE blob + layout entries."""
    names, flat = M.flatten_params(params)
    entries = []
    offset = 0
    with open(out_path, "wb") as f:
        for name, arr in zip(names, flat):
            a = np.asarray(arr, dtype="<f4")
            f.write(a.tobytes())
            entries.append({
                "name": name,
                "shape": list(a.shape),
                "dtype": "f32",
                "offset_bytes": offset,
                "num_elements": int(a.size),
            })
            offset += a.nbytes
    return entries


# ---------------------------------------------------------------------------
# Exported entry points (closures over config; params passed as flat tuple
# so the HLO argument order matches params.bin's sorted-name layout).
# ---------------------------------------------------------------------------

def _state_spec(cfg: M.ModelConfig, batch: int, chunk_t: int):
    return jax.ShapeDtypeStruct((M.state_size(cfg, batch, chunk_t),),
                                jnp.float32)


def lower_decode(cfg: M.ModelConfig, names, batch: int, chunk_t: int):
    """Single decode step over the packed state (host-side sampling)."""
    def fn(*args):
        flat = args[:len(names)]
        state, tokens, active = args[len(names):]
        params = M.unflatten_params(names, flat)
        return M.serve_decode(params, cfg, state, tokens, active,
                              chunk_t=chunk_t, use_pallas=True)

    specs = _param_specs(cfg, names) + [
        _state_spec(cfg, batch, chunk_t),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    # Donate the state buffer: the KV update happens in place on device.
    return jax.jit(fn, donate_argnums=(len(names),)).lower(*specs)


def lower_decode_chunk(cfg: M.ModelConfig, names, batch: int, t_steps: int):
    """Fused T-step decode with in-graph sampling (the L3 hot path)."""
    def fn(*args):
        flat = args[:len(names)]
        state, active, key, inv_temp = args[len(names):]
        params = M.unflatten_params(names, flat)
        return M.serve_decode_chunk(params, cfg, state, active, key,
                                    inv_temp, chunk_t=t_steps,
                                    use_pallas=True)

    specs = _param_specs(cfg, names) + [
        _state_spec(cfg, batch, t_steps),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),  # threefry key data
        jax.ShapeDtypeStruct((), jnp.float32),   # 1/temperature
    ]
    return jax.jit(fn, donate_argnums=(len(names),)).lower(*specs)


def lower_prefill(cfg: M.ModelConfig, names, batch: int, chunk_t: int):
    """Prompt prefill into selected slots of the packed state."""
    def fn(*args):
        flat = args[:len(names)]
        state, tokens, lengths, slot_mask = args[len(names):]
        params = M.unflatten_params(names, flat)
        return M.serve_prefill(params, cfg, state, tokens, lengths,
                               slot_mask, chunk_t=chunk_t, use_pallas=True)

    specs = _param_specs(cfg, names) + [
        _state_spec(cfg, batch, chunk_t),
        jax.ShapeDtypeStruct((batch, cfg.prompt_len), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    return jax.jit(fn, donate_argnums=(len(names),)).lower(*specs)


def lower_peek(cfg: M.ModelConfig, batch: int, chunk_t: int):
    """Control-prefix readback: state -> [tokens_out|logits|lengths|alive].

    The CPU PJRT client lacks CopyRawToHost, so partial readback is done
    on device: this param-free executable slices the small control prefix
    off the packed state; the host then fetches its (tiny) literal.
    """
    control = M.state_size(cfg, batch, chunk_t) - M.state_offsets(
        cfg, batch, chunk_t)["kv"][1]

    def fn(state):
        return state[:control]

    return jax.jit(fn).lower(_state_spec(cfg, batch, chunk_t))


def lower_prm(cfg: P.PrmConfig, names, batch: int, seq: int):
    """PRM scorer at a (batch, seq) bucket.

    Sequence buckets matter for serving cost: most pruning queries carry
    short prefixes, and scoring them in a 256-position executable wastes
    4x the FLOPs (see EXPERIMENTS.md §Perf L3).
    """
    def fn(*args):
        flat = args[:len(names)]
        tokens, lengths = args[len(names):]
        params = M.unflatten_params(names, flat)
        return P.prm_score(params, cfg, tokens, lengths, use_pallas=True)

    specs = _prm_param_specs(cfg, names) + [
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    return jax.jit(fn).lower(*specs)


def _param_specs(cfg: M.ModelConfig, names):
    shapes = {k: v.shape for k, v in M.init_params(cfg, seed=0).items()}
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]


def _prm_param_specs(cfg: P.PrmConfig, names):
    shapes = {k: v.shape for k, v in P.init_params(cfg, seed=0).items()}
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]


# ---------------------------------------------------------------------------
# Orchestration.
# ---------------------------------------------------------------------------

def ensure_trained(out_dir: str, model_names, lm_steps: int, prm_steps: int,
                   corpus_size: int, log):
    """Train any missing checkpoint (idempotent across reruns)."""
    corpus = None

    def get_corpus():
        nonlocal corpus
        if corpus is None:
            log(f"building corpus (n={corpus_size})...")
            corpus = D.build_corpus(corpus_size, seed=0)
        return corpus

    for name in model_names:
        path = f"{out_dir}/{name}.params.npz"
        if not os.path.exists(path):
            cfg = M.MODELS[name]
            log(f"training {name} ({lm_steps} steps)...")
            params = T.train_lm(cfg, get_corpus(), steps=lm_steps, log=log)
            T.save_params(path, params)
            for spec in (D.SYNTH_GAOKAO, D.SYNTH_GPQA):
                stats = T.eval_serving_properties(params, cfg, spec,
                                                  n_questions=12,
                                                  samples_per_q=8)
                log(f"  [{name}] {stats}")
    prm_path = f"{out_dir}/{P.PRM_MINI.name}.params.npz"
    if not os.path.exists(prm_path):
        log(f"training {P.PRM_MINI.name} ({prm_steps} steps)...")
        prm_params = T.train_prm(P.PRM_MINI, get_corpus(), steps=prm_steps,
                                 log=log)
        auc = T.prm_auc(prm_params, P.PRM_MINI, get_corpus())
        log(f"  [{P.PRM_MINI.name}] held-out AUC: {auc:.3f}")
        T.save_params(prm_path, prm_params)


def export_all(out_dir: str, model_names, batches, prm_batches, chunk_t,
               log=print):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "generated_unix": int(time.time()),
        "models": {},
        "prm": {},
        "datasets": {k: s.to_json() for k, s in D.DATASETS.items()},
    }

    for name in model_names:
        cfg = M.MODELS[name]
        params = T.load_params(f"{out_dir}/{name}.params.npz")
        names, _ = M.flatten_params(params)
        mdir = f"{out_dir}/{name}"
        os.makedirs(mdir, exist_ok=True)
        entries = export_params_bin(params, f"{mdir}/params.bin")
        execs = {"decode": {}, "prefill": {}, "decode_chunk": {}, "peek": {}}
        state_sizes = {}
        for b in batches:
            t0 = time.time()
            _write(f"{mdir}/decode_b{b}.hlo.txt",
                   to_hlo_text(lower_decode(cfg, names, b, chunk_t)), log)
            _write(f"{mdir}/prefill_b{b}.hlo.txt",
                   to_hlo_text(lower_prefill(cfg, names, b, chunk_t)), log)
            _write(f"{mdir}/decode_chunk_b{b}_t{chunk_t}.hlo.txt",
                   to_hlo_text(lower_decode_chunk(cfg, names, b, chunk_t)),
                   log)
            _write(f"{mdir}/peek_b{b}.hlo.txt",
                   to_hlo_text(lower_peek(cfg, b, chunk_t)), log)
            execs["decode"][str(b)] = f"{name}/decode_b{b}.hlo.txt"
            execs["prefill"][str(b)] = f"{name}/prefill_b{b}.hlo.txt"
            execs["decode_chunk"][str(b)] = (
                f"{name}/decode_chunk_b{b}_t{chunk_t}.hlo.txt")
            execs["peek"][str(b)] = f"{name}/peek_b{b}.hlo.txt"
            state_sizes[str(b)] = M.state_size(cfg, b, chunk_t)
            log(f"  [{name}] batch {b} lowered in {time.time() - t0:.1f}s")
        manifest["models"][name] = {
            "config": cfg.to_json(),
            "params_bin": f"{name}/params.bin",
            "params": entries,
            "kv_shape_per_batch": list(M.kv_shape(cfg, 1)),
            "chunk_t": chunk_t,
            # Cross-check values: rust recomputes the packed-state layout
            # from the config and asserts these totals match.
            "state_sizes": state_sizes,
            "executables": execs,
        }

    # PRM.
    prm_cfg = P.PRM_MINI
    prm_params = T.load_params(f"{out_dir}/{prm_cfg.name}.params.npz")
    pnames, _ = M.flatten_params(prm_params)
    pdir = f"{out_dir}/{prm_cfg.name}"
    os.makedirs(pdir, exist_ok=True)
    prm_entries = export_params_bin(prm_params, f"{pdir}/params.bin")
    prm_execs = {}
    prm_batch = max(prm_batches)
    for s_bucket in PRM_SEQ_BUCKETS:
        _write(f"{pdir}/score_b{prm_batch}_s{s_bucket}.hlo.txt",
               to_hlo_text(lower_prm(prm_cfg, pnames, prm_batch, s_bucket)),
               log)
        prm_execs[str(s_bucket)] = (
            f"{prm_cfg.name}/score_b{prm_batch}_s{s_bucket}.hlo.txt")
    manifest["prm"] = {
        "config": prm_cfg.to_json(),
        "params_bin": f"{prm_cfg.name}/params.bin",
        "params": prm_entries,
        "batch": prm_batch,
        # Keyed by SEQUENCE bucket (batch is fixed): the scorer picks the
        # smallest bucket that fits the longest prefix in a chunk.
        "executables": {"score": prm_execs},
    }

    with open(f"{out_dir}/tokenizer.json", "w") as f:
        json.dump(V.tokenizer_spec(), f, indent=1)
    with open(f"{out_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"manifest written: {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts dir (also accepts the Makefile's "
                         "../artifacts/model.hlo.txt sentinel path)")
    ap.add_argument("--models", nargs="*", default=list(M.MODELS))
    ap.add_argument("--batches", type=int, nargs="*",
                    default=list(DEFAULT_BATCHES))
    ap.add_argument("--prm-batches", type=int, nargs="*",
                    default=list(DEFAULT_PRM_BATCHES))
    ap.add_argument("--chunk-t", type=int, default=DEFAULT_CHUNK_T)
    ap.add_argument("--lm-steps", type=int, default=1400)
    ap.add_argument("--prm-steps", type=int, default=600)
    ap.add_argument("--corpus-size", type=int, default=12000)
    args = ap.parse_args()

    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):  # Makefile sentinel file
        out_dir = os.path.dirname(out_dir)

    ensure_trained(out_dir, args.models, args.lm_steps, args.prm_steps,
                   args.corpus_size, print)
    export_all(out_dir, args.models, args.batches, args.prm_batches,
               args.chunk_t, print)
    # Makefile sentinel so `make artifacts` is a cheap no-op when fresh.
    if args.out.endswith(".hlo.txt"):
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
