"""Pallas fused RMSNorm kernel.

Normalizes the last axis and applies the learned scale in one VMEM pass.
Grid tiles the (flattened) row axis so arbitrarily large activations
stream through a fixed VMEM footprint; the model dimension stays resident
per tile. Reduction is performed in f32 regardless of input dtype.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "eps"))
def rmsnorm(x, w, *, block_t: int = 128, eps: float = 1e-6):
    """Fused RMSNorm over the last axis.

    Args:
      x: [..., D] activations.
      w: [D] scale.
      block_t: row-tile size (rows are the flattened leading axes).
    """
    orig_shape = x.shape
    d = x.shape[-1]
    t = 1
    for s in orig_shape[:-1]:
        t *= s
    x2 = x.reshape(t, d)
    bt = min(block_t, t)
    # Pad rows up to a multiple of the tile.
    t_pad = (t + bt - 1) // bt * bt
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, d), x.dtype),
        interpret=True,
    )(x2, w)
    return out[:t].reshape(orig_shape)
