"""Pallas fused feed-forward kernel: ``gelu(x @ w1 + b1) @ w2 + b2``.

The whole position-wise FFN is fused in a single kernel so the hidden
activation ``h`` (the widest tensor in the block, [block_t, F]) lives only
in VMEM and is never written back to HBM — the main bandwidth saving of a
fused FFN on TPU. The row axis is tiled by the grid; both weight matrices
stay resident per tile (they fit VMEM for the model sizes this repo
serves; larger models would add an F-axis accumulation grid dimension).
Matmuls accumulate in f32 (MXU-native).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jnp.dot(x, w1_ref[...].astype(jnp.float32)) + b1_ref[...]
    h = jax.nn.gelu(h, approximate=True)
    y = jnp.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def ffn(x, w1, b1, w2, b2, *, block_t: int = 128):
    """Fused FFN over the last axis.

    Args:
      x: [..., D]; w1: [D, F]; b1: [F]; w2: [F, D]; b2: [D].
      block_t: row-tile size over the flattened leading axes.
    """
    orig_shape = x.shape
    d = x.shape[-1]
    f = w1.shape[1]
    t = 1
    for s in orig_shape[:-1]:
        t *= s
    x2 = x.reshape(t, d)
    bt = min(block_t, t)
    t_pad = (t + bt - 1) // bt * bt
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=(t_pad // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, d), x.dtype),
        interpret=True,
    )(x2, w1, b1, w2, b2)
    return out[:t].reshape(orig_shape)
