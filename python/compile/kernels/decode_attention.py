"""Pallas flash-decoding attention kernel.

One decode step attends a single query per (slot, head) against the KV
cache. Tiling (see DESIGN.md §3 and §Perf):

* grid = (S // block_s,): KV is streamed HBM→VMEM in ``block_s``-position
  tiles via ``BlockSpec``; each tile is **batch-dense** ([B, H, block_s,
  D]), so every grid step issues one large MXU-shaped contraction instead
  of B small ones. (First revision used a (B, S//block_s) grid; the
  batch-dense re-tiling was the §Perf L1 iteration that recovered ~2x —
  interpret-mode lowering preserves the batched einsum, and on TPU the
  tile still fits VMEM comfortably: B·H·block_s·D·4B ≈ 0.5 MB at the
  largest exported shapes.)
* online softmax with running (m, l, acc) carried in f32 VMEM scratch
  across KV tiles — the flash-decoding recurrence, so the full [S] score
  row never materializes;
* length masking (positions >= lengths[b] are garbage) makes fixed-shape
  slots correct for ragged branches.

Under ``interpret=True`` this lowers to plain HLO so the rust CPU PJRT
client can execute it; on a real TPU the same BlockSpec schedule targets
VMEM/MXU directly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, num_blocks: int, scale: float):
    s_idx = pl.program_id(0)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)        # [B, H, D]
    k = k_ref[...].astype(jnp.float32)        # [B, H, block_s, D]
    v = v_ref[...].astype(jnp.float32)        # [B, H, block_s, D]
    lengths = len_ref[...]                    # [B]

    # Scores for this KV tile: [B, H, block_s].
    s = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos < lengths[:, None, None], s, _NEG_INF)

    # Online-softmax update.
    m_prev = m_ref[...]                       # [B, H, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                    # [B, H, block_s]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("bhs,bhsd->bhd", p, v)
    m_ref[...] = m_new

    @pl.when(s_idx == num_blocks - 1)
    def _finalize():
        # lengths >= 1 always (the current token is in the cache), so l > 0.
        o_ref[...] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k, v, lengths, *, block_s: int = 256):
    """Flash-decoding attention. Shapes as in ``ref.decode_attention``.

    Args:
      q: [B, H, D]; k, v: [B, H, S, D]; lengths: [B] int32 (>= 1).
      block_s: KV tile size along the sequence axis (must divide S).
    """
    b, h, s, d = k.shape
    block_s = min(block_s, s)
    if s % block_s != 0:
        raise ValueError(f"seq len {s} not divisible by block_s {block_s}")
    num_blocks = s // block_s
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _kernel, block_s=block_s, num_blocks=num_blocks, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((b,), lambda j: (0,)),                 # lengths
            pl.BlockSpec((b, h, d), lambda j: (0, 0, 0)),       # q
            pl.BlockSpec((b, h, block_s, d), lambda j: (0, 0, j, 0)),
            pl.BlockSpec((b, h, block_s, d), lambda j: (0, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((b, h, d), lambda j: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, h, 1), jnp.float32),  # running max  m
            pltpu.VMEM((b, h, 1), jnp.float32),  # running norm l
            pltpu.VMEM((b, h, d), jnp.float32),  # running acc
        ],
        interpret=True,
    )(lengths, q, k, v)
