"""Pallas flash-style causal prefill attention kernel.

Grid = (B, Sq // block_q): each step computes one query tile for one slot
against KV tiles streamed across the sequence, with the standard
flash-attention online-softmax recurrence carried in f32 VMEM scratch.
Causality is enforced at tile granularity (KV tiles strictly above the
query tile's diagonal are skipped by masking) plus an element mask inside
the diagonal tile; per-slot prompt-length masking handles the ragged batch.

Rows at positions >= lengths[b] would have an all-masked score row; they
are forced to attend position 0 (uniform over one key) so no NaNs are
produced — callers never read those rows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_q: int, block_kv: int, num_kv: int, scale: float):
    q_idx = pl.program_id(1)
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [H, block_q, D]
    k = k_ref[0].astype(jnp.float32)  # [H, block_kv, D]
    v = v_ref[0].astype(jnp.float32)
    length = len_ref[0]

    s = jnp.einsum("hid,hjd->hij", q, k) * scale  # [H, bq, bkv]
    rows = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    cols = kv_idx * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    mask = (cols <= rows) & (cols < length)
    # Keep column 0 open for out-of-range rows so softmax stays finite.
    mask = mask | (cols == 0)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]  # [H, block_q, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("hij,hjd->hid", p, v)
    m_ref[...] = m_new

    @pl.when(kv_idx == num_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv"))
def prefill_attention(q, k, v, lengths, *, block_q: int = 16,
                      block_kv: int = 32):
    """Causal prefill attention. Shapes as in ``ref.prefill_attention``.

    Args:
      q, k, v: [B, H, S, D]; lengths: [B] int32.
      block_q/block_kv: query/key tile sizes (must divide S).
    """
    b, h, s, d = q.shape
    if s % block_q != 0 or s % block_kv != 0:
        raise ValueError(f"S={s} must be divisible by tiles "
                         f"({block_q}, {block_kv})")
    num_kv = s // block_kv
    kernel = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, num_kv=num_kv,
        scale=1.0 / (d ** 0.5))
    return pl.pallas_call(
        kernel,
        grid=(b, s // block_q, num_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, t: (i,)),
            pl.BlockSpec((1, h, block_q, d), lambda i, j, t: (i, 0, j, 0)),
            pl.BlockSpec((1, h, block_kv, d), lambda i, j, t: (i, 0, t, 0)),
            pl.BlockSpec((1, h, block_kv, d), lambda i, j, t: (i, 0, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, block_q, d), lambda i, j, t: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, block_q, 1), jnp.float32),
            pltpu.VMEM((h, block_q, 1), jnp.float32),
            pltpu.VMEM((h, block_q, d), jnp.float32),
        ],
        interpret=True,
    )(lengths, q, k, v)
