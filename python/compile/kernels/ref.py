"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels match
these to tight tolerances. They are also used by the build-time trainer
(`train.py`) where interpret-mode Pallas would be needlessly slow — the
AOT-exported serving graphs use the Pallas kernels, and the equivalence is
what the kernel tests establish.
"""

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMS-normalize the last axis and scale: ``x / rms(x) * w``."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps) * w).astype(x.dtype)


def ffn(x, w1, b1, w2, b2):
    """Fused position-wise feed-forward: ``gelu(x @ w1 + b1) @ w2 + b2``."""
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2


def decode_attention(q, k, v, lengths):
    """Single-position attention against a (padded) KV cache.

    Args:
      q: [B, H, D]    query at the current decode position.
      k: [B, H, S, D] key cache (positions >= lengths[b] are garbage).
      v: [B, H, S, D] value cache.
      lengths: [B] int32, number of *valid* cache positions per slot
        (inclusive of the current token, whose k/v were just written).

    Returns [B, H, D].
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(k.shape[2])[None, None, :]
    mask = pos < lengths[:, None, None]
    scores = jnp.where(mask, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p.astype(v.dtype), v)


def prefill_attention(q, k, v, lengths):
    """Causal self-attention over a padded prompt block.

    Args:
      q, k, v: [B, H, S, D].
      lengths: [B] int32 valid prompt length per slot.

    Returns [B, H, S, D]. Rows at positions >= lengths[b] attend only to
    the valid prefix, so they never contain NaNs, but their values are
    unused by the caller.
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bhid,bhjd->bhij", q, k).astype(jnp.float32) * scale
    s = q.shape[2]
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    causal = j <= i
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(causal[None, None] & valid, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhij,bhjd->bhid", p.astype(v.dtype), v)
