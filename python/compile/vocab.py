"""Token vocabulary for the synthetic reasoning language (SynthMath).

The serving stack reproduces SART's dynamics with a tiny reasoning LM
trained on a procedural corpus of step-by-step modular arithmetic. The
vocabulary is deliberately small (32 ids) so the build-time training run
is fast while the *serving-side* phenomena the paper studies — heavy-tail
response lengths, imperfect per-branch accuracy, over-thinking loops —
all emerge from real autoregressive sampling.

This file is the single source of truth for token ids; `aot.py` exports it
as `artifacts/tokenizer.json`, which the rust tokenizer mirrors.
"""

# Special / structural tokens.
PAD = 0  # padding (never trained as a target)
BOS = 1  # beginning of sequence
EOS = 2  # end of sequence; a branch is "completed" when it samples EOS
Q = 3  # question open
EQ = 4  # question close
THINK = 5  # reasoning open  (serving prompts end right after THINK)
ETHINK = 6  # reasoning close
ANS = 7  # answer marker
STEP = 8  # one derivation step follows
RECHECK = 9  # the model re-verifies the whole chain (over-thinking loop)

# Digits 0..9 -> ids 10..19.
DIGIT_BASE = 10

# Operators.
PLUS = 20
MUL = 21
EQUALS = 22

VOCAB_SIZE = 32  # ids 23..31 reserved (keeps shapes MXU/lane friendly)

TOKEN_NAMES = {
    PAD: "<pad>",
    BOS: "<bos>",
    EOS: "<eos>",
    Q: "<q>",
    EQ: "</q>",
    THINK: "<think>",
    ETHINK: "</think>",
    ANS: "<ans>",
    STEP: "<step>",
    RECHECK: "<recheck>",
    PLUS: "+",
    MUL: "*",
    EQUALS: "=",
}
for _d in range(10):
    TOKEN_NAMES[DIGIT_BASE + _d] = str(_d)


def digit(d: int) -> int:
    """Token id of digit ``d`` (0..9)."""
    assert 0 <= d <= 9
    return DIGIT_BASE + d


def is_digit(tok: int) -> bool:
    return DIGIT_BASE <= tok < DIGIT_BASE + 10


def digit_value(tok: int) -> int:
    assert is_digit(tok)
    return tok - DIGIT_BASE


def op_token(op: str) -> int:
    return PLUS if op == "+" else MUL


def detokenize(tokens) -> str:
    """Human-readable rendering of a token sequence (debugging / logs)."""
    return " ".join(TOKEN_NAMES.get(int(t), f"<{int(t)}?>") for t in tokens)


def tokenizer_spec() -> dict:
    """JSON-serializable spec consumed by the rust tokenizer."""
    return {
        "vocab_size": VOCAB_SIZE,
        "pad": PAD,
        "bos": BOS,
        "eos": EOS,
        "q": Q,
        "eq": EQ,
        "think": THINK,
        "ethink": ETHINK,
        "ans": ANS,
        "step": STEP,
        "recheck": RECHECK,
        "digit_base": DIGIT_BASE,
        "plus": PLUS,
        "mul": MUL,
        "equals": EQUALS,
        "names": {str(k): v for k, v in TOKEN_NAMES.items()},
    }
