"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes/dtypes; every kernel must match `ref.py` to tight
tolerances under interpret mode — this equivalence is what lets the
trainer use the fast jnp path while serving uses the Pallas path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention
from compile.kernels.ffn import ffn
from compile.kernels.prefill_attention import prefill_attention
from compile.kernels.rmsnorm import rmsnorm

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.integers(1, 200),
    d=st.sampled_from([16, 64, 128]),
    block=st.sampled_from([32, 128]),
    dtype=st.sampled_from([jnp.float32]),
)
def test_rmsnorm_matches_ref(rows, d, block, dtype):
    x = rand(0, (rows, d), dtype)
    w = rand(1, (d,), dtype)
    out = rmsnorm(x, w, block_t=block)
    np.testing.assert_allclose(out, ref.rmsnorm(x, w), rtol=1e-5, atol=1e-5)


def test_rmsnorm_3d_shapes():
    x = rand(2, (3, 17, 64), jnp.float32)
    w = rand(3, (64,), jnp.float32)
    np.testing.assert_allclose(
        rmsnorm(x, w), ref.rmsnorm(x, w), rtol=1e-5, atol=1e-5)


def test_rmsnorm_extreme_magnitudes():
    # f32 reduction stability: huge and tiny inputs.
    for scale in (1e-4, 1e4):
        x = rand(4, (8, 64), jnp.float32, scale)
        w = jnp.ones((64,), jnp.float32)
        np.testing.assert_allclose(
            rmsnorm(x, w), ref.rmsnorm(x, w), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ffn
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.integers(1, 150),
    d=st.sampled_from([32, 64]),
    f=st.sampled_from([128, 256]),
)
def test_ffn_matches_ref(rows, d, f):
    x = rand(5, (rows, d), jnp.float32)
    w1 = rand(6, (d, f), jnp.float32, 0.05)
    b1 = rand(7, (f,), jnp.float32)
    w2 = rand(8, (f, d), jnp.float32, 0.05)
    b2 = rand(9, (d,), jnp.float32)
    out = ffn(x, w1, b1, w2, b2)
    np.testing.assert_allclose(
        out, ref.ffn(x, w1, b1, w2, b2), rtol=2e-4, atol=2e-4)


def test_ffn_row_padding_exact():
    # Rows not divisible by the tile must not leak padding garbage.
    x = rand(10, (5, 32), jnp.float32)
    w1 = rand(11, (32, 64), jnp.float32, 0.1)
    b1 = jnp.zeros((64,))
    w2 = rand(12, (64, 32), jnp.float32, 0.1)
    b2 = jnp.zeros((32,))
    out = ffn(x, w1, b1, w2, b2, block_t=4)
    np.testing.assert_allclose(
        out, ref.ffn(x, w1, b1, w2, b2), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([128, 256]),
    d=st.sampled_from([16, 32]),
    block=st.sampled_from([64, 128]),
    data=st.data(),
)
def test_decode_attention_matches_ref(b, h, s, d, block, data):
    q = rand(13, (b, h, d), jnp.float32)
    k = rand(14, (b, h, s, d), jnp.float32)
    v = rand(15, (b, h, s, d), jnp.float32)
    lens = jnp.asarray(
        data.draw(st.lists(st.integers(1, s), min_size=b, max_size=b)),
        jnp.int32,
    )
    out = decode_attention(q, k, v, lens, block_s=block)
    exp = ref.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


def test_decode_attention_masks_garbage_cache():
    # Positions beyond lengths hold garbage; result must ignore them.
    b, h, s, d = 2, 2, 128, 16
    q = rand(16, (b, h, d), jnp.float32)
    k = rand(17, (b, h, s, d), jnp.float32)
    v = rand(18, (b, h, s, d), jnp.float32)
    lens = jnp.asarray([5, 9], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    # Poison the invalid region.
    k2 = k.at[:, :, 10:, :].set(1e9)
    v2 = v.at[:, :, 10:, :].set(-1e9)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_attention_single_valid_position():
    b, h, s, d = 1, 2, 128, 16
    q = rand(19, (b, h, d), jnp.float32)
    k = rand(20, (b, h, s, d), jnp.float32)
    v = rand(21, (b, h, s, d), jnp.float32)
    lens = jnp.asarray([1], jnp.int32)
    out = decode_attention(q, k, v, lens)
    # With one valid position, output == v[:, :, 0, :].
    np.testing.assert_allclose(out, v[:, :, 0, :], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([32, 64]),
    d=st.sampled_from([16, 32]),
    bq=st.sampled_from([8, 16]),
    bkv=st.sampled_from([16, 32]),
    data=st.data(),
)
def test_prefill_attention_matches_ref(b, h, s, d, bq, bkv, data):
    q = rand(22, (b, h, s, d), jnp.float32)
    k = rand(23, (b, h, s, d), jnp.float32)
    v = rand(24, (b, h, s, d), jnp.float32)
    lens = jnp.asarray(
        data.draw(st.lists(st.integers(1, s), min_size=b, max_size=b)),
        jnp.int32,
    )
    out = prefill_attention(q, k, v, lens, block_q=bq, block_kv=bkv)
    exp = ref.prefill_attention(q, k, v, lens)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


def test_prefill_attention_causality():
    # Future tokens must not influence earlier positions: perturb position
    # j and check rows < j unchanged.
    b, h, s, d = 1, 2, 32, 16
    q = rand(25, (b, h, s, d), jnp.float32)
    k = rand(26, (b, h, s, d), jnp.float32)
    v = rand(27, (b, h, s, d), jnp.float32)
    lens = jnp.asarray([s], jnp.int32)
    out1 = prefill_attention(q, k, v, lens)
    j = 20
    k2 = k.at[:, :, j:, :].add(3.0)
    v2 = v.at[:, :, j:, :].add(-2.0)
    out2 = prefill_attention(q, k2, v2, lens)
    np.testing.assert_allclose(
        out1[:, :, :j], out2[:, :, :j], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, :, j:], out2[:, :, j:])


def test_kernels_no_custom_calls_in_hlo():
    """Interpret-mode Pallas must lower to plain HLO (rust CPU PJRT
    cannot run Mosaic custom-calls)."""

    def fn(q, k, v, lens):
        return decode_attention(q, k, v, lens)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((2, 2, 16), jnp.float32),       # q [B,H,D]
        jax.ShapeDtypeStruct((2, 2, 128, 16), jnp.float32),  # k
        jax.ShapeDtypeStruct((2, 2, 128, 16), jnp.float32),  # v
        jax.ShapeDtypeStruct((2,), jnp.int32),
    )
    text = str(lowered.compiler_ir("stablehlo")).lower()
    assert "mosaic" not in text
    assert "tpu_custom_call" not in text
