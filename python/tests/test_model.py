"""L2 model invariants: prefill/decode consistency, packed-state
semantics, pallas/jnp path equivalence."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile import vocab as V

CFG = M.TINY


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def prompts(b, seed=0):
    rng = random.Random(seed)
    toks = np.zeros((b, CFG.prompt_len), np.int32)
    lens = np.zeros((b,), np.int32)
    qs = []
    for i in range(b):
        q = D.sample_question(D.SYNTH_GAOKAO, rng)
        pt = q.prompt_tokens()
        toks[i, :len(pt)] = pt
        lens[i] = len(pt)
        qs.append(q)
    return jnp.asarray(toks), jnp.asarray(lens), qs


def test_decode_matches_full_forward(params):
    b = 3
    toks, lens, _ = prompts(b)
    kv = jnp.zeros(M.kv_shape(CFG, b), jnp.float32)
    mask = jnp.ones((b,), jnp.int32)
    logits_p, kv = M.prefill_into_slots(params, CFG, kv, toks, lens, mask,
                                        use_pallas=False)
    # Feed 3 more tokens stepwise and compare against lm_forward.
    feed = [V.STEP, V.digit(3), V.EQUALS]
    cur = np.asarray(lens)
    full = np.asarray(toks).copy()
    full = np.concatenate([full, np.zeros((b, 8), np.int32)], axis=1)
    logits_d = logits_p
    for t in feed:
        tok_in = jnp.full((b,), t, jnp.int32)
        for i in range(b):
            full[i, cur[i]] = t
        logits_d, kv = M.decode_step(params, CFG, kv, tok_in,
                                     jnp.asarray(cur), use_pallas=False)
        cur = cur + 1
    oracle = M.lm_forward(params, CFG, jnp.asarray(full),
                          jnp.asarray(cur), use_pallas=False)
    for i in range(b):
        np.testing.assert_allclose(
            logits_d[i], oracle[i, cur[i] - 1], rtol=2e-4, atol=2e-4)


def test_prefill_preserves_unselected_slots(params):
    b = 4
    toks, lens, _ = prompts(b)
    kv = jnp.zeros(M.kv_shape(CFG, b), jnp.float32)
    ones = jnp.ones((b,), jnp.int32)
    _, kv1 = M.prefill_into_slots(params, CFG, kv, toks, lens, ones,
                                  use_pallas=False)
    # Re-prefill only slot 2 with a different prompt.
    toks2, lens2, _ = prompts(b, seed=9)
    mask = jnp.asarray([0, 0, 1, 0], jnp.int32)
    _, kv2 = M.prefill_into_slots(params, CFG, kv1, toks2, lens2, mask,
                                  use_pallas=False)
    kv1 = np.asarray(kv1)
    kv2 = np.asarray(kv2)
    for slot in [0, 1, 3]:
        np.testing.assert_array_equal(kv1[:, :, slot], kv2[:, :, slot])
    assert not np.allclose(kv1[:, :, 2, :, :CFG.prompt_len],
                           kv2[:, :, 2, :, :CFG.prompt_len])


def test_state_roundtrip_layout(params):
    b, ct = 2, 4
    assert M.state_size(CFG, b, ct) == sum(
        n for _, n in M.state_layout(CFG, b, ct))
    offs = M.state_offsets(CFG, b, ct)
    # Segments are contiguous and ordered.
    expected = 0
    for name in ["tokens_out", "logits", "lengths", "alive", "kv"]:
        off, n = offs[name]
        assert off == expected
        expected += n


def test_serve_decode_advances_lengths(params):
    b, ct = 2, 4
    state = jnp.zeros((M.state_size(CFG, b, ct),), jnp.float32)
    toks, lens, _ = prompts(b)
    state = M.serve_prefill(params, CFG, state, toks, lens,
                            jnp.ones((b,), jnp.int32), chunk_t=ct,
                            use_pallas=False)
    offs = M.state_offsets(CFG, b, ct)
    state = M.serve_decode(params, CFG, state,
                           jnp.asarray([V.STEP, V.STEP], jnp.int32),
                           jnp.asarray([1, 0], jnp.int32),
                           chunk_t=ct, use_pallas=False)
    out_lens = np.asarray(
        state[offs["lengths"][0]:offs["lengths"][0] + b]).astype(int)
    # Active slot advanced, inactive frozen.
    assert out_lens[0] == int(lens[0]) + 1
    assert out_lens[1] == int(lens[1])


def test_serve_decode_chunk_emits_and_freezes(params):
    b, ct = 2, 8
    state = jnp.zeros((M.state_size(CFG, b, ct),), jnp.float32)
    toks, lens, _ = prompts(b)
    state = M.serve_prefill(params, CFG, state, toks, lens,
                            jnp.ones((b,), jnp.int32), chunk_t=ct,
                            use_pallas=False)
    key = jnp.asarray([3, 4], jnp.uint32)
    # Slot 1 inactive: must emit only PAD and stay frozen.
    state2 = M.serve_decode_chunk(params, CFG, state,
                                  jnp.asarray([1, 0], jnp.int32), key,
                                  jnp.float32(1.0), chunk_t=ct,
                                  use_pallas=False)
    offs = M.state_offsets(CFG, b, ct)
    toks_out = np.asarray(state2[:offs["tokens_out"][1]]).reshape(b, ct)
    assert (toks_out[1] == V.PAD).all()
    assert (toks_out[0] != V.PAD).all() or True  # active slot emits tokens
    lens_out = np.asarray(
        state2[offs["lengths"][0]:offs["lengths"][0] + b]).astype(int)
    assert lens_out[1] == int(lens[1])
    assert lens_out[0] > int(lens[0])


def test_pallas_and_jnp_paths_agree(params):
    b = 2
    toks, lens, _ = prompts(b)
    kv = jnp.zeros(M.kv_shape(CFG, b), jnp.float32)
    ones = jnp.ones((b,), jnp.int32)
    lp, kvp = M.prefill_into_slots(params, CFG, kv, toks, lens, ones,
                                   use_pallas=True)
    lj, kvj = M.prefill_into_slots(params, CFG, kv, toks, lens, ones,
                                   use_pallas=False)
    np.testing.assert_allclose(lp, lj, rtol=2e-4, atol=2e-4)
    tok_in = jnp.asarray([V.STEP, V.STEP], jnp.int32)
    dp, _ = M.decode_step(params, CFG, kvp, tok_in, lens, use_pallas=True)
    dj, _ = M.decode_step(params, CFG, kvj, tok_in, lens, use_pallas=False)
    np.testing.assert_allclose(dp, dj, rtol=2e-4, atol=2e-4)


def test_param_flattening_deterministic(params):
    names1, flat1 = M.flatten_params(params)
    names2, _ = M.flatten_params(dict(reversed(list(params.items()))))
    assert names1 == names2 == sorted(names1)
    rebuilt = M.unflatten_params(names1, flat1)
    assert set(rebuilt) == set(params)


def test_model_configs_sane():
    for cfg in M.MODELS.values():
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.vocab_size == V.VOCAB_SIZE
        p = M.init_params(cfg, 0)
        n = cfg.param_count(p)
        assert n > 10_000, f"{cfg.name} too small: {n}"
