"""PRM model invariants and trainability smoke."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile import prm as P
from compile import train as T


@pytest.fixture(scope="module")
def prm_params():
    return P.init_params(P.PRM_MINI, seed=1)


def test_prm_score_in_unit_interval(prm_params):
    toks = jnp.zeros((3, 256), jnp.int32).at[:, 0].set(1)
    lens = jnp.asarray([1, 10, 256], jnp.int32)
    s = P.prm_score(prm_params, P.PRM_MINI, toks, lens, use_pallas=False)
    assert s.shape == (3,)
    assert ((s >= 0) & (s <= 1)).all()


def test_prm_ignores_padding(prm_params):
    corpus = D.build_corpus(4, seed=0)
    toks = np.asarray(corpus.tokens[:2], np.int32)
    lens = np.asarray(corpus.lengths[:2], np.int32)
    s1 = P.prm_score(prm_params, P.PRM_MINI, jnp.asarray(toks),
                     jnp.asarray(lens), use_pallas=False)
    # Change padding region only — score must be identical.
    toks2 = toks.copy()
    for i in range(2):
        toks2[i, lens[i]:] = 17
    s2 = P.prm_score(prm_params, P.PRM_MINI, jnp.asarray(toks2),
                     jnp.asarray(lens), use_pallas=False)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)


def test_prm_pallas_path_agrees(prm_params):
    corpus = D.build_corpus(4, seed=1)
    toks = jnp.asarray(corpus.tokens[:2], jnp.int32)
    lens = jnp.asarray(corpus.lengths[:2], jnp.int32)
    a = P.prm_score(prm_params, P.PRM_MINI, toks, lens, use_pallas=False)
    b = P.prm_score(prm_params, P.PRM_MINI, toks, lens, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_prm_short_training_improves_auc():
    """A brief PRM training run must beat chance AUC on held-out data."""
    corpus = D.build_corpus(1200, seed=2)
    params = T.train_prm(P.PRM_MINI, corpus, steps=150, bs=32,
                         log=lambda s: None)
    auc = T.prm_auc(params, P.PRM_MINI, corpus, n=400, seed=11)
    assert auc > 0.55, f"PRM AUC barely above chance: {auc}"
