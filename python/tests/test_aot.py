"""AOT export contract tests.

Lowering smoke-tests run always (no artifacts needed); manifest validation
runs against `artifacts/` when present (after `make artifacts`).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import prm as P
from compile import vocab as V

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_state_size_matches_layout():
    for cfg in M.MODELS.values():
        for b in (1, 4):
            total = M.state_size(cfg, b, 16)
            offs = M.state_offsets(cfg, b, 16)
            assert total == offs["kv"][0] + offs["kv"][1]


def test_lower_decode_is_single_output_no_mosaic():
    cfg = M.TINY
    names, _ = M.flatten_params(M.init_params(cfg, 0))
    text = aot.to_hlo_text(aot.lower_decode(cfg, names, 2, 8))
    assert "mosaic" not in text.lower()
    # Single flat f32 output of the packed-state size.
    assert f"f32[{M.state_size(cfg, 2, 8)}]" in text


def test_lower_prm_single_output():
    cfg = P.PRM_MINI
    names, _ = M.flatten_params(P.init_params(cfg, 0))
    text = aot.to_hlo_text(aot.lower_prm(cfg, names, 2, 64))
    assert "f32[2]" in text


def test_params_bin_layout(tmp_path):
    params = M.init_params(M.TINY, 0)
    path = tmp_path / "params.bin"
    entries = aot.export_params_bin(params, str(path))
    names, flat = M.flatten_params(params)
    assert [e["name"] for e in entries] == names
    # Offsets contiguous; blob round-trips.
    blob = path.read_bytes()
    off = 0
    for e, arr in zip(entries, flat):
        assert e["offset_bytes"] == off
        n = e["num_elements"] * 4
        got = np.frombuffer(blob[off:off + n], "<f4").reshape(e["shape"])
        np.testing.assert_array_equal(got, np.asarray(arr, "<f4"))
        off += n
    assert off == len(blob)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_well_formed():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    assert man["models"], "no models exported"
    for name, m in man["models"].items():
        cfg = M.MODELS[name]
        for b_str, size in m["state_sizes"].items():
            assert size == M.state_size(cfg, int(b_str), m["chunk_t"])
        for kind in ("decode", "prefill", "decode_chunk"):
            for rel in m["executables"][kind].values():
                assert os.path.exists(os.path.join(ART, rel)), rel
        bin_path = os.path.join(ART, m["params_bin"])
        expected = sum(p["num_elements"] * 4 for p in m["params"])
        assert os.path.getsize(bin_path) == expected
    for rel in man["prm"]["executables"]["score"].values():
        assert os.path.exists(os.path.join(ART, rel))


@needs_artifacts
def test_tokenizer_json_matches_vocab():
    with open(os.path.join(ART, "tokenizer.json")) as f:
        spec = json.load(f)
    gen = V.tokenizer_spec()
    for key in ("vocab_size", "pad", "bos", "eos", "ans", "step",
                "recheck", "digit_base"):
        assert spec[key] == gen[key]


@needs_artifacts
def test_exported_hlo_has_no_serialized_proto_markers():
    # We ship HLO *text*; make sure files parse as text and mention the
    # expected entry computation.
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    name, m = next(iter(man["models"].items()))
    rel = next(iter(m["executables"]["decode"].values()))
    text = open(os.path.join(ART, rel)).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
