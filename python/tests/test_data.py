"""SynthHop corpus properties: the statistical shape the serving
experiments rely on (Observations 1 & 2 of the paper)."""

import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import vocab as V


def test_question_answer_follows_chain():
    q = D.Question(mapping=tuple((k + 1) % 10 for k in range(10)),
                   start=3, hops=4)
    assert q.answer == 7


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_trajectory_well_formed(seed):
    rng = random.Random(seed)
    spec = D.SYNTH_GPQA if seed % 2 else D.SYNTH_GAOKAO
    q = D.sample_question(spec, rng)
    toks, ans, rechecks = D.sample_trajectory(q, spec, rng)
    assert toks[0] == V.BOS
    assert toks[-1] == V.EOS
    assert toks[-4] == V.ETHINK
    assert toks[-3] == V.ANS
    assert len(toks) <= 256
    assert D.extract_answer(toks) == ans
    assert rechecks >= 0


def test_error_free_trajectories_always_correct():
    import dataclasses
    spec = dataclasses.replace(D.SYNTH_GAOKAO, p_err=0.0)
    rng = random.Random(0)
    for _ in range(100):
        q = D.sample_question(spec, rng)
        _, ans, _ = D.sample_trajectory(q, spec, rng)
        assert ans == q.answer


def test_corpus_weak_length_quality_correlation():
    """Observation 1: correctness ~ independent of length (|r| small)."""
    corpus = D.build_corpus(4000, seed=1)
    lens = np.asarray(corpus.lengths, float)
    correct = (np.asarray(corpus.answers) == np.asarray(corpus.truths))
    r = np.corrcoef(lens, correct.astype(float))[0, 1]
    assert abs(r) < 0.25, f"length/quality correlation too strong: {r}"


def test_corpus_heavy_tail_lengths():
    """Over-thinking: p99 length should far exceed the median."""
    corpus = D.build_corpus(4000, seed=2)
    lens = np.asarray(corpus.lengths, float)
    p50, p99 = np.percentile(lens, [50, 99])
    assert p99 > 2.0 * p50, (p50, p99)


def test_gpqa_harder_than_gaokao():
    g1 = D.build_corpus(2000, specs=(D.SYNTH_GAOKAO,), seed=3)
    g2 = D.build_corpus(2000, specs=(D.SYNTH_GPQA,), seed=3)
    acc1 = np.mean(np.asarray(g1.answers) == np.asarray(g1.truths))
    acc2 = np.mean(np.asarray(g2.answers) == np.asarray(g2.truths))
    assert acc2 < acc1, (acc1, acc2)
    assert np.mean(g2.lengths) > np.mean(g1.lengths)


def test_prompt_fits_bucket():
    rng = random.Random(4)
    for spec in (D.SYNTH_GAOKAO, D.SYNTH_GPQA):
        for _ in range(50):
            q = D.sample_question(spec, rng)
            assert len(q.prompt_tokens()) == 27 <= 32


def test_extract_answer_edge_cases():
    assert D.extract_answer([]) is None
    assert D.extract_answer([V.ANS]) is None
    assert D.extract_answer([V.ANS, V.PLUS]) is None
    assert D.extract_answer([V.ANS, V.digit(3), V.RECHECK,
                             V.ANS, V.digit(5), V.EOS]) == 5


def test_prm_examples_labels_match_truth():
    corpus = D.build_corpus(200, seed=5)
    xs, ls, ys = D.prm_examples(corpus, per_traj=2, seed=5)
    assert len(xs) == len(ls) == len(ys)
    assert set(np.unique(ys)) <= {0.0, 1.0}
    # Both classes present in a 200-trajectory mixed corpus.
    assert 0.0 in ys and 1.0 in ys
    for x, l in zip(xs[:50], ls[:50]):
        assert len(x) == 256
        assert all(t == V.PAD for t in x[l:])
