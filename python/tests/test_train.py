"""Trainer smoke tests: optimization works and short runs reduce loss."""

import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile import train as T


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = T.adamw_init(params)
    import jax
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt = T.adamw_update(params, grads, opt, lr=0.1, wd=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    total = 1000
    warm = float(T.cosine_lr(jnp.asarray(0), total, 1e-3))
    peak = float(T.cosine_lr(jnp.asarray(50), total, 1e-3))
    end = float(T.cosine_lr(jnp.asarray(total - 1), total, 1e-3))
    assert warm < peak
    assert end < 0.05 * peak


def test_lm_loss_masks_padding():
    cfg = M.TINY
    params = M.init_params(cfg, 0)
    corpus = D.build_corpus(8, seed=0)
    toks = jnp.asarray(corpus.tokens[:4], jnp.int32)
    lens = jnp.asarray(corpus.lengths[:4], jnp.int32)
    l1 = T.lm_loss(params, cfg, toks, lens)
    # Corrupt padding — loss must not change.
    toks2 = np.asarray(toks).copy()
    for i in range(4):
        toks2[i, int(lens[i]):] = 19
    l2 = T.lm_loss(params, cfg, jnp.asarray(toks2), lens)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_short_lm_training_reduces_loss():
    cfg = M.TINY
    corpus = D.build_corpus(600, seed=1)
    params0 = M.init_params(cfg, 0)
    toks = jnp.asarray(corpus.tokens[:64], jnp.int32)
    lens = jnp.asarray(corpus.lengths[:64], jnp.int32)
    before = float(T.lm_loss(params0, cfg, toks, lens))
    params = T.train_lm(cfg, corpus, steps=60, bs=16, log=lambda s: None)
    after = float(T.lm_loss(params, cfg, toks, lens))
    assert after < before * 0.8, (before, after)
