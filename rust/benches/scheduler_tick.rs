//! Bench: L3 coordinator overhead per scheduling round.
//!
//! Serves a standing workload on the virtual-time SimEngine, so the
//! measured *wall* time is almost entirely scheduler bookkeeping
//! (fill_batch, round processing, PRM batching, metrics) — the paper's
//! requirement is that coordination is negligible next to decoding.
//!
//! Beyond the per-policy serve benches, the SART scaling section drives
//! 64 / 256 / 512-request runs at 64 slots and reports µs of pure
//! coordination per round: with O(1)-per-round bookkeeping this must stay
//! flat as the lifetime request count grows (the pre-refactor loop's
//! full per-round scans made it grow linearly, i.e. O(R²) per serve).
//!
//! Results land in `BENCH_scheduler.json` (see EXPERIMENTS.md §Benches).
//!
//!     cargo bench --bench scheduler_tick

use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::prm::OraclePrm;
use sart::testkit::bench::{self, BenchReport};
use sart::util::clock::SimClock;
use sart::workload::{poisson_trace, TaskSpec};

fn serve_once(
    policy: Policy,
    n_req: usize,
    rate: f64,
    slots: usize,
    kv_tokens: usize,
) -> (usize, f64) {
    let spec = TaskSpec::synth_gaokao();
    let trace = poisson_trace(&spec, n_req, rate, 42);
    let mut engine = SimEngine::new(slots, 256, spec, SimCostModel::default());
    let mut prm = OraclePrm::new(0.08, 7);
    let cfg = SchedConfig {
        policy,
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(kv_tokens, 16),
        adaptive: None,
        seed: 42,
    };
    let mut sched =
        Scheduler::new(cfg, &mut engine, &mut prm, ClockHandle::Sim(SimClock::new()));
    let res = sched.serve(&trace).unwrap();
    (res.rounds, res.wall_seconds)
}

fn sart() -> Policy {
    Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 }
}

fn main() {
    println!("== scheduler_tick ==");
    let mut report = BenchReport::new("scheduler");
    for (label, policy) in [
        ("vanilla", Policy::Vanilla),
        ("self-consistency N=8", Policy::SelfConsistency { n: 8 }),
        ("sart N=8 M=4", sart()),
    ] {
        report.push(bench::run(&format!("serve 32 reqs ({label})"), 2, 20, || {
            std::hint::black_box(serve_once(policy, 32, 4.0, 16, 16384));
        }));
    }

    // Pure per-round coordination at SART scale: 64 slots, generous KV
    // budget (so queuing does not mask bookkeeping), growing lifetime
    // request counts. µs/round must not grow with the request count.
    println!("-- SART scaling (N=8, 64 slots) --");
    let mut us_per_round = Vec::new();
    for &n_req in &[64usize, 256, 512] {
        let (rounds, wall) = serve_once(sart(), n_req, 16.0, 64, 1 << 20);
        let us = wall / rounds as f64 * 1e6;
        println!(
            "sart {n_req:>4}-request run: {rounds} rounds in {wall:.3}s wall \
             → {us:.1} µs/round of pure coordination"
        );
        report.metric(&format!("sart_{n_req}req_us_per_round"), us);
        report.metric(&format!("sart_{n_req}req_rounds"), rounds as f64);
        us_per_round.push((n_req, us));
    }
    if let (Some(&(_, us64)), Some(&(_, us512))) =
        (us_per_round.first(), us_per_round.last())
    {
        let ratio = us512 / us64;
        println!(
            "scaling ratio (512 vs 64 requests): {ratio:.2}x per-round cost \
             (flat ≈ 1.0 means coordination is independent of lifetime \
             request count)"
        );
        report.metric("us_per_round_ratio_512_vs_64", ratio);
    }
    report.write().expect("writing BENCH_scheduler.json");
}
