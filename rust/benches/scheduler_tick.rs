//! Bench: L3 coordinator overhead per scheduling round.
//!
//! Serves a standing workload on the virtual-time SimEngine, so the
//! measured *wall* time is almost entirely scheduler bookkeeping
//! (fill_batch, round processing, PRM batching, metrics) — the paper's
//! requirement is that coordination is negligible next to decoding.
//!
//!     cargo bench --bench scheduler_tick

use sart::coordinator::{ClockHandle, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::prm::OraclePrm;
use sart::testkit::bench;
use sart::util::clock::SimClock;
use sart::workload::{poisson_trace, TaskSpec};

fn serve_once(policy: Policy, n_req: usize, slots: usize) -> (usize, f64) {
    let spec = TaskSpec::synth_gaokao();
    let trace = poisson_trace(&spec, n_req, 4.0, 42);
    let mut engine = SimEngine::new(slots, 256, spec, SimCostModel::default());
    let mut prm = OraclePrm::new(0.08, 7);
    let cfg = SchedConfig {
        policy,
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv_capacity_tokens: 16384,
        kv_page_tokens: 16,
        seed: 42,
    };
    let mut sched =
        Scheduler::new(cfg, &mut engine, &mut prm, ClockHandle::Sim(SimClock::new()));
    let res = sched.serve(&trace).unwrap();
    (res.rounds, res.wall_seconds)
}

fn main() {
    println!("== scheduler_tick ==");
    for (label, policy) in [
        ("vanilla", Policy::Vanilla),
        ("self-consistency N=8", Policy::SelfConsistency { n: 8 }),
        ("sart N=8 M=4", Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 }),
    ] {
        bench::run(&format!("serve 32 reqs ({label})"), 2, 20, || {
            std::hint::black_box(serve_once(policy, 32, 16));
        });
    }
    // Per-round cost (the tick): rounds/sec from one big run.
    let (rounds, wall) = serve_once(
        Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
        256,
        16,
    );
    println!(
        "sart 256-request run: {rounds} rounds in {wall:.3}s wall → \
         {:.1} µs/round of pure coordination",
        wall / rounds as f64 * 1e6
    );
}
