//! Bench: chunked prefill with decode-overlap scheduling.
//!
//! Serves a prefix-heavy trace with *long cold few-shot headers* (six
//! distinct 5-shot templates, no prefix cache — every header misses) under
//! a token-priced prefill cost model, once monolithically and once with
//! chunked prefill, and records `BENCH_chunked.json` (schema in
//! EXPERIMENTS.md §Benches; gated by `tools/check_bench.py`).
//!
//! The question the paper's batching story needs answered: when a cold
//! ~270-token prompt is admitted into a busy batch, how long do the
//! resident decoding branches stall? Monolithic prefill charges the whole
//! header to one round; chunked prefill bounds each round's prefill work
//! by the token budget, so the stall tail collapses while total work only
//! grows by the per-chunk dispatch overhead.
//!
//! Headline (CI-enforced): `p99_decode_stall_ratio_chunked_vs_mono < 1.0`
//! — the p99 of per-round decode stall (prefill seconds absorbed by
//! rounds that had resident branches) must be strictly lower chunked.
//!
//!     cargo bench --bench chunked_prefill

use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::prm::OraclePrm;
use sart::testkit::bench::{self, BenchReport};
use sart::util::clock::SimClock;
use sart::util::stats::percentile;
use sart::workload::{templated_trace, TaskSpec};

const SLOTS: usize = 8;
const KV_TOKENS: usize = 32768;
const N_REQUESTS: usize = 96;
const RATE: f64 = 3.0;
const SEED: u64 = 47;
const CHUNK: usize = 32;
const BUDGET: usize = 32;

fn spec() -> TaskSpec {
    TaskSpec::synth_gaokao()
}

fn cost_model() -> SimCostModel {
    // Token-priced prefill (same calibration as the prefix bench): a
    // 5-shot header costs ~0.05s of prefill, comparable to a decode
    // round — exactly the regime where monolithic admission stalls the
    // batch.
    SimCostModel { prefill_per_token: 0.2e-3, ..SimCostModel::default() }
}

fn serve(chunk: usize, budget: usize) -> sart::coordinator::ServeResult {
    // 5-shot gaokao headers reach ~240 tokens + the 27-token question,
    // so the prompt bucket must exceed the 256 default.
    let mut engine = SimEngine::new(SLOTS, 560, spec(), cost_model());
    engine.set_prompt_bucket(288);
    let mut prm = OraclePrm::new(0.08, SEED ^ 7);
    let cfg = SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16)
            .with_chunked_prefill(chunk, budget),
        adaptive: None,
        seed: SEED,
    };
    let trace = templated_trace(&spec(), N_REQUESTS, RATE, SEED, 1.0, 6, 5);
    let mut sched = Scheduler::new(
        cfg,
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.serve(&trace).expect("chunked bench serve")
}

fn makespan(res: &sart::coordinator::ServeResult) -> f64 {
    res.outcomes
        .iter()
        .map(|o| o.finished_at)
        .fold(0.0f64, f64::max)
}

fn mean_ttft(res: &sart::coordinator::ServeResult) -> f64 {
    res.outcomes.iter().map(|o| o.ttft()).sum::<f64>()
        / res.outcomes.len().max(1) as f64
}

fn main() {
    println!(
        "== chunked_prefill ({SLOTS} slots, {N_REQUESTS} requests, \
         6 cold 5-shot templates, chunk {CHUNK} / budget {BUDGET}) =="
    );
    let mut report = BenchReport::new("chunked");

    let mono = serve(0, 0);
    let chunked = serve(CHUNK, BUDGET);
    assert_eq!(mono.outcomes.len(), N_REQUESTS);
    assert_eq!(chunked.outcomes.len(), N_REQUESTS);

    // The stall definition lives in Timeline::decode_stall_series — the
    // same code path the regression tests assert against.
    let stalls_mono = mono.timeline.decode_stall_series();
    let stalls_chunked = chunked.timeline.decode_stall_series();
    let p99_mono = percentile(&stalls_mono, 99.0);
    let p99_chunked = percentile(&stalls_chunked, 99.0);
    let max_mono = stalls_mono.iter().cloned().fold(0.0f64, f64::max);
    let max_chunked = stalls_chunked.iter().cloned().fold(0.0f64, f64::max);
    let ratio = p99_chunked / p99_mono.max(1e-12);
    println!(
        "decode stall per round: p99 mono {:.2}ms vs chunked {:.2}ms \
         (ratio {ratio:.3}, must stay < 1.0); worst round {:.2}ms vs {:.2}ms",
        1e3 * p99_mono,
        1e3 * p99_chunked,
        1e3 * max_mono,
        1e3 * max_chunked,
    );
    report.metric("p99_decode_stall_s_mono", p99_mono);
    report.metric("p99_decode_stall_s_chunked", p99_chunked);
    report.metric("p99_decode_stall_ratio_chunked_vs_mono", ratio);
    report.metric("max_decode_stall_s_mono", max_mono);
    report.metric("max_decode_stall_s_chunked", max_chunked);

    // Chunking is not free: each chunk re-pays the dispatch overhead, so
    // makespan may give a little back. Record the trade so regressions
    // in either direction are visible in the artifact trail.
    let thru_ratio = makespan(&mono) / makespan(&chunked).max(1e-9);
    let ttft_mono = mean_ttft(&mono);
    let ttft_chunked = mean_ttft(&chunked);
    println!(
        "throughput chunked/mono {thru_ratio:.3}; \
         mean ttft mono {ttft_mono:.3}s vs chunked {ttft_chunked:.3}s"
    );
    report.metric("throughput_ratio_chunked_vs_mono", thru_ratio);
    report.metric("mean_ttft_s_mono", ttft_mono);
    report.metric("mean_ttft_s_chunked", ttft_chunked);
    let peak_backlog = chunked
        .timeline
        .points
        .iter()
        .map(|p| p.queued_prefill_tokens)
        .max()
        .unwrap_or(0);
    report.metric("peak_queued_prefill_tokens", peak_backlog as f64);

    // Coordination wall cost of the two paths (virtual-time serves do no
    // real compute, so this times the scheduler + chunk bookkeeping).
    report.push(bench::run(
        &format!("serve {N_REQUESTS} reqs monolithic"),
        1,
        5,
        || {
            std::hint::black_box(serve(0, 0));
        },
    ));
    report.push(bench::run(
        &format!("serve {N_REQUESTS} reqs chunked ({CHUNK}/{BUDGET})"),
        1,
        5,
        || {
            std::hint::black_box(serve(CHUNK, BUDGET));
        },
    ));

    report.write().expect("writing BENCH_chunked.json");
}
