//! Bench: adaptive per-request test-time compute vs the static SART
//! configuration on a mixed easy/hard workload.
//!
//! The trace interleaves easy (synth-gaokao, 3-5 hop) and hard
//! (synth-gpqa, 5-8 hop) questions. The static serve spends N = 4
//! branches on every request; the adaptive serve learns online that the
//! easy dataset finishes short with high first-round rewards and routes
//! its later arrivals to the 1-branch no-think fast path, prunes
//! agreeing branch sets down to 2, and tightens the per-branch cap on
//! requests in the over-thinking tail — same trace, same seed, same
//! engine substrate.
//!
//! Recorded in `BENCH_adaptive.json` (schema in EXPERIMENTS.md §Reading
//! BENCH_adaptive.json), gated by `tools/check_bench.py`:
//!
//! * `adaptive_requests_lost` / `baseline_requests_lost` — must be 0.
//! * `adaptive_vs_static_tokens_ratio` — tokens per request, adaptive /
//!   static. Must stay < 1.0: adapting may never cost tokens.
//! * `adaptive_vs_static_accuracy_delta` — adaptive accuracy minus
//!   static accuracy. Must stay >= -0.05: the savings may not buy more
//!   than a marginal accuracy dip.
//! * `adaptive_fast_path_share` — fraction of requests routed to the
//!   fast path. Must be > 0 on the mixed workload: the easy traffic
//!   exists and the classifier must find it.
//!
//!     cargo bench --bench adaptive_policy

use sart::coordinator::{
    AdaptiveConfig, ClockHandle, KvConfig, Policy, SchedConfig, Scheduler,
    ServeResult,
};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::metrics::ServeReport;
use sart::prm::OraclePrm;
use sart::testkit::bench::{self, BenchReport};
use sart::util::clock::SimClock;
use sart::workload::{mixed_trace, TaskSpec};

const SLOTS: usize = 8;
const KV_TOKENS: usize = 32768;
const SEED: u64 = 31;
const N_REQUESTS: usize = 128;
const RATE: f64 = 4.0;
const HARD_SHARE: f64 = 0.5;

fn adaptive_cfg() -> AdaptiveConfig {
    AdaptiveConfig {
        // OraclePrm noise is sigma 0.08: a 0.15 band separates "all
        // branches agree" from genuine reward dispersion.
        spread_tol: 0.15,
        prune_keep: 2,
        tail_pct: 90.0,
        // 2x the observed mean/tail keeps honest chains unclipped; only
        // the over-thinking outliers hit the tightened cap.
        cap_slack: 2.0,
        min_samples: 8,
        fast_reward: 0.55,
        fast_len: 64.0,
    }
}

fn serve(adaptive: Option<AdaptiveConfig>) -> ServeResult {
    let trace = mixed_trace(
        &TaskSpec::synth_gaokao(),
        &TaskSpec::synth_gpqa(),
        N_REQUESTS,
        RATE,
        SEED,
        HARD_SHARE,
    );
    let mut engine = SimEngine::new(
        SLOTS,
        256,
        TaskSpec::synth_gaokao(),
        SimCostModel::default(),
    );
    let mut prm = OraclePrm::new(0.08, SEED ^ 7);
    let cfg = SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16),
        adaptive,
        seed: SEED,
    };
    let mut sched = Scheduler::new(
        cfg,
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.serve(&trace).expect("adaptive bench serve")
}

fn makespan(res: &ServeResult) -> f64 {
    res.outcomes.iter().map(|o| o.finished_at).fold(0.0f64, f64::max)
}

fn main() {
    println!(
        "== adaptive_policy ({SLOTS} slots, {N_REQUESTS} requests, \
         hard share {HARD_SHARE}) =="
    );
    let mut report = BenchReport::new("adaptive");

    let base = serve(None);
    let adapted = serve(Some(adaptive_cfg()));

    let base_lost = N_REQUESTS - base.outcomes.len();
    let adaptive_lost = N_REQUESTS - adapted.outcomes.len();
    assert_eq!(adaptive_lost, 0, "adaptive serve dropped requests");
    assert_eq!(base_lost, 0, "static serve dropped requests");

    let base_report = ServeReport::from_outcomes("static", &base.outcomes);
    let adapt_report =
        ServeReport::from_outcomes("adaptive", &adapted.outcomes);

    let tokens_ratio =
        adapt_report.tokens_per_request / base_report.tokens_per_request;
    let accuracy_delta = adapt_report.accuracy - base_report.accuracy;
    let stats = &adapted.adaptive;
    let fast_share = stats.fast_path_requests as f64 / N_REQUESTS as f64;

    assert!(
        tokens_ratio < 1.0,
        "adaptive must cut tokens per request: ratio {tokens_ratio:.3} \
         ({:.1} vs {:.1})",
        adapt_report.tokens_per_request,
        base_report.tokens_per_request
    );
    assert!(
        accuracy_delta >= -0.05,
        "adaptive accuracy fell too far: {:.3} vs {:.3}",
        adapt_report.accuracy,
        base_report.accuracy
    );
    assert!(
        fast_share > 0.0,
        "the mixed workload classified no dataset easy"
    );

    println!(
        "tokens/req adaptive {:.1} vs static {:.1} (ratio {tokens_ratio:.3}, \
         must stay < 1.0)",
        adapt_report.tokens_per_request, base_report.tokens_per_request
    );
    println!(
        "accuracy adaptive {:.3} vs static {:.3} (delta {accuracy_delta:+.3}, \
         must stay >= -0.05)",
        adapt_report.accuracy, base_report.accuracy
    );
    println!(
        "decisions: {} fast-path ({:.0}% of requests), {} spread-pruned \
         branches, {} caps tightened, {} static fallbacks",
        stats.fast_path_requests,
        100.0 * fast_share,
        stats.spread_pruned_branches,
        stats.cap_tightened_requests,
        stats.static_fallbacks,
    );

    report.metric("adaptive_requests_lost", adaptive_lost as f64);
    report.metric("baseline_requests_lost", base_lost as f64);
    report.metric("adaptive_vs_static_tokens_ratio", tokens_ratio);
    report.metric("adaptive_vs_static_accuracy_delta", accuracy_delta);
    report.metric("adaptive_fast_path_share", fast_share);
    report.metric("adaptive_accuracy", adapt_report.accuracy);
    report.metric("baseline_accuracy", base_report.accuracy);
    report.metric(
        "adaptive_tokens_per_request",
        adapt_report.tokens_per_request,
    );
    report.metric(
        "baseline_tokens_per_request",
        base_report.tokens_per_request,
    );
    report.metric(
        "adaptive_spread_pruned_branches",
        stats.spread_pruned_branches as f64,
    );
    report.metric(
        "adaptive_cap_tightened_requests",
        stats.cap_tightened_requests as f64,
    );
    report.metric("adaptive_static_fallbacks", stats.static_fallbacks as f64);
    report.metric("adaptive_makespan_seconds", makespan(&adapted));
    report.metric("baseline_makespan_seconds", makespan(&base));

    report.push(bench::run("serve 128 mixed reqs static sart:4", 1, 5, || {
        std::hint::black_box(serve(None));
    }));
    report.push(bench::run("serve 128 mixed reqs adaptive", 1, 5, || {
        std::hint::black_box(serve(Some(adaptive_cfg())));
    }));

    report.write().expect("writing BENCH_adaptive.json");
}
