//! Bench: the cross-request radix prefix cache, end to end.
//!
//! Three questions, answered on a deterministic prefix-heavy workload
//! (shared few-shot headers + per-request questions, `workload::
//! templated_trace`) and recorded in `BENCH_prefix.json` (schema in
//! EXPERIMENTS.md §Benches):
//!
//! 1. **How many prefill tokens does the cache save?**
//!    `prefill_tokens_saved_frac` = cache-covered prompt tokens / total
//!    prompt tokens over a single-replica serve. CI fails the bench-smoke
//!    job if this is ≤ 0 on the prefix-heavy config; the design target
//!    is > 0.3.
//! 2. **Does saving them buy throughput?** `hit_vs_cold_throughput_ratio`
//!    compares makespan-derived throughput of the same trace served with
//!    the cache on vs off (cache capacity 0 = the pre-cache path).
//! 3. **Does cache-affinity routing keep hits at cluster scale?** At
//!    R = 4 replicas with more templates than any single cache budget
//!    holds, `cache_hit_rate_aff` vs `cache_hit_rate_p2c`: p2c scatters
//!    each template across all replicas (every replica churns through
//!    every header), while prefix-affinity pins templates where their
//!    pages already live. The headline `aff_vs_p2c_hit_rate_delta` must
//!    stay > 0.
//!
//! The kv-level micro rows time warm/cold monolithic admission against the
//! scalar `admit` baseline.
//!
//!     cargo bench --bench prefix_cache

use sart::cluster::{serve_cluster, ClusterConfig, LbPolicy};
use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::kvcache::{AdmissionRequest, KvCacheManager};
use sart::prm::{OraclePrm, PrmScorer};
use sart::testkit::bench::{self, BenchReport};
use sart::util::clock::SimClock;
use sart::workload::{templated_trace, Request, TaskSpec};

const SLOTS: usize = 8;
const KV_TOKENS: usize = 32768;
const CACHE_PAGES: usize = 64;
const SEED: u64 = 42;
const N_REQUESTS: usize = 96;
const RATE: f64 = 4.0;

fn spec() -> TaskSpec {
    TaskSpec::synth_gaokao()
}

fn cost_model() -> SimCostModel {
    // Emphasize the per-token prefill component so the time win (not
    // just the token win) is visible above decode costs.
    SimCostModel { prefill_per_token: 0.2e-3, ..SimCostModel::default() }
}

fn sched_cfg(prefix_cache_pages: usize) -> SchedConfig {
    SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16)
            .with_prefix_cache(prefix_cache_pages),
        adaptive: None,
        seed: SEED,
    }
}

fn engine() -> SimEngine {
    let mut e = SimEngine::new(SLOTS, 512, spec(), cost_model());
    e.set_prompt_bucket(256);
    e
}

fn serve_single(
    trace: &[Request],
    prefix_cache_pages: usize,
) -> sart::coordinator::ServeResult {
    let mut eng = engine();
    let mut prm = OraclePrm::new(0.08, SEED ^ 7);
    let mut sched = Scheduler::new(
        sched_cfg(prefix_cache_pages),
        &mut eng,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.serve(trace).expect("prefix serve")
}

fn makespan(res: &sart::coordinator::ServeResult) -> f64 {
    res.outcomes
        .iter()
        .map(|o| o.finished_at)
        .fold(0.0f64, f64::max)
}

fn cluster_hit_rate(
    trace: &[Request],
    lb: LbPolicy,
    replicas: usize,
    cache_pages: usize,
) -> f64 {
    let mut engines: Vec<Box<dyn Engine>> = (0..replicas)
        .map(|_| Box::new(engine()) as Box<dyn Engine>)
        .collect();
    let mut prms: Vec<Box<dyn PrmScorer>> = (0..replicas)
        .map(|i| {
            Box::new(OraclePrm::new(0.08, SEED ^ 7 ^ ((i as u64) << 32)))
                as Box<dyn PrmScorer>
        })
        .collect();
    let cfg = ClusterConfig {
        replicas,
        lb,
        sched: sched_cfg(cache_pages),
        seed: SEED,
        audit: false,
        gossip_rounds: 0,
        gossip_adapt: false,
        fault_plan: Default::default(),
        scale: None,
    };
    let res = serve_cluster(&cfg, &mut engines, &mut prms, trace)
        .expect("cluster serve");
    res.cache_hit_rate()
}

fn main() {
    println!(
        "== prefix_cache ({SLOTS} slots, {N_REQUESTS} requests, \
         cache {CACHE_PAGES} pages) =="
    );
    let mut report = BenchReport::new("prefix");

    // ---- 1 + 2: single replica, one hot template --------------------
    let trace = templated_trace(&spec(), N_REQUESTS, RATE, SEED, 0.9, 2, 3);
    let warm = serve_single(&trace, CACHE_PAGES);
    let cold = serve_single(&trace, 0);
    let saved_frac = warm.cache_hit_tokens as f64 / warm.prompt_tokens as f64;
    let thru_warm = N_REQUESTS as f64 / makespan(&warm).max(1e-9);
    let thru_cold = N_REQUESTS as f64 / makespan(&cold).max(1e-9);
    let thru_ratio = thru_warm / thru_cold;
    assert_eq!(
        cold.cache_hit_tokens, 0,
        "cache capacity 0 must never report hits"
    );
    println!(
        "single replica: {}/{} prompt tokens from cache \
         (saved_frac {saved_frac:.3}, target > 0.3)",
        warm.cache_hit_tokens, warm.prompt_tokens
    );
    println!(
        "throughput: warm {thru_warm:.2} req/s vs cold {thru_cold:.2} req/s \
         → ratio {thru_ratio:.3}"
    );
    report.metric("prefill_tokens_saved_frac", saved_frac);
    report.metric("hit_vs_cold_throughput_ratio", thru_ratio);
    report.metric("cache_hit_tokens", warm.cache_hit_tokens as f64);
    report.metric("prompt_tokens_total", warm.prompt_tokens as f64);

    report.push(bench::run("serve 96 reqs warm (cache 64 pages)", 1, 5, || {
        std::hint::black_box(serve_single(&trace, CACHE_PAGES));
    }));
    report.push(bench::run("serve 96 reqs cold (cache off)", 1, 5, || {
        std::hint::black_box(serve_single(&trace, 0));
    }));

    // ---- 3: affinity vs p2c at R = 4 --------------------------------
    // 4 templates and a per-replica budget (24 pages ≈ 2.5 templates)
    // that cannot hold all of them: scattering templates across replicas
    // (p2c) churns every cache, affinity pins each template.
    let replicas = 4;
    let small_cache = 24;
    let ctrace =
        templated_trace(&spec(), 2 * N_REQUESTS, 2.0 * RATE, SEED, 0.85, 4, 3);
    let hit_aff = cluster_hit_rate(
        &ctrace,
        LbPolicy::PrefixAffinity,
        replicas,
        small_cache,
    );
    let hit_p2c = cluster_hit_rate(
        &ctrace,
        LbPolicy::PowerOfTwoChoices,
        replicas,
        small_cache,
    );
    let delta = hit_aff - hit_p2c;
    println!(
        "R={replicas}: cache-hit rate prefix-affinity {hit_aff:.3} vs \
         p2c {hit_p2c:.3} (delta {delta:+.3}, must stay > 0)"
    );
    report.metric("cache_hit_rate_aff", hit_aff);
    report.metric("cache_hit_rate_p2c", hit_p2c);
    report.metric("aff_vs_p2c_hit_rate_delta", delta);

    // ---- kv-level micro rows ----------------------------------------
    let header: Vec<i32> = (1000..1000 + 128).collect();
    let mut kv = KvCacheManager::with_prefix_cache(KV_TOKENS, 16, CACHE_PAGES);
    // Warm the tree once so the timed admissions hit.
    let seed_adm = kv
        .admit(&AdmissionRequest::monolithic(&header, 32, 1))
        .unwrap()
        .into_admission()
        .unwrap();
    for b in seed_adm.branches {
        kv.release_branch(b).unwrap();
    }
    report.push(bench::run("monolithic admit warm (8-page hit)", 100, 5000, || {
        let adm = kv
            .admit(&AdmissionRequest::monolithic(&header, 32, 1))
            .unwrap()
            .into_admission()
            .unwrap();
        std::hint::black_box(adm.cached_tokens);
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
    }));
    let mut cold_kv = KvCacheManager::new(KV_TOKENS, 16);
    report.push(bench::run("monolithic admit baseline (cache off)", 100, 5000, || {
        let adm = cold_kv
            .admit(&AdmissionRequest::monolithic(&header, 32, 1))
            .unwrap()
            .into_admission()
            .unwrap();
        for b in adm.branches {
            cold_kv.release_branch(b).unwrap();
        }
    }));

    report.write().expect("writing BENCH_prefix.json");
}
