//! Bench: engine hot paths.
//!
//! Two sections, both serialized into `BENCH_engine.json`:
//!
//! * **sim** (always runs): SimEngine prefill + chunked decode — the
//!   substrate of every full-scale figure sweep. Decode must be a slice
//!   copy per slot per round, not per-token queue pops.
//! * **hlo** (requires `make artifacts`; skips gracefully): prefill,
//!   fused-chunk decode, stepwise decode, PRM scoring — the L1/L2/runtime
//!   measurement used in EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench engine_step

use sart::engine::hlo::{DecodeMode, HloEngine};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::{ChunkResult, Engine, PrefillEntry};
use sart::prm::{HloPrm, PrmScorer};
use sart::runtime::{Manifest, Runtime};
use sart::testkit::bench::{self, BenchReport};
use sart::util::rng::Rng;
use sart::workload::{Question, TaskSpec};

fn sim_section(report: &mut BenchReport) {
    println!("-- sim --");
    let spec = TaskSpec::synth_gaokao();
    let mut rng = Rng::new(11);
    for &batch in &[8usize, 64] {
        let mut eng =
            SimEngine::new(batch, 256, spec.clone(), SimCostModel::default());
        let entries: Vec<PrefillEntry> = (0..batch)
            .map(|s| PrefillEntry {
                slot: s,
                prompt: Question::sample(&spec, &mut rng).prompt_tokens(),
                seed: s as u64,
                cached_tokens: 0,
            })
            .collect();
        let slots: Vec<usize> = (0..batch).collect();
        report.push(bench::run_result(
            &format!("sim prefill b{batch}"),
            2,
            200,
            || eng.prefill(&entries).map(|_| ()),
        ));
        // Chunked decode with the reused emit buffers. Slots are
        // re-prefilled before scripts exhaust so every timed round does
        // real work — the prefill happens OUTSIDE the timed region so the
        // recorded stats are pure decode (run_timed).
        eng.prefill(&entries).unwrap();
        let mut out = ChunkResult::default();
        let mut rounds = 0usize;
        report.push(bench::run_timed(
            &format!("sim decode 16-step round b{batch}"),
            2,
            500,
            || {
                rounds += 1;
                if rounds % 4 == 0 {
                    eng.prefill(&entries).expect("re-prefill");
                }
                let t0 = std::time::Instant::now();
                eng.decode_into(&slots, 16, 1.0, &mut out).expect("decode");
                t0.elapsed().as_secs_f64() * 1e6
            },
        ));
    }
}

fn hlo_section(report: &mut BenchReport) {
    let dir = sart::runtime::artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("-- hlo: SKIPPED (no artifacts: {e}) --");
            return;
        }
    };
    let model = std::env::var("SART_BENCH_MODEL")
        .unwrap_or_else(|_| "r1mini-tiny".into());
    println!("-- hlo ({model}) --");
    let spec = TaskSpec::synth_gaokao();
    let mut rng = Rng::new(1);

    for &batch in &[1usize, 8] {
        for (mode_label, mode) in
            [("fused", DecodeMode::Fused), ("stepwise", DecodeMode::Stepwise)]
        {
            let rt = Runtime::cpu().unwrap();
            let mut eng =
                HloEngine::load(rt, &manifest, &model, batch, mode, 7).unwrap();
            let entries: Vec<PrefillEntry> = (0..batch)
                .map(|s| PrefillEntry {
                    slot: s,
                    prompt: Question::sample(&spec, &mut rng).prompt_tokens(),
                    seed: s as u64,
                    cached_tokens: 0,
                })
                .collect();
            let slots: Vec<usize> = (0..batch).collect();
            report.push(bench::run_result(
                &format!("prefill b{batch}"),
                2,
                20,
                || eng.prefill(&entries).map(|_| ()),
            ));
            let chunk = eng.caps().chunk_t;
            // Re-prefill between rounds so lengths never overflow max_seq.
            let mut rounds = 0usize;
            report.push(bench::run_result(
                &format!("decode {chunk}-step round b{batch} ({mode_label})"),
                2,
                30,
                || {
                    rounds += 1;
                    if rounds % 8 == 0 {
                        eng.prefill(&entries)?;
                    }
                    eng.decode(&slots, chunk, 1.0).map(|_| ())
                },
            ));
        }
    }

    // PRM scoring batch.
    let rt = Runtime::cpu().unwrap();
    let mut prm = HloPrm::load(rt, &manifest, 8).unwrap();
    let seqs: Vec<Vec<i32>> = (0..8)
        .map(|i| {
            let mut r = Rng::new(i);
            let q = Question::sample(&spec, &mut r);
            let mut s = q.prompt_tokens();
            s.extend(sart::workload::sample_response(&q, &spec, &mut r, 256));
            s
        })
        .collect();
    let refs: Vec<&[i32]> = seqs.iter().map(|s| s.as_slice()).collect();
    report.push(bench::run_result("prm score batch of 8", 2, 20, || {
        prm.score(&refs).map(|_| ())
    }));
}

fn main() {
    println!("== engine_step ==");
    let mut report = BenchReport::new("engine");
    sim_section(&mut report);
    hlo_section(&mut report);
    report.write().expect("writing BENCH_engine.json");
}
