//! Bench: HLO engine hot path — prefill, fused-chunk decode, stepwise
//! decode, PRM scoring (requires `make artifacts`; skips gracefully).
//!
//! This is the L1/L2/runtime measurement used in EXPERIMENTS.md §Perf:
//! per-token decode latency of the fused path vs the stepwise baseline.
//!
//!     cargo bench --bench engine_step

use sart::engine::hlo::{DecodeMode, HloEngine};
use sart::engine::{Engine, PrefillEntry};
use sart::prm::{HloPrm, PrmScorer};
use sart::runtime::{Manifest, Runtime};
use sart::testkit::bench;
use sart::util::rng::Rng;
use sart::workload::{Question, TaskSpec};

fn main() {
    let dir = sart::runtime::artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("== engine_step: SKIPPED (no artifacts: {e}) ==");
            return;
        }
    };
    let model = std::env::var("SART_BENCH_MODEL")
        .unwrap_or_else(|_| "r1mini-tiny".into());
    println!("== engine_step ({model}) ==");
    let spec = TaskSpec::synth_gaokao();
    let mut rng = Rng::new(1);

    for &batch in &[1usize, 8] {
        for (mode_label, mode) in
            [("fused", DecodeMode::Fused), ("stepwise", DecodeMode::Stepwise)]
        {
            let rt = Runtime::cpu().unwrap();
            let mut eng =
                HloEngine::load(rt, &manifest, &model, batch, mode, 7).unwrap();
            let entries: Vec<PrefillEntry> = (0..batch)
                .map(|s| PrefillEntry {
                    slot: s,
                    prompt: Question::sample(&spec, &mut rng).prompt_tokens(),
                    seed: s as u64,
                })
                .collect();
            let slots: Vec<usize> = (0..batch).collect();
            bench::run_result(
                &format!("prefill b{batch}"),
                2,
                20,
                || eng.prefill(&entries).map(|_| ()),
            );
            let chunk = eng.caps().chunk_t;
            // Re-prefill between rounds so lengths never overflow max_seq.
            let mut rounds = 0usize;
            bench::run_result(
                &format!("decode {chunk}-step round b{batch} ({mode_label})"),
                2,
                30,
                || {
                    rounds += 1;
                    if rounds % 8 == 0 {
                        eng.prefill(&entries)?;
                    }
                    eng.decode(&slots, chunk, 1.0).map(|_| ())
                },
            );
        }
    }

    // PRM scoring batch.
    let rt = Runtime::cpu().unwrap();
    let mut prm = HloPrm::load(rt, &manifest, 8).unwrap();
    let seqs: Vec<Vec<i32>> = (0..8)
        .map(|i| {
            let mut r = Rng::new(i);
            let q = Question::sample(&spec, &mut r);
            let mut s = q.prompt_tokens();
            s.extend(sart::workload::sample_response(&q, &spec, &mut r, 256));
            s
        })
        .collect();
    let refs: Vec<&[i32]> = seqs.iter().map(|s| s.as_slice()).collect();
    bench::run_result("prm score batch of 8", 2, 20, || {
        prm.score(&refs).map(|_| ())
    });
}
