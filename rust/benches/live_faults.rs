//! Bench: what does a replica failure cost on the *wall-clock* path?
//!
//! Replays one trace through a loopback `sart listen` + `sart replay`
//! pair twice — fault-free, and with replica 1 killed a third of the way
//! into the arrivals and restarted at the two-thirds mark — with the
//! client's resilience layer armed (`--retry-max 3`). Records, in
//! `BENCH_live_faults.json` (schema in EXPERIMENTS.md §Benches):
//!
//! 1. **Is the live failure loss-free?** `live_faults_requests_lost`
//!    must be exactly 0 (`tools/check_bench.py` gates it): every session
//!    on the dead replica is re-dispatched to a survivor *without its
//!    socket closing* and streams to its single `finalized` line.
//! 2. **Did the fault actually bite?** `live_faults_migrated_sessions`
//!    (sessions that saw a `migrated` event) is gated >= 1 — a plan that
//!    fires into an idle replica would make the loss-free gate vacuous.
//! 3. **What does the detour cost in wall time?**
//!    `live_faulted_vs_clean_p99_ratio` = faulted p99 wall e2e over the
//!    clean run's, gated < 10: survivors absorb the dead replica's load
//!    and re-prefill its lost KV state, stretching but not exploding
//!    the tail.
//! 4. **Client-side tallies** ride along: `live_faults_retries` and
//!    `live_faults_rejected` size how much the resilience layer worked.
//!
//!     cargo bench --bench live_faults

use sart::config::{Args, LiveConfig, ReplayConfig, ServeSpec};
use sart::frontend;
use sart::testkit::bench::{self, BenchReport};
use sart::util::stats::percentile;
use std::time::Instant;

const N_REQUESTS: usize = 60;
const RATE: f64 = 6.0;
const REPLICAS: usize = 3;
const TIME_SCALE: f64 = 0.01;

fn spec(fault_plan: &str) -> ServeSpec {
    let plan = if fault_plan.is_empty() {
        String::new()
    } else {
        format!("--fault-plan {fault_plan}")
    };
    let args = Args::parse(
        format!(
            "--method sart:4 --requests {N_REQUESTS} --rate {RATE} \
             --replicas {REPLICAS} --kv-tokens 8192 --seed 42 {plan}"
        )
        .split_whitespace()
        .map(String::from),
    )
    .expect("bench args");
    ServeSpec::from_args(&args).expect("bench spec")
}

fn run_live(spec: &ServeSpec) -> (frontend::ReplayResult, f64) {
    let trace = sart::server::trace_for(spec).expect("bench trace");
    let live = LiveConfig {
        addr: "127.0.0.1:0".into(),
        time_scale: TIME_SCALE,
        max_sessions: 256,
    };
    let cfg = ReplayConfig {
        retry_max: 3,
        retry_base_ms: 25,
        session_deadline_s: 0.0,
        seed: 42,
    };
    let handle = frontend::listen(spec, &live).expect("loopback listener");
    let addr = handle.addr().to_string();
    let t0 = Instant::now();
    let res = frontend::replay_with(&addr, &trace, TIME_SCALE, true, &cfg)
        .expect("loopback replay");
    let wall_s = t0.elapsed().as_secs_f64();
    handle.join().expect("listener drain");
    (res, wall_s)
}

fn main() {
    println!(
        "== live_faults ({N_REQUESTS} requests, {REPLICAS} replicas, \
         loopback NDJSON, time-scale {TIME_SCALE}) =="
    );
    let mut report = BenchReport::new("live_faults");

    // Fault times derived from the trace, exactly like the virtual-time
    // fault bench: kill replica 1 a third of the way into the arrivals,
    // restart it at the two-thirds mark.
    let trace = sart::server::trace_for(&spec("")).expect("bench trace");
    let t_fail = trace[N_REQUESTS / 3].arrival;
    let t_restart = trace[2 * N_REQUESTS / 3].arrival;
    let plan = format!("fail@{t_fail}:1,restart@{t_restart}:1");

    let (clean, clean_wall_s) = run_live(&spec(""));
    let (faulted, faulted_wall_s) = run_live(&spec(&plan));

    let lost = faulted.requests_lost as f64;
    let migrated = faulted.migrated_sessions as f64;
    let p99_clean = percentile(&clean.wall_e2e, 99.0);
    let p99_faulted = percentile(&faulted.wall_e2e, 99.0);
    let ratio = p99_faulted / p99_clean.max(1e-12);
    println!(
        "clean: {}/{} finalized in {clean_wall_s:.2}s wall",
        clean.outcomes.len(),
        trace.len(),
    );
    println!(
        "faulted (fail@{t_fail:.2}, restart@{t_restart:.2}): {}/{} \
         finalized, {migrated:.0} migrated, {} retries, {lost:.0} lost \
         in {faulted_wall_s:.2}s wall",
        faulted.outcomes.len(),
        trace.len(),
        faulted.retries,
    );
    println!(
        "p99 wall e2e: clean {p99_clean:.3}s vs faulted {p99_faulted:.3}s \
         (ratio {ratio:.2}, gate < 10)"
    );

    report.metric("live_faults_requests_lost", lost);
    report.metric("live_faults_migrated_sessions", migrated);
    report.metric("live_faults_retries", faulted.retries as f64);
    report.metric("live_faults_rejected", faulted.rejected as f64);
    report.metric("wall_e2e_p99_clean_s", p99_clean);
    report.metric("wall_e2e_p99_faulted_s", p99_faulted);
    report.metric("live_faulted_vs_clean_p99_ratio", ratio);

    // Wall cost of the faulted loopback replay (one sample — re-running
    // would re-pay the whole scaled trace).
    report.push(bench::run_timed(
        &format!("faulted loopback replay {N_REQUESTS} reqs"),
        0,
        1,
        || faulted_wall_s * 1e6,
    ));

    report.write().expect("writing BENCH_live_faults.json");
}
