//! Bench: end-to-end method comparison tables (the paper's §5.2 numbers
//! at bench scale) — prints the same rows as Fig. 5's harness plus wall
//! time per method, over the virtual-time engine by default.
//!
//! Per-method accuracy / p97 / wall-ms also land in `BENCH_e2e.json`.
//!
//!     cargo bench --bench e2e_tables

use sart::cluster::LbPolicy;
use sart::config::{EngineChoice, Method, PrmChoice, ServeSpec};
use sart::metrics::ServeReport;
use sart::server;
use sart::testkit::bench::BenchReport;
use sart::util::stats::render_table;

fn spec() -> ServeSpec {
    ServeSpec {
        method: Method::Vanilla,
        dataset: "synth-gaokao".into(),
        n_requests: 64,
        rate: 2.0,
        engine: EngineChoice::Sim,
        prm: PrmChoice::Oracle { sigma: 0.08 },
        replicas: 1,
        lb: LbPolicy::RoundRobin,
        gossip_rounds: 0,
        gossip_adapt: false,
        fault_plan: Default::default(),
        scale: None,
        slots: 16,
        kv_capacity_tokens: 8192,
        kv_page_tokens: 16,
        prefix_cache_pages: 0,
        prefill_chunk_tokens: 0,
        max_batched_prefill_tokens: 0,
        kv_stream: false,
        kv_preempt: false,
        prefix_share: 0.0,
        prefix_templates: 3,
        prefix_shots: 3,
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        seed: 42,
    }
}

fn metric_key(label: &str, what: &str) -> String {
    let slug: String = label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("{slug}_{what}")
}

fn main() {
    println!("== e2e_tables (sim, 64 requests @ 2/s, 16 slots) ==");
    let base = spec();
    let trace = server::trace_for(&base).unwrap();
    let n = 8;
    let m = 4;
    let methods = [
        Method::Vanilla,
        Method::SelfConsistency { n },
        Method::Rebase { n },
        Method::SartNoPrune { n, m },
        Method::Sart { n, m, alpha: 0.5, beta: m },
    ];
    let mut report = BenchReport::new("e2e");
    let mut rows = Vec::new();
    for method in methods {
        let mut s = base.clone();
        s.method = method;
        let t0 = std::time::Instant::now();
        let out = server::run_on_trace(&s, &trace).unwrap();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        report.metric(&metric_key(&out.report.label, "acc"), out.report.accuracy);
        report.metric(&metric_key(&out.report.label, "e2e_p97_s"), out.report.e2e.p97);
        report.metric(&metric_key(&out.report.label, "bench_wall_ms"), wall_ms);
        let mut row = out.report.row();
        row.push(format!("{wall_ms:.0} ms"));
        rows.push(row);
    }
    let mut headers: Vec<&str> = ServeReport::ROW_HEADERS.to_vec();
    headers.push("bench-wall");
    println!("{}", render_table(&headers, &rows));
    report.write().expect("writing BENCH_e2e.json");
}
