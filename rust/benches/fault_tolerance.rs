//! Bench: what does a replica failure actually cost?
//!
//! Serves one prefix-heavy trace through `cluster::serve_cluster` at
//! R = 4 with gossip-routed prefix affinity, three ways — fault-free
//! (static), with a scripted mid-trace failure + restart of replica 1,
//! and with the queue-driven scale controller starting at 2 live
//! replicas — and records, in `BENCH_faults.json` (schema in
//! EXPERIMENTS.md §Benches):
//!
//! 1. **Is the failure loss-free?** `faults_requests_lost` must be
//!    exactly 0 (`tools/check_bench.py` gates it): every in-flight
//!    request on the dead replica is re-dispatched and completes.
//! 2. **What does the detour cost?** `faults_vs_static_p99_ratio` = the
//!    faulted serve's p99 end-to-end latency over the static serve's,
//!    gated < 5.0: a one-replica outage may stretch the tail (lost KV
//!    state is re-prefilled, survivors absorb the load) but must not
//!    blow it up unboundedly. `redispatches_total` sizes the detour.
//! 3. **Does the rejoined replica actually recover?**
//!    `rewarm_hit_rate_recovery` = cluster cache-hit rate over the last
//!    quarter of arrivals (well after the restart) over the first
//!    quarter's (pre-failure), gated ≥ 0.5 — a restart that left
//!    routing or re-warming broken would depress late hits.
//!    `digest_rows_restarted` pins the gossip-level observable: the
//!    rejoined replica's table row advertised again.
//! 4. **Does elasticity serve the same work?** The scale-controller run
//!    reports `scale_ups_total` / `scale_downs_total` and its own lost
//!    count in `scale_requests_lost` (also must be 0 — it shares the
//!    loss-free gate's machinery).
//!
//!     cargo bench --bench fault_tolerance

use sart::cluster::{
    serve_cluster, ClusterConfig, ClusterResult, FaultPlan, LbPolicy,
    ScaleConfig,
};
use sart::coordinator::{KvConfig, Policy, SchedConfig};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::metrics::ServeReport;
use sart::prm::{OraclePrm, PrmScorer};
use sart::testkit::bench::{self, BenchReport};
use sart::workload::{templated_trace, Request, TaskSpec};

const REPLICAS: usize = 4;
const SLOTS: usize = 8;
const KV_TOKENS: usize = 32768;
const CACHE_PAGES: usize = 24;
const GOSSIP_ROUNDS: usize = 8;
const SEED: u64 = 42;
const N_REQUESTS: usize = 160;
const RATE: f64 = 6.0;

fn spec() -> TaskSpec {
    TaskSpec::synth_gaokao()
}

fn sched_cfg() -> SchedConfig {
    SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16)
            .with_prefix_cache(CACHE_PAGES),
        adaptive: None,
        seed: SEED,
    }
}

fn run_cluster(
    fault_plan: FaultPlan,
    scale: Option<ScaleConfig>,
    trace: &[Request],
) -> ClusterResult {
    let mut engines: Vec<Box<dyn Engine>> = (0..REPLICAS)
        .map(|_| {
            let mut e =
                SimEngine::new(SLOTS, 512, spec(), SimCostModel::default());
            e.set_prompt_bucket(256);
            Box::new(e) as Box<dyn Engine>
        })
        .collect();
    let mut prms: Vec<Box<dyn PrmScorer>> = (0..REPLICAS)
        .map(|i| {
            Box::new(OraclePrm::new(0.08, SEED ^ 7 ^ ((i as u64) << 32)))
                as Box<dyn PrmScorer>
        })
        .collect();
    let cfg = ClusterConfig {
        replicas: REPLICAS,
        lb: LbPolicy::PrefixAffinity,
        sched: sched_cfg(),
        seed: SEED,
        audit: false,
        gossip_rounds: GOSSIP_ROUNDS,
        gossip_adapt: false,
        fault_plan,
        scale,
    };
    serve_cluster(&cfg, &mut engines, &mut prms, trace)
        .expect("fault bench serve")
}

/// Cluster cache-hit rate over one window of trace positions.
fn window_hit_rate(
    trace: &[Request],
    res: &ClusterResult,
    range: std::ops::Range<usize>,
) -> f64 {
    let mut hit = 0usize;
    let mut total = 0usize;
    for pos in range {
        hit += res.outcomes[pos].cached_prompt_tokens;
        total += trace[pos].prompt_tokens().len();
    }
    if total > 0 {
        hit as f64 / total as f64
    } else {
        0.0
    }
}

fn main() {
    println!(
        "== fault_tolerance ({REPLICAS} replicas x {SLOTS} slots, \
         {N_REQUESTS} requests, gossip period {GOSSIP_ROUNDS}) =="
    );
    let mut report = BenchReport::new("faults");

    let trace = templated_trace(&spec(), N_REQUESTS, RATE, SEED, 0.85, 3, 3);
    // Fail replica 1 a third of the way in, restart it at the midpoint:
    // the last quarter of arrivals sees a fully rejoined cluster.
    let t_fail = trace[N_REQUESTS / 3].arrival;
    let t_restart = trace[N_REQUESTS / 2].arrival;
    let plan =
        FaultPlan::parse(&format!("fail@{t_fail}:1,restart@{t_restart}:1"))
            .expect("bench fault plan");

    let static_res = run_cluster(FaultPlan::default(), None, &trace);
    let faulted = run_cluster(plan.clone(), None, &trace);
    let scaled = run_cluster(
        FaultPlan::default(),
        Some(ScaleConfig {
            min_live: 2,
            scale_up_queue: 3,
            scale_up_prefill_tokens: 0,
            scale_up_pressure: 0.0,
            scale_down_queue: 1,
            cooldown_arrivals: 4,
        }),
        &trace,
    );

    let lost = (trace.len() - faulted.outcomes.len()) as f64;
    let scale_lost = (trace.len() - scaled.outcomes.len()) as f64;
    let p99_static = ServeReport::from_outcomes("static", &static_res.outcomes)
        .e2e
        .p99;
    let p99_faulted = ServeReport::from_outcomes("faulted", &faulted.outcomes)
        .e2e
        .p99;
    let p99_ratio = p99_faulted / p99_static.max(1e-12);
    let early = window_hit_rate(&trace, &faulted, 0..N_REQUESTS / 4);
    let late =
        window_hit_rate(&trace, &faulted, 3 * N_REQUESTS / 4..N_REQUESTS);
    let recovery = late / early.max(1e-12);
    println!(
        "failure at t={t_fail:.2}, restart at t={t_restart:.2}: \
         {} re-dispatches over {} requests, 0 lost",
        faulted.fault.redispatches, faulted.fault.requests_redispatched,
    );
    println!(
        "p99 e2e: static {p99_static:.2}s vs faulted {p99_faulted:.2}s \
         (ratio {p99_ratio:.2}, gate < 5.0)"
    );
    println!(
        "cache-hit rate: first quarter {early:.3} vs last quarter {late:.3} \
         (recovery {recovery:.2}, gate ≥ 0.5); rejoined replica advertises \
         {} digests",
        faulted.digest_rows[1],
    );
    println!(
        "scale controller: {} ups / {} downs, {scale_lost:.0} lost",
        scaled.fault.scale_ups, scaled.fault.scale_downs,
    );

    report.metric("faults_requests_lost", lost);
    report.metric("faults_vs_static_p99_ratio", p99_ratio);
    report.metric("rewarm_hit_rate_recovery", recovery);
    report.metric("digest_rows_restarted", faulted.digest_rows[1] as f64);
    report.metric("redispatches_total", faulted.fault.redispatches as f64);
    report.metric(
        "requests_redispatched",
        faulted.fault.requests_redispatched as f64,
    );
    report.metric("p99_e2e_static", p99_static);
    report.metric("p99_e2e_faulted", p99_faulted);
    report.metric("cache_hit_rate_static", static_res.cache_hit_rate());
    report.metric("cache_hit_rate_faulted", faulted.cache_hit_rate());
    report.metric("scale_requests_lost", scale_lost);
    report.metric("scale_ups_total", scaled.fault.scale_ups as f64);
    report.metric("scale_downs_total", scaled.fault.scale_downs as f64);

    // Wall cost of the co-simulated serves: the fault pump's overhead on
    // the dispatch path (drain + re-dispatch + retraction included).
    report.push(bench::run(
        &format!("cluster serve {N_REQUESTS} reqs (static)"),
        1,
        5,
        || {
            std::hint::black_box(run_cluster(
                FaultPlan::default(),
                None,
                &trace,
            ));
        },
    ));
    report.push(bench::run(
        &format!("cluster serve {N_REQUESTS} reqs (fail+restart)"),
        1,
        5,
        || {
            std::hint::black_box(run_cluster(plan.clone(), None, &trace));
        },
    ));

    report.write().expect("writing BENCH_faults.json");
}
