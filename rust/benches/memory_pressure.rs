//! Bench: serving under memory pressure — stream-aware admission plus
//! reward-driven preemption vs all-or-nothing admission at the *same*
//! tight page budget.
//!
//! The workload keeps the budget the bottleneck: every prompt carries a
//! cold 5-shot header (cache off, ~17 pages) ahead of a 4-branch SART
//! request (4 x 14 reserved pages), under a budget that holds barely one
//! request whole. All-or-nothing admission must wait for the whole
//! uncovered suffix plus reservations to fit; streamed admission enters
//! once the first chunk fits and grows its pledge as the prompt streams,
//! and preemption reclaims the lowest-reward running branches when an
//! admission still falls short.
//!
//! Recorded in `BENCH_pressure.json` (schema in EXPERIMENTS.md §Reading
//! BENCH_pressure.json), gated by `tools/check_bench.py`:
//!
//! * `pressure_requests_lost` — must be 0: swapping branches out and
//!   recomputing them on resume may never drop a request.
//! * `pressure_admitted_at_budget_ratio` — requests admitted by the
//!   baseline's median admission time, pressure / baseline. Must stay
//!   > 1.0: the pressure path admits strictly more with the same pages.
//!
//!     cargo bench --bench memory_pressure

use sart::coordinator::{
    ClockHandle, KvConfig, Policy, SchedConfig, Scheduler, ServeResult,
};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::prm::OraclePrm;
use sart::testkit::bench::{self, BenchReport};
use sart::util::clock::SimClock;
use sart::workload::{templated_trace, TaskSpec};

const SLOTS: usize = 8;
// 96 pages x 16 tokens: one headered 4-branch request (~73 pages) fits
// whole, a second only via streaming + preemption.
const KV_TOKENS: usize = 96 * 16;
const SEED: u64 = 23;
const N_REQUESTS: usize = 48;
const RATE: f64 = 6.0;
const CHUNK: usize = 32;
const BUDGET: usize = 64;

fn spec() -> TaskSpec {
    TaskSpec::synth_gaokao()
}

fn serve(stream: bool, preempt: bool) -> ServeResult {
    // Cold 5-shot headers (~240 tokens + question): prompt bucket must
    // exceed the default 256, and the engine must hold prompt + max_new.
    let trace = templated_trace(&spec(), N_REQUESTS, RATE, SEED, 1.0, 6, 5);
    let mut engine = SimEngine::new(
        SLOTS,
        560,
        spec(),
        SimCostModel { prefill_per_token: 0.2e-3, ..SimCostModel::default() },
    );
    engine.set_prompt_bucket(288);
    let mut prm = OraclePrm::new(0.08, SEED ^ 7);
    let cfg = SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16)
            .with_chunked_prefill(CHUNK, BUDGET)
            .with_stream_admission(stream)
            .with_preemption(preempt),
        adaptive: None,
        seed: SEED,
    };
    let mut sched = Scheduler::new(
        cfg,
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.serve(&trace).expect("pressure serve")
}

fn makespan(res: &ServeResult) -> f64 {
    res.outcomes.iter().map(|o| o.finished_at).fold(0.0f64, f64::max)
}

fn main() {
    println!(
        "== memory_pressure ({SLOTS} slots, {N_REQUESTS} requests, \
         {} kv pages) ==",
        KV_TOKENS / 16
    );
    let mut report = BenchReport::new("pressure");

    let base = serve(false, false);
    let pressure = serve(true, true);

    let base_lost = N_REQUESTS - base.outcomes.len();
    let pressure_lost = N_REQUESTS - pressure.outcomes.len();
    assert_eq!(pressure_lost, 0, "pressure serve dropped requests");
    assert_eq!(base_lost, 0, "baseline serve dropped requests");

    // Admission horizon: the baseline's median admission time. The
    // pressure path must have admitted strictly more requests by then —
    // same pages, earlier entry.
    let mut admitted: Vec<f64> =
        base.outcomes.iter().map(|o| o.admitted_at).collect();
    admitted.sort_by(f64::total_cmp);
    let horizon = admitted[admitted.len() / 2];
    let by_horizon = |res: &ServeResult| {
        res.outcomes.iter().filter(|o| o.admitted_at <= horizon).count()
    };
    let base_admits = by_horizon(&base);
    let pressure_admits = by_horizon(&pressure);
    let ratio = pressure_admits as f64 / base_admits.max(1) as f64;
    assert!(
        ratio > 1.0,
        "streamed + preempting admission must beat all-or-nothing at the \
         same budget: {pressure_admits} vs {base_admits} by t={horizon:.2}s"
    );

    let preemptions: usize =
        pressure.outcomes.iter().map(|o| o.preemptions).sum();
    let mk_base = makespan(&base);
    let mk_pressure = makespan(&pressure);
    println!(
        "admitted by t={horizon:.2}s: pressure {pressure_admits} vs \
         baseline {base_admits} (ratio {ratio:.3}, must stay > 1.0)"
    );
    println!(
        "preemptions {preemptions}, makespan pressure {mk_pressure:.2}s \
         vs baseline {mk_base:.2}s, lost {pressure_lost}/{base_lost}"
    );

    report.metric("pressure_requests_lost", pressure_lost as f64);
    report.metric("baseline_requests_lost", base_lost as f64);
    report.metric("pressure_admitted_at_budget_ratio", ratio);
    report.metric("admission_horizon_seconds", horizon);
    report.metric("pressure_admits_by_horizon", pressure_admits as f64);
    report.metric("baseline_admits_by_horizon", base_admits as f64);
    report.metric("pressure_preemptions_total", preemptions as f64);
    report.metric("pressure_makespan_seconds", mk_pressure);
    report.metric("baseline_makespan_seconds", mk_base);

    report.push(bench::run("serve 48 reqs all-or-nothing (96 pages)", 1, 5, || {
        std::hint::black_box(serve(false, false));
    }));
    report.push(bench::run("serve 48 reqs streamed+preempt (96 pages)", 1, 5, || {
        std::hint::black_box(serve(true, true));
    }));

    report.write().expect("writing BENCH_pressure.json");
}
