//! Bench: KV-cache manager hot-path operations.
//!
//! The manager is consulted on every admission decision and every
//! branch termination; these must be far off the engine-step critical
//! path (<1 µs). Storage is slab-based with generation-checked handles,
//! so admit/release/note_decode are array indexing, not hashing.
//!
//! Results land in `BENCH_kvcache.json`.
//!
//!     cargo bench --bench kvcache_ops

use sart::kvcache::{AdmissionOutcome, AdmissionRequest, KvCacheManager};
use sart::testkit::bench::{self, BenchReport};
use sart::util::rng::Rng;

fn main() {
    println!("== kvcache_ops ==");
    let mut report = BenchReport::new("kvcache");

    let prompt: Vec<i32> = (0..27).collect();

    report.push(bench::run("admit+release 8-branch request", 100, 5000, || {
        let mut kv = KvCacheManager::new(16384, 16);
        let adm = kv
            .admit(&AdmissionRequest::monolithic(&prompt, 224, 8))
            .unwrap()
            .into_admission()
            .unwrap();
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
    }));

    // Steady-state churn at ~70% occupancy (the serving regime).
    let mut kv = KvCacheManager::new(65536, 16);
    let mut live = Vec::new();
    let mut rng = Rng::new(0);
    for _ in 0..40 {
        if let AdmissionOutcome::Admitted(adm) = kv
            .admit(&AdmissionRequest::monolithic(&prompt, 224, 4))
            .unwrap()
        {
            live.extend(adm.branches);
        }
    }
    report.push(bench::run("steady-state admit/release churn", 100, 5000, || {
        if rng.chance(0.5) && !live.is_empty() {
            let i = rng.below(live.len());
            let b = live.swap_remove(i);
            kv.release_branch(b).unwrap();
        } else if let AdmissionOutcome::Admitted(adm) = kv
            .admit(&AdmissionRequest::monolithic(&prompt, 224, 4))
            .unwrap()
        {
            live.extend(adm.branches);
        }
    }));

    report.push(bench::run("note_decode (per-round progress)", 100, 20000, || {
        if let Some(&b) = live.first() {
            kv.note_decode(b, 1).unwrap();
        }
        std::hint::black_box(kv.live_decoded_tokens());
    }));

    // The side-effect-free path: an oversized request is always
    // Deferred, so the probe mutates nothing (the old `can_admit`).
    report.push(bench::run("deferred admission probe", 100, 20000, || {
        let out = kv
            .admit(&AdmissionRequest::monolithic(&prompt, 1 << 20, 8))
            .unwrap();
        std::hint::black_box(out.is_deferred());
    }));

    report.push(bench::run("invariant check (diagnostic path)", 10, 2000, || {
        kv.check_invariants().unwrap();
    }));

    report.write().expect("writing BENCH_kvcache.json");
}
