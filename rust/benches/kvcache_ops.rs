//! Bench: KV-cache manager hot-path operations.
//!
//! The manager is consulted on every admission decision and every
//! branch termination; these must be far off the engine-step critical
//! path (<1 µs). Storage is slab-based with generation-checked handles,
//! so admit/release/note_decode are array indexing, not hashing.
//!
//! Results land in `BENCH_kvcache.json`.
//!
//!     cargo bench --bench kvcache_ops

use sart::kvcache::KvCacheManager;
use sart::testkit::bench::{self, BenchReport};
use sart::util::rng::Rng;

fn main() {
    println!("== kvcache_ops ==");
    let mut report = BenchReport::new("kvcache");

    report.push(bench::run("admit+release 8-branch request", 100, 5000, || {
        let mut kv = KvCacheManager::new(16384, 16);
        let (_, bs) = kv.admit(27, 224, 8).unwrap();
        for b in bs {
            kv.release_branch(b).unwrap();
        }
    }));

    // Steady-state churn at ~70% occupancy (the serving regime).
    let mut kv = KvCacheManager::new(65536, 16);
    let mut live = Vec::new();
    let mut rng = Rng::new(0);
    for _ in 0..40 {
        if let Ok((_, bs)) = kv.admit(27, 224, 4) {
            live.extend(bs);
        }
    }
    report.push(bench::run("steady-state admit/release churn", 100, 5000, || {
        if rng.chance(0.5) && !live.is_empty() {
            let i = rng.below(live.len());
            let b = live.swap_remove(i);
            kv.release_branch(b).unwrap();
        } else if kv.can_admit(27, 224, 4) {
            let (_, bs) = kv.admit(27, 224, 4).unwrap();
            live.extend(bs);
        }
    }));

    report.push(bench::run("note_decode (per-round progress)", 100, 20000, || {
        if let Some(&b) = live.first() {
            kv.note_decode(b, 1).unwrap();
        }
        std::hint::black_box(kv.live_decoded_tokens());
    }));

    report.push(bench::run("can_admit check", 100, 20000, || {
        std::hint::black_box(kv.can_admit(27, 224, 8));
    }));

    report.push(bench::run("invariant check (diagnostic path)", 10, 2000, || {
        kv.check_invariants().unwrap();
    }));

    report.write().expect("writing BENCH_kvcache.json");
}
