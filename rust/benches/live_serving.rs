//! Bench: what does serving over the wall-clock front end cost?
//!
//! Replays one 64-request trace twice — through the virtual-time serve
//! (`server::run_on_trace`) and through a loopback `sart listen` +
//! `sart replay` pair at `--time-scale 0.01` — and records, in
//! `BENCH_serving.json` (schema in EXPERIMENTS.md §Benches):
//!
//! 1. **Is the live path loss-free?** `serving_requests_lost` must be
//!    exactly 0 (`tools/check_bench.py` gates it): every accepted
//!    session streams to its `finalized` event. `serving_rejected`
//!    rides along (0 here — the trace never exceeds the session table).
//! 2. **What does wall-clock pacing cost?**
//!    `wall_vs_virtual_p99_ratio` = the live serve's p99 wall e2e
//!    latency over the virtual serve's p99 *scaled to wall units*
//!    (virtual p99 × time-scale), gated < 50: the live path pays
//!    stepping granularity, socket hops and thread scheduling on top of
//!    the simulated decode cost, but must stay within an order of
//!    magnitude of the ideal replay at this aggressive a time scale.
//! 3. **Live tail observables**: `wall_ttft_p99_s` / `wall_e2e_p99_s`
//!    (wall seconds per session from open to first `tokens` /
//!    `finalized`) and `virtual_e2e_p99_s` for the same trace.
//!
//!     cargo bench --bench live_serving

use sart::config::{Args, LiveConfig, ServeSpec};
use sart::frontend;
use sart::testkit::bench::{self, BenchReport};
use sart::util::stats::percentile;
use std::time::Instant;

const N_REQUESTS: usize = 64;
const TIME_SCALE: f64 = 0.01;

fn spec() -> ServeSpec {
    let args = Args::parse(
        format!(
            "--method sart:4 --requests {N_REQUESTS} --rate 4 \
             --kv-tokens 8192 --seed 42"
        )
        .split_whitespace()
        .map(String::from),
    )
    .expect("bench args");
    ServeSpec::from_args(&args).expect("bench spec")
}

fn main() {
    println!(
        "== live_serving ({N_REQUESTS} requests, loopback NDJSON, \
         time-scale {TIME_SCALE}) =="
    );
    let mut report = BenchReport::new("serving");

    let spec = spec();
    let trace = sart::server::trace_for(&spec).expect("bench trace");

    // Virtual-time baseline: the same trace through the batch serve.
    let virt = sart::server::run_on_trace(&spec, &trace)
        .expect("virtual baseline serve");
    let virtual_p99 = virt.report.e2e.p99;

    // Live loopback: listener on an ephemeral port, replay at trace rate.
    let live = LiveConfig {
        addr: "127.0.0.1:0".into(),
        time_scale: TIME_SCALE,
        max_sessions: 256,
    };
    let handle = frontend::listen(&spec, &live).expect("loopback listener");
    let addr = handle.addr().to_string();
    let t0 = Instant::now();
    let res = frontend::replay(&addr, &trace, TIME_SCALE, true)
        .expect("loopback replay");
    let replay_wall_s = t0.elapsed().as_secs_f64();
    handle.join().expect("listener drain");

    let lost = res.requests_lost as f64;
    let rejected = res.rejected as f64;
    let wall_ttft_p99 = percentile(&res.wall_ttft, 99.0);
    let wall_e2e_p99 = percentile(&res.wall_e2e, 99.0);
    // The ideal live serve realizes a virtual second in TIME_SCALE wall
    // seconds; the ratio is the live path's overhead over that ideal.
    let ratio = wall_e2e_p99 / (virtual_p99 * TIME_SCALE).max(1e-12);
    println!(
        "live: {}/{} finalized, {rejected:.0} rejected, {lost:.0} lost \
         in {replay_wall_s:.2}s wall",
        res.outcomes.len(),
        trace.len(),
    );
    println!(
        "p99 e2e: virtual {virtual_p99:.2}s (ideal wall {:.3}s) vs live \
         wall {wall_e2e_p99:.3}s (ratio {ratio:.2}, gate < 50)",
        virtual_p99 * TIME_SCALE,
    );

    report.metric("serving_requests_lost", lost);
    report.metric("serving_rejected", rejected);
    report.metric("wall_ttft_p99_s", wall_ttft_p99);
    report.metric("wall_e2e_p99_s", wall_e2e_p99);
    report.metric("virtual_e2e_p99_s", virtual_p99);
    report.metric("wall_vs_virtual_p99_ratio", ratio);

    // Wall cost of the full loopback replay (one sample — the serve
    // above; re-running would re-pay the whole scaled trace).
    report.push(bench::run_timed(
        &format!("loopback replay {N_REQUESTS} reqs"),
        0,
        1,
        || replay_wall_s * 1e6,
    ));

    report.write().expect("writing BENCH_serving.json");
}
