//! Bench: the multi-replica dispatch layer under a saturating Poisson
//! trace.
//!
//! Serves the same workload through `cluster::serve_cluster` once per
//! load-balancing policy and records cluster-level p50/p99 end-to-end
//! latency plus per-replica occupancy skew in `BENCH_cluster.json`
//! (schema in EXPERIMENTS.md §Benches). The arrival rate is calibrated
//! in-run against a single replica's batch throughput, so the comparison
//! stays in the discriminating near-saturation regime (~0.92 utilisation)
//! even if the sim cost model changes.
//!
//! The headline metric is `p2c_vs_rr_p99_ratio`: power-of-two-choices
//! must beat round-robin on p99 (< 1.0) — load-blind dispatch lets one
//! replica build a backlog while another idles, exactly the tail the
//! paper's single-engine scheduling work is trying to keep down.
//!
//!     cargo bench --bench cluster_dispatch

use sart::cluster::{serve_cluster, ClusterConfig, ClusterResult, LbPolicy};
use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::prm::{OraclePrm, PrmScorer};
use sart::testkit::bench::{self, BenchReport};
use sart::util::clock::SimClock;
use sart::util::stats::percentile;
use sart::workload::{batch_trace, poisson_trace, Request, TaskSpec};

const REPLICAS: usize = 4;
const SLOTS: usize = 8;
const KV_TOKENS: usize = 8192;
const N_REQUESTS: usize = 192;
const SEED: u64 = 42;

fn sched_cfg() -> SchedConfig {
    SchedConfig {
        // N=4 over 8 slots: two requests decode concurrently per replica,
        // so service times are long and variable (synth-gpqa re-think
        // loops) — the regime where dispatch policy moves the tail.
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16),
        adaptive: None,
        seed: SEED,
    }
}

fn spec() -> TaskSpec {
    TaskSpec::synth_gpqa()
}

fn replica_stacks(
    n: usize,
) -> (Vec<Box<dyn Engine>>, Vec<Box<dyn PrmScorer>>) {
    let engines: Vec<Box<dyn Engine>> = (0..n)
        .map(|_| {
            Box::new(SimEngine::new(
                SLOTS,
                256,
                spec(),
                SimCostModel::default(),
            )) as Box<dyn Engine>
        })
        .collect();
    let prms: Vec<Box<dyn PrmScorer>> = (0..n)
        .map(|i| {
            Box::new(OraclePrm::new(0.08, SEED ^ 7 ^ ((i as u64) << 32)))
                as Box<dyn PrmScorer>
        })
        .collect();
    (engines, prms)
}

/// Single-replica batch throughput (req/s of virtual time with slots
/// always full) — the calibration anchor for the saturating rate.
fn single_replica_throughput() -> f64 {
    let probe = batch_trace(&spec(), 48, SEED);
    let mut engine =
        SimEngine::new(SLOTS, 256, spec(), SimCostModel::default());
    let mut prm = OraclePrm::new(0.08, SEED ^ 7);
    let mut sched = Scheduler::new(
        sched_cfg(),
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    let res = sched.serve(&probe).expect("calibration serve");
    let makespan = res
        .outcomes
        .iter()
        .map(|o| o.finished_at)
        .fold(0.0f64, f64::max);
    48.0 / makespan.max(1e-9)
}

fn run_cluster(lb: LbPolicy, trace: &[Request]) -> ClusterResult {
    let (mut engines, mut prms) = replica_stacks(REPLICAS);
    let cfg = ClusterConfig {
        replicas: REPLICAS,
        lb,
        sched: sched_cfg(),
        seed: SEED,
        audit: false,
        gossip_rounds: 0,
        gossip_adapt: false,
        fault_plan: Default::default(),
        scale: None,
    };
    serve_cluster(&cfg, &mut engines, &mut prms, trace)
        .expect("cluster serve")
}

fn main() {
    println!(
        "== cluster_dispatch ({REPLICAS} replicas x {SLOTS} slots, \
         {N_REQUESTS} requests, synth-gpqa) =="
    );
    let mut report = BenchReport::new("cluster");

    let thru1 = single_replica_throughput();
    let rate = 0.92 * REPLICAS as f64 * thru1;
    println!(
        "calibration: single-replica throughput {thru1:.2} req/s \
         → Poisson rate {rate:.2} req/s (~0.92 utilisation)"
    );
    report.metric("single_replica_throughput_req_s", thru1);
    report.metric("poisson_rate_req_s", rate);
    let trace = poisson_trace(&spec(), N_REQUESTS, rate, SEED);

    let mut p99_by_slug: Vec<(&'static str, f64)> = Vec::new();
    for lb in LbPolicy::ALL {
        // This bench runs with the prefix cache disabled, where
        // prefix-affinity's cold fallback is decision-for-decision p2c —
        // its row would duplicate the p2c one (the affinity comparison
        // lives in `prefix_cache` / BENCH_prefix.json).
        if lb == LbPolicy::PrefixAffinity {
            continue;
        }
        let res = run_cluster(lb, &trace);
        let e2e: Vec<f64> =
            res.outcomes.iter().map(|o| o.e2e_latency()).collect();
        let p50 = percentile(&e2e, 50.0);
        let p99 = percentile(&e2e, 99.0);
        let rep = res.report();
        println!(
            "{:<14} p50 {p50:>7.2}s  p99 {p99:>7.2}s  occupancy-skew \
             {:.3}  req/replica {:?}",
            lb.label(),
            rep.occupancy_skew,
            rep.per_replica_requests
        );
        let slug = lb.slug();
        report.metric(&format!("p50_e2e_s_{slug}"), p50);
        report.metric(&format!("p99_e2e_s_{slug}"), p99);
        report.metric(&format!("occupancy_skew_{slug}"), rep.occupancy_skew);
        report.metric(&format!("request_skew_{slug}"), rep.request_skew);
        p99_by_slug.push((slug, p99));
        // Dispatch-layer wall cost (the whole co-simulated serve; the
        // sim engine does no real compute, so this is coordination +
        // dispatch bookkeeping).
        report.push(bench::run(
            &format!("cluster serve {N_REQUESTS} reqs ({})", lb.label()),
            1,
            5,
            || {
                std::hint::black_box(run_cluster(lb, &trace));
            },
        ));
    }

    let p99_of = |slug: &str| {
        p99_by_slug
            .iter()
            .find(|(s, _)| *s == slug)
            .map(|&(_, p)| p)
            .unwrap_or(f64::NAN)
    };
    let ratio = p99_of("p2c") / p99_of("rr");
    println!(
        "p2c vs round-robin p99 ratio: {ratio:.3} (< 1.0 means two random \
         load probes per request already tame the tail)"
    );
    report.metric("p2c_vs_rr_p99_ratio", ratio);
    report.metric("jsq_vs_rr_p99_ratio", p99_of("jsq") / p99_of("rr"));
    report.write().expect("writing BENCH_cluster.json");
}
