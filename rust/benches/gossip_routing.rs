//! Bench: prefix-digest gossip routing vs probe-per-replica affinity.
//!
//! Serves one prefix-heavy trace through `cluster::serve_cluster` at
//! R = 4 under eviction pressure (more header templates than any single
//! replica's retention budget holds) and records, in `BENCH_gossip.json`
//! (schema in EXPERIMENTS.md §Benches):
//!
//! 1. **Does routing on advertised digests keep the hits?**
//!    `gossip_vs_probe_hit_rate_ratio` = cluster-wide cache-hit rate with
//!    gossip (period `GOSSIP_ROUNDS`) over the probe-based policy's.
//!    `tools/check_bench.py` gates this ≥ 0.95: staleness may cost a few
//!    re-prefills, but the table must keep templates pinned where their
//!    pages live.
//! 2. **Does it actually remove the dispatch-hot-path scan?**
//!    `probe_calls_per_request_gossip` must be exactly 0 (the probe run
//!    records R per arrival for contrast) — also gated.
//! 3. **What does staleness cost?** `stale_hits_gossip`,
//!    `advertisements_gossip` and `digest_table_digests_gossip` give the
//!    trade's observability; p2c's hit rate anchors the floor both
//!    affinity spellings must clear.
//!
//!     cargo bench --bench gossip_routing

use sart::cluster::{serve_cluster, ClusterConfig, ClusterResult, LbPolicy};
use sart::coordinator::{KvConfig, Policy, SchedConfig};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::prm::{OraclePrm, PrmScorer};
use sart::testkit::bench::{self, BenchReport};
use sart::workload::{templated_trace, Request, TaskSpec};

const REPLICAS: usize = 4;
const SLOTS: usize = 8;
const KV_TOKENS: usize = 32768;
/// Per-replica retention budget: ~2.5 of the 4 templates — small enough
/// that scattering templates across replicas churns every cache.
const CACHE_PAGES: usize = 24;
const GOSSIP_ROUNDS: usize = 8;
const SEED: u64 = 42;
const N_REQUESTS: usize = 192;
const RATE: f64 = 8.0;

fn spec() -> TaskSpec {
    TaskSpec::synth_gaokao()
}

fn sched_cfg() -> SchedConfig {
    SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(KV_TOKENS, 16)
            .with_prefix_cache(CACHE_PAGES),
        adaptive: None,
        seed: SEED,
    }
}

fn run_cluster(
    lb: LbPolicy,
    gossip_rounds: usize,
    trace: &[Request],
) -> ClusterResult {
    let mut engines: Vec<Box<dyn Engine>> = (0..REPLICAS)
        .map(|_| {
            let mut e =
                SimEngine::new(SLOTS, 512, spec(), SimCostModel::default());
            e.set_prompt_bucket(256);
            Box::new(e) as Box<dyn Engine>
        })
        .collect();
    let mut prms: Vec<Box<dyn PrmScorer>> = (0..REPLICAS)
        .map(|i| {
            Box::new(OraclePrm::new(0.08, SEED ^ 7 ^ ((i as u64) << 32)))
                as Box<dyn PrmScorer>
        })
        .collect();
    let cfg = ClusterConfig {
        replicas: REPLICAS,
        lb,
        sched: sched_cfg(),
        seed: SEED,
        audit: false,
        gossip_rounds,
        gossip_adapt: false,
        fault_plan: Default::default(),
        scale: None,
    };
    serve_cluster(&cfg, &mut engines, &mut prms, trace)
        .expect("gossip bench serve")
}

fn main() {
    println!(
        "== gossip_routing ({REPLICAS} replicas x {SLOTS} slots, \
         {N_REQUESTS} requests, cache {CACHE_PAGES} pages, \
         gossip period {GOSSIP_ROUNDS}) =="
    );
    let mut report = BenchReport::new("gossip");

    // 4 templates over a 0.85 share: the same eviction-pressure shape
    // BENCH_prefix uses for its affinity-vs-p2c comparison.
    let trace = templated_trace(&spec(), N_REQUESTS, RATE, SEED, 0.85, 4, 3);

    let probe = run_cluster(LbPolicy::PrefixAffinity, 0, &trace);
    let gossip = run_cluster(LbPolicy::PrefixAffinity, GOSSIP_ROUNDS, &trace);
    let p2c = run_cluster(LbPolicy::PowerOfTwoChoices, 0, &trace);

    let hit_probe = probe.cache_hit_rate();
    let hit_gossip = gossip.cache_hit_rate();
    let hit_p2c = p2c.cache_hit_rate();
    let ratio = hit_gossip / hit_probe.max(1e-12);
    let n = trace.len() as f64;
    let probes_per_req_probe = probe.gossip.probe_calls as f64 / n;
    let probes_per_req_gossip = gossip.gossip.probe_calls as f64 / n;
    println!(
        "cache-hit rate: probe-affinity {hit_probe:.3} vs gossip-affinity \
         {hit_gossip:.3} (ratio {ratio:.3}, gate ≥ 0.95) vs p2c {hit_p2c:.3}"
    );
    println!(
        "dispatch cost: {probes_per_req_probe:.1} probes/request (probe \
         mode) vs {probes_per_req_gossip:.1} (gossip, gate == 0); gossip \
         paid {} advertisements, {} digests in table, {} stale hits",
        gossip.gossip.advertisements,
        gossip.gossip.digest_table_digests,
        gossip.gossip.stale_hits,
    );

    report.metric("cache_hit_rate_probe", hit_probe);
    report.metric("cache_hit_rate_gossip", hit_gossip);
    report.metric("cache_hit_rate_p2c", hit_p2c);
    report.metric("gossip_vs_probe_hit_rate_ratio", ratio);
    report.metric("probe_calls_per_request_probe", probes_per_req_probe);
    report.metric("probe_calls_per_request_gossip", probes_per_req_gossip);
    report.metric("stale_hits_gossip", gossip.gossip.stale_hits as f64);
    report.metric(
        "advertisements_gossip",
        gossip.gossip.advertisements as f64,
    );
    report.metric(
        "digest_table_digests_gossip",
        gossip.gossip.digest_table_digests as f64,
    );

    // Wall cost of the whole co-simulated serve per routing mode (the
    // sim engine does no real compute, so this is coordination +
    // dispatch bookkeeping — the probe scan's O(R) walks included).
    report.push(bench::run(
        &format!("cluster serve {N_REQUESTS} reqs (probe affinity)"),
        1,
        5,
        || {
            std::hint::black_box(run_cluster(
                LbPolicy::PrefixAffinity,
                0,
                &trace,
            ));
        },
    ));
    report.push(bench::run(
        &format!("cluster serve {N_REQUESTS} reqs (gossip affinity)"),
        1,
        5,
        || {
            std::hint::black_box(run_cluster(
                LbPolicy::PrefixAffinity,
                GOSSIP_ROUNDS,
                &trace,
            ));
        },
    ));

    report.write().expect("writing BENCH_gossip.json");
}
