//! Deterministic fault injection and elasticity knobs for cluster serves.
//!
//! A [`FaultPlan`] is a virtual-time script of replica failures and
//! restarts that the dispatcher applies between steps — no wall-clock
//! randomness, so a faulted serve replays bit-for-bit under the same
//! seed. [`ScaleConfig`] drives the queue-pressure scale controller that
//! adds and removes replicas through the same join/drain machinery, and
//! [`FaultStats`] is the cluster report's tally of everything that
//! happened.

use anyhow::{bail, Context, Result};

/// What happens to a replica at a scheduled instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The replica crashes: in-flight requests are re-dispatched to
    /// survivors, its gossip row is retracted, its cache is lost.
    Fail,
    /// The replica rejoins cold (empty cache, clock advanced to the
    /// event time) and re-warms through the ordinary gossip path.
    Restart,
}

/// One scripted event: `kind` applied to `replica` at virtual time `t`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub replica: usize,
    pub kind: FaultKind,
}

/// A virtual-time script of [`FaultEvent`]s, sorted by time. The default
/// (empty) plan is inert: the dispatcher's zero-fault path is
/// property-tested byte-identical to a plan-less serve.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse the CLI syntax: comma-separated `kind@t:replica` terms,
    /// e.g. `fail@2.5:1,restart@6.0:1`. Events may be given in any
    /// order; the plan sorts them by time (stable, so same-instant
    /// events keep their written order). Replica indices are validated
    /// against the actual replica count at serve time, not here.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for term in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (head, replica) = term
                .rsplit_once(':')
                .with_context(|| format!("fault term `{term}`: missing `:replica`"))?;
            let (kind, t) = head
                .split_once('@')
                .with_context(|| format!("fault term `{term}`: missing `@time`"))?;
            let kind = match kind {
                "fail" => FaultKind::Fail,
                "restart" => FaultKind::Restart,
                other => bail!(
                    "fault term `{term}`: unknown kind `{other}` \
                     (want fail|restart)"
                ),
            };
            let t: f64 = t
                .parse()
                .with_context(|| format!("fault term `{term}`: bad time `{t}`"))?;
            if !t.is_finite() || t < 0.0 {
                bail!("fault term `{term}`: time must be finite and >= 0");
            }
            let replica: usize = replica.parse().with_context(|| {
                format!("fault term `{term}`: bad replica index `{replica}`")
            })?;
            events.push(FaultEvent { t, replica, kind });
        }
        events.sort_by(|a, b| a.t.total_cmp(&b.t));
        Ok(FaultPlan { events })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Largest replica index named by any event (plan validation).
    pub fn max_replica(&self) -> Option<usize> {
        self.events.iter().map(|e| e.replica).max()
    }
}

/// Queue-pressure scale controller knobs. The controller is evaluated
/// once per arrival (after replicas catch up to it): it scales **up**
/// when the mean queue depth across live replicas exceeds
/// `scale_up_queue` — or the cluster-wide chunked-prefill backlog
/// exceeds `scale_up_prefill_tokens` — and scales **down** when the mean
/// depth falls below `scale_down_queue`. Keeping the down-threshold
/// strictly below the up-threshold is the hysteresis band that stops the
/// controller flapping; `cooldown_arrivals` rate-limits consecutive
/// actions on top.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleConfig {
    /// Replicas started live (also the floor scale-down respects).
    pub min_live: usize,
    /// Scale up when Σ requests-in-system > this × live replicas.
    pub scale_up_queue: usize,
    /// Also scale up when Σ pending prefill tokens exceeds this
    /// (0 disables the prefill-backlog trigger).
    pub scale_up_prefill_tokens: usize,
    /// Also scale up when any live replica's KV pressure — (used +
    /// pledged) / capacity pages — exceeds this (`--scale-pressure`;
    /// 0.0 disables the trigger). A saturated cache stalls streamed
    /// admissions and triggers preemptions long before the queue deepens,
    /// so memory pressure is a leading indicator the queue-depth
    /// thresholds lag.
    pub scale_up_pressure: f64,
    /// Scale down when Σ requests-in-system < this × live replicas
    /// (0 disables scale-down). Must stay below `scale_up_queue`.
    pub scale_down_queue: usize,
    /// Arrivals that must pass between two scaling actions.
    pub cooldown_arrivals: usize,
}

impl ScaleConfig {
    /// Scale-up decision from the controller's inputs: Σ
    /// requests-in-system, Σ pending prefill tokens, and the worst
    /// per-replica KV pressure over the `live` currently-live replicas.
    /// Pure so the virtual-time dispatcher and the wall-clock listener
    /// share one threshold definition.
    pub fn wants_scale_up(
        &self,
        queued: usize,
        prefill_backlog: usize,
        max_kv_pressure: f64,
        live: usize,
    ) -> bool {
        queued > self.scale_up_queue * live
            || (self.scale_up_prefill_tokens > 0
                && prefill_backlog > self.scale_up_prefill_tokens)
            || (self.scale_up_pressure > 0.0
                && max_kv_pressure > self.scale_up_pressure)
    }

    /// Scale-down decision (the other edge of the hysteresis band);
    /// `false` whenever scale-down is disabled or the floor is reached.
    pub fn wants_scale_down(&self, queued: usize, live: usize) -> bool {
        self.scale_down_queue > 0
            && live > self.min_live
            && queued < self.scale_down_queue * live
    }

    pub fn validate(&self) -> Result<()> {
        if self.min_live == 0 {
            bail!("scale controller needs min_live >= 1");
        }
        if self.scale_up_queue == 0 {
            bail!("scale controller needs scale_up_queue >= 1");
        }
        if self.scale_down_queue >= self.scale_up_queue {
            bail!(
                "scale_down_queue ({}) must stay below scale_up_queue ({}) \
                 — no hysteresis band means the controller flaps",
                self.scale_down_queue,
                self.scale_up_queue
            );
        }
        if !(0.0..=1.0).contains(&self.scale_up_pressure) {
            bail!(
                "scale_up_pressure must be in [0, 1] (a fraction of the \
                 page budget), got {}",
                self.scale_up_pressure
            );
        }
        Ok(())
    }
}

/// What the fault/elasticity layer did during one cluster serve.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Scripted failures applied.
    pub failures: usize,
    /// Scripted restarts applied.
    pub restarts: usize,
    /// Replicas activated by the scale controller.
    pub scale_ups: usize,
    /// Replicas drained by the scale controller.
    pub scale_downs: usize,
    /// Re-dispatch events (one per in-flight request per failure it
    /// survived; a request failed twice counts twice).
    pub redispatches: usize,
    /// Distinct requests that were re-dispatched at least once.
    pub requests_redispatched: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sorts_and_roundtrips() {
        let p = FaultPlan::parse("restart@6.0:1, fail@2.5:1").unwrap();
        assert_eq!(p.events.len(), 2);
        assert_eq!(
            p.events[0],
            FaultEvent { t: 2.5, replica: 1, kind: FaultKind::Fail }
        );
        assert_eq!(
            p.events[1],
            FaultEvent { t: 6.0, replica: 1, kind: FaultKind::Restart }
        );
        assert_eq!(p.max_replica(), Some(1));
        assert!(!p.is_empty());
    }

    #[test]
    fn parse_empty_is_inert() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
        assert!(FaultPlan::default().is_empty());
        assert_eq!(FaultPlan::default().max_replica(), None);
    }

    #[test]
    fn parse_rejects_malformed_terms() {
        for bad in [
            "fail@2.5",        // missing replica
            "fail:1",          // missing time
            "die@2.5:1",       // unknown kind
            "fail@x:1",        // bad time
            "fail@-1.0:1",     // negative time
            "fail@inf:1",      // non-finite time
            "fail@2.5:x",      // bad replica
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn parse_keeps_same_instant_order_stable() {
        let p = FaultPlan::parse("fail@1.0:0,fail@1.0:2,restart@1.0:0")
            .unwrap();
        let reps: Vec<usize> = p.events.iter().map(|e| e.replica).collect();
        assert_eq!(reps, vec![0, 2, 0]);
    }

    #[test]
    fn scale_thresholds_are_a_hysteresis_band() {
        let sc = ScaleConfig {
            min_live: 1,
            scale_up_queue: 4,
            scale_up_prefill_tokens: 100,
            scale_up_pressure: 0.9,
            scale_down_queue: 2,
            cooldown_arrivals: 0,
        };
        // Queue trigger: strictly above up-threshold × live.
        assert!(!sc.wants_scale_up(8, 0, 0.0, 2));
        assert!(sc.wants_scale_up(9, 0, 0.0, 2));
        // Prefill-backlog trigger is independent of queue depth.
        assert!(sc.wants_scale_up(0, 101, 0.0, 2));
        assert!(!sc.wants_scale_up(0, 100, 0.0, 2));
        // KV-pressure trigger: strictly above the threshold, and 0.0
        // disables it.
        assert!(sc.wants_scale_up(0, 0, 0.95, 2));
        assert!(!sc.wants_scale_up(0, 0, 0.9, 2));
        let no_pressure = ScaleConfig { scale_up_pressure: 0.0, ..sc };
        assert!(!no_pressure.wants_scale_up(0, 0, 1.0, 2));
        // Scale-down: strictly below down-threshold × live, floored.
        assert!(sc.wants_scale_down(3, 2));
        assert!(!sc.wants_scale_down(4, 2));
        assert!(!sc.wants_scale_down(0, 1), "min_live floor must hold");
        let off = ScaleConfig { scale_down_queue: 0, ..sc };
        assert!(!off.wants_scale_down(0, 2), "0 disables scale-down");
        // No overlap: a state that wants up never simultaneously wants
        // down (the hysteresis band validate() enforces).
        for q in 0..32 {
            assert!(
                !(sc.wants_scale_up(q, 0, 0.0, 2)
                    && sc.wants_scale_down(q, 2)),
                "flapping at queued={q}"
            );
        }
    }

    #[test]
    fn scale_config_validation() {
        let ok = ScaleConfig {
            min_live: 2,
            scale_up_queue: 6,
            scale_up_prefill_tokens: 0,
            scale_up_pressure: 0.0,
            scale_down_queue: 2,
            cooldown_arrivals: 8,
        };
        ok.validate().unwrap();
        assert!(ScaleConfig { min_live: 0, ..ok }.validate().is_err());
        assert!(ScaleConfig { scale_up_queue: 0, ..ok }.validate().is_err());
        assert!(
            ScaleConfig { scale_down_queue: 6, ..ok }.validate().is_err(),
            "down threshold touching up threshold must be rejected"
        );
        assert!(
            ScaleConfig { scale_up_pressure: 1.5, ..ok }
                .validate()
                .is_err(),
            "pressure threshold above 1.0 must be rejected"
        );
    }
}
