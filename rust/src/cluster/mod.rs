//! Multi-replica serving: R independent engine replicas behind a
//! dispatch layer.
//!
//! The paper's efficiency claims are about batching more requests under a
//! fixed memory budget; serving heavy traffic needs the next layer up —
//! horizontal scale. A [`serve_cluster`] run owns R *replicas*, each a
//! full single-engine stack (its own [`Engine`], `KvCacheManager` and
//! [`Scheduler`] state), and assigns every arriving request to exactly
//! one replica via a pluggable [`LbPolicy`].
//!
//! # Virtual-time co-simulation
//!
//! Replicas run in parallel in deployment, so their timelines are
//! independent: each replica advances its own [`SimClock`] by its own
//! engine costs only. All clocks share the trace's `t = 0` origin, which
//! keeps per-replica timelines directly comparable and lets the merged
//! outcome set report cluster-level latency percentiles. The dispatcher
//! drives the replicas event-by-event: before assigning a request that
//! arrives at time `t`, every replica is stepped forward until its clock
//! reaches `t` (or it idles), so load-aware policies observe each
//! replica's true state *at the arrival instant* — not a stale snapshot.
//!
//! A busy replica may overshoot `t` mid-round; that is exactly the
//! single-engine semantics, where a request arriving during a decode
//! round is admitted at the next round boundary.
//!
//! # Exact reduction at R = 1
//!
//! With one replica every request is dispatched to it in arrival order
//! and the step sequence is identical to [`Scheduler::serve`] on the same
//! trace, so outcomes and timeline are byte-identical — the property
//! tests assert this for every policy. The layer therefore costs nothing
//! to keep on the single-engine path.
//!
//! # Prefix-digest gossip (`gossip_rounds`)
//!
//! With `gossip_rounds = 0`, [`LbPolicy::PrefixAffinity`] probes every
//! replica's radix tree per arrival — O(R) tree walks on the dispatch
//! hot path. With `gossip_rounds = G ≥ 1`, each replica instead
//! re-advertises its resident prefix-digest set into a [`DigestTable`]
//! once it has run `G` scheduler steps since its last advertisement
//! (checked at each arrival instant, mirroring how a deployment's gossip
//! period is measured in replica rounds, not dispatcher events), and
//! routing becomes a table lookup: longest advertised prefix match, ties
//! broken by fewest requests in system, cold prompts falling back to
//! power-of-two-choices. `G = 1` keeps the table exactly as fresh as the
//! probes (a replica's tree only changes inside its own steps), which is
//! what the byte-identity property tests pin; larger `G` trades routing
//! freshness for advertisement traffic. Stale table entries are only a
//! placement pessimization — admission walks the real tree — and are
//! counted in [`GossipStats::stale_hits`].

pub mod gossip;

pub use gossip::DigestTable;

use crate::coordinator::{
    ClockHandle, RequestOutcome, SchedConfig, Scheduler, ServeResult,
    StepOutcome,
};
use crate::engine::Engine;
use crate::metrics::{Timeline, TimelinePoint};
use crate::prm::PrmScorer;
use crate::util::clock::SimClock;
use crate::util::rng::Rng;
use crate::workload::Request;
use anyhow::{bail, Result};

/// Multiplier used to decorrelate per-replica seed streams (replica 0
/// keeps the base seed, preserving the R = 1 reduction).
pub const REPLICA_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Load-balancing policy of the dispatch layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LbPolicy {
    /// Cyclic assignment, blind to load.
    RoundRobin,
    /// Fewest running (decoding) tokens at the arrival instant.
    LeastLoaded,
    /// Fewest requests in system (queued + in flight).
    JoinShortestQueue,
    /// Sample two distinct replicas, join the shorter queue — JSQ's tail
    /// behaviour at O(1) probe cost (Mitzenmacher's power of two choices).
    PowerOfTwoChoices,
    /// Route to the replica whose radix prefix cache holds the longest
    /// prefix of the request's prompt (ties broken by fewest requests in
    /// system); cold prompts fall back to power-of-two-choices. This is
    /// what turns the per-replica cache into a cluster-level one: the
    /// same few-shot template keeps landing where its pages already live.
    PrefixAffinity,
}

impl LbPolicy {
    pub const ALL: [LbPolicy; 5] = [
        LbPolicy::RoundRobin,
        LbPolicy::LeastLoaded,
        LbPolicy::JoinShortestQueue,
        LbPolicy::PowerOfTwoChoices,
        LbPolicy::PrefixAffinity,
    ];

    /// Parse a `--lb` flag value.
    pub fn parse(s: &str) -> Result<LbPolicy> {
        Ok(match s {
            "rr" | "round-robin" => LbPolicy::RoundRobin,
            "ll" | "least-loaded" => LbPolicy::LeastLoaded,
            "jsq" | "join-shortest-queue" => LbPolicy::JoinShortestQueue,
            "p2c" | "power-of-two" => LbPolicy::PowerOfTwoChoices,
            "aff" | "prefix-affinity" => LbPolicy::PrefixAffinity,
            _ => bail!(
                "unknown lb policy `{s}` (rr|least-loaded|jsq|p2c|\
                 prefix-affinity)"
            ),
        })
    }

    /// Canonical flag spelling.
    pub fn label(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "round-robin",
            LbPolicy::LeastLoaded => "least-loaded",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::PowerOfTwoChoices => "p2c",
            LbPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    /// Short identifier for metric keys (`BENCH_cluster.json`).
    pub fn slug(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "rr",
            LbPolicy::LeastLoaded => "ll",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::PowerOfTwoChoices => "p2c",
            LbPolicy::PrefixAffinity => "aff",
        }
    }
}

/// Everything one cluster serve needs beyond the engines themselves.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: usize,
    pub lb: LbPolicy,
    /// Per-replica scheduler configuration. The seed is decorrelated per
    /// replica (`seed ^ i * REPLICA_SEED_STRIDE`); replica 0 keeps it
    /// verbatim so R = 1 reduces exactly to the single-engine path.
    pub sched: SchedConfig,
    /// Dispatcher RNG seed (power-of-two-choices sampling).
    pub seed: u64,
    /// Enable per-round audit cross-checks in every replica (tests).
    pub audit: bool,
    /// Prefix-digest gossip period for [`LbPolicy::PrefixAffinity`]: a
    /// replica re-advertises its digest set after running this many
    /// scheduler steps since its last advertisement. 0 = probe every
    /// replica's tree per arrival (the pre-gossip behaviour, property-
    /// tested byte-identical to gossip with fresh advertisements).
    pub gossip_rounds: usize,
}

/// Gossip-layer accounting of one cluster serve (all zero when gossip is
/// off or the policy never consults it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GossipStats {
    /// The configured advertisement period (`ClusterConfig::gossip_rounds`).
    pub gossip_rounds: usize,
    /// Full-state advertisements replicas pushed into the digest table.
    pub advertisements: usize,
    /// Σ advertised digests across replicas at the end of the serve.
    pub digest_table_digests: usize,
    /// Requests routed on a table match the replica could no longer fully
    /// honour at admission (evicted between advertisement and admission):
    /// the replica re-prefilled the difference. A routing pessimization,
    /// never a correctness issue.
    pub stale_hits: usize,
    /// Per-replica radix-tree probes made by routing decisions (O(R) per
    /// prefix-affinity arrival in probe mode; 0 with gossip on — the
    /// dispatch-cost headline of BENCH_gossip.json).
    pub probe_calls: usize,
}

/// Result of a cluster serve.
pub struct ClusterResult {
    /// Merged outcomes in global dispatch (= arrival) order.
    pub outcomes: Vec<RequestOutcome>,
    /// Per-replica serve results (timelines share the t = 0 origin).
    /// Their `outcomes` vectors are empty: the k-way merge *moves* each
    /// outcome into the merged list above instead of cloning it.
    pub replica_results: Vec<ServeResult>,
    /// Replica index each trace position was dispatched to.
    pub assignments: Vec<usize>,
    pub lb: LbPolicy,
    /// Gossip-layer accounting (advertisements, table size, stale hits,
    /// probe calls). All zero except `gossip_rounds` when the policy
    /// never consulted the digest table.
    pub gossip: GossipStats,
    pub wall_seconds: f64,
}

impl ClusterResult {
    /// Cluster-wide occupancy timeline: a sweep over every replica's
    /// sample times emitting, at each event, the *sum* of each replica's
    /// latest state — so `peak_branches()` etc. report cluster totals,
    /// not one replica's snapshot. (A drained replica's last sample is
    /// all-zero, so it stops contributing.) Per-replica views stay in
    /// `replica_results`.
    pub fn merged_timeline(&self) -> Timeline {
        let mut events: Vec<(f64, usize, usize)> = Vec::new();
        for (ri, r) in self.replica_results.iter().enumerate() {
            for (pi, p) in r.timeline.points.iter().enumerate() {
                events.push((p.t, ri, pi));
            }
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut last: Vec<Option<&TimelinePoint>> =
            vec![None; self.replica_results.len()];
        let mut points = Vec::with_capacity(events.len());
        for (t, ri, pi) in events {
            last[ri] = Some(&self.replica_results[ri].timeline.points[pi]);
            let mut agg = TimelinePoint {
                t,
                running_branches: 0,
                decoding_branches: 0,
                running_tokens: 0,
                kv_pages_used: 0,
                queued_requests: 0,
                cache_hit_tokens: 0,
                queued_prefill_tokens: 0,
                prefill_seconds: 0.0,
            };
            for l in last.iter().flatten() {
                agg.running_branches += l.running_branches;
                agg.decoding_branches += l.decoding_branches;
                agg.running_tokens += l.running_tokens;
                agg.kv_pages_used += l.kv_pages_used;
                agg.queued_requests += l.queued_requests;
                // Per-replica values are cumulative, so the sum is the
                // cluster-wide cumulative hit count (same for prefill
                // seconds below).
                agg.cache_hit_tokens += l.cache_hit_tokens;
                agg.queued_prefill_tokens += l.queued_prefill_tokens;
                agg.prefill_seconds += l.prefill_seconds;
            }
            points.push(agg);
        }
        Timeline { points }
    }

    /// Cluster-wide prefix-cache hit rate: Σ cache-covered prompt tokens
    /// over Σ admitted prompt tokens, across all replicas. 0.0 with the
    /// cache disabled (or before any admission).
    pub fn cache_hit_rate(&self) -> f64 {
        let hit: usize =
            self.replica_results.iter().map(|r| r.cache_hit_tokens).sum();
        let total: usize =
            self.replica_results.iter().map(|r| r.prompt_tokens).sum();
        if total > 0 {
            hit as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Aggregate per-replica occupancy / skew statistics.
    pub fn report(&self) -> ClusterReport {
        let replicas = self.replica_results.len();
        let mut per_replica_requests = vec![0usize; replicas];
        for &rep in &self.assignments {
            per_replica_requests[rep] += 1;
        }
        // Occupancy integrated over the *cluster* horizon, not each
        // replica's own busy span — a replica that drains early and then
        // idles must read as lightly loaded, or round-robin's
        // leave-one-idle imbalance would show a skew of ~1.0.
        let horizon = self
            .replica_results
            .iter()
            .filter_map(|r| r.timeline.points.last().map(|p| p.t))
            .fold(0.0f64, f64::max);
        let per_replica_mean_branches: Vec<f64> = self
            .replica_results
            .iter()
            .map(|r| {
                let mut area = 0.0;
                for w in r.timeline.points.windows(2) {
                    area += w[0].running_branches as f64
                        * (w[1].t - w[0].t).max(0.0);
                }
                if horizon > 0.0 {
                    area / horizon
                } else {
                    0.0
                }
            })
            .collect();
        let per_replica_tokens: Vec<usize> = {
            let mut tok = vec![0usize; replicas];
            for (i, &rep) in self.assignments.iter().enumerate() {
                tok[rep] += self.outcomes[i].tokens_generated;
            }
            tok
        };
        let per_replica_engine_seconds: Vec<f64> = self
            .replica_results
            .iter()
            .map(|r| r.engine_seconds)
            .collect();
        ClusterReport {
            replicas,
            lb: self.lb.label().to_string(),
            cache_hit_rate: self.cache_hit_rate(),
            occupancy_skew: skew_f64(&per_replica_mean_branches),
            request_skew: skew_f64(
                &per_replica_requests
                    .iter()
                    .map(|&c| c as f64)
                    .collect::<Vec<_>>(),
            ),
            per_replica_requests,
            per_replica_mean_branches,
            per_replica_tokens,
            per_replica_engine_seconds,
            gossip: self.gossip,
        }
    }
}

/// Cluster-level aggregate handed to reports/benches: how evenly did the
/// dispatch policy spread work across replicas?
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub replicas: usize,
    pub lb: String,
    pub per_replica_requests: Vec<usize>,
    /// Running branches per replica integrated over the cluster horizon
    /// (the latest sample time across all replicas), so idle tails count
    /// as zero load.
    pub per_replica_mean_branches: Vec<f64>,
    pub per_replica_tokens: Vec<usize>,
    pub per_replica_engine_seconds: Vec<f64>,
    /// max/mean of per-replica mean occupancy (1.0 = perfectly even).
    pub occupancy_skew: f64,
    /// max/mean of per-replica request counts (1.0 = perfectly even).
    pub request_skew: f64,
    /// Cluster-wide prefix-cache hit rate (0.0 with the cache disabled).
    pub cache_hit_rate: f64,
    /// Gossip-layer accounting (see [`GossipStats`]).
    pub gossip: GossipStats,
}

/// max/mean skew; 1.0 for empty or all-zero inputs.
fn skew_f64(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return 1.0;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if mean > 0.0 {
        max / mean
    } else {
        1.0
    }
}

/// Step `s` until its clock reaches `t` or it runs out of work. An idle
/// replica's state cannot change before its next dispatch, so stopping
/// early is exact, not an approximation. Returns the number of steps
/// worked (the gossip layer's advertisement clock — a replica's radix
/// tree can only change inside its own steps).
fn catch_up(s: &mut Scheduler, t: f64) -> Result<usize> {
    let mut steps = 0usize;
    while s.now() < t {
        match s.step()? {
            StepOutcome::Worked => steps += 1,
            StepOutcome::Idle => break,
        }
    }
    Ok(steps)
}

/// Two random probes, join the shorter queue (also the prefix-affinity
/// fallback for cold prompts, so both spellings stay in lockstep).
/// Caller guarantees ≥ 2 replicas (`pick_replica` short-circuits R = 1).
fn pick_p2c(scheds: &[Scheduler], rng: &mut Rng) -> usize {
    let r = scheds.len();
    debug_assert!(r >= 2, "p2c needs two replicas to probe");
    let a = rng.below(r);
    let mut b = rng.below(r - 1);
    if b >= a {
        b += 1;
    }
    if scheds[b].load().requests_in_system()
        < scheds[a].load().requests_in_system()
    {
        b
    } else {
        a
    }
}

/// Choose the replica for one arriving request. All load reads happen at
/// the arrival instant (the caller caught every replica up to it).
/// `probe_calls` is incremented at the probe site for every per-replica
/// radix-tree probe made (the dispatch-cost metric gossip removes), so
/// the published counter can never drift from the work actually done.
fn pick_replica(
    lb: LbPolicy,
    scheds: &[Scheduler],
    req: &Request,
    rr_next: &mut usize,
    rng: &mut Rng,
    probe_calls: &mut usize,
) -> usize {
    let r = scheds.len();
    if r == 1 {
        return 0;
    }
    match lb {
        LbPolicy::RoundRobin => {
            let i = *rr_next % r;
            *rr_next += 1;
            i
        }
        // Token load counts the in-flight prefill backlog too: a replica
        // mid-way through streaming a long cold header has committed to
        // that compute even though no decode tokens show it yet.
        LbPolicy::LeastLoaded => (0..r)
            .min_by_key(|&i| scheds[i].load().token_load())
            .unwrap_or(0),
        LbPolicy::JoinShortestQueue => (0..r)
            .min_by_key(|&i| scheds[i].load().requests_in_system())
            .unwrap_or(0),
        LbPolicy::PowerOfTwoChoices => pick_p2c(scheds, rng),
        LbPolicy::PrefixAffinity => {
            // Probe every replica's radix cache for the longest resident
            // prefix of this prompt; route to the best hit, breaking ties
            // by queue depth (then index, for determinism). A cold prompt
            // has no affinity anywhere — fall back to p2c. (Gossip mode
            // replaces this scan with `pick_gossip`.)
            let prompt = req.prompt_tokens();
            let hits: Vec<usize> = scheds
                .iter()
                .map(|s| {
                    *probe_calls += 1;
                    s.cached_prefix_tokens(&prompt)
                })
                .collect();
            let best = hits.iter().copied().max().unwrap_or(0);
            if best == 0 {
                return pick_p2c(scheds, rng);
            }
            (0..r)
                .filter(|&i| hits[i] == best)
                .min_by_key(|&i| (scheds[i].load().requests_in_system(), i))
                .unwrap_or(0)
        }
    }
}

/// Gossip-mode prefix affinity: route on the digest table instead of
/// probing trees. Same decision rule as the probe path — longest
/// advertised prefix, ties by fewest requests in system (then index),
/// cold → power-of-two-choices — so fresh advertisements reproduce probe
/// routing byte for byte (property-tested). Returns the chosen replica
/// and the advertised match length the table promised (0 on cold /
/// fallback routes; the caller compares it against the admission's
/// actual cache coverage to count stale hits).
fn pick_gossip(
    table: &DigestTable,
    scheds: &[Scheduler],
    req: &Request,
    rng: &mut Rng,
) -> (usize, usize) {
    debug_assert!(scheds.len() >= 2, "gossip routing needs replicas");
    let prompt = req.prompt_tokens();
    let (matched_tokens, candidates) = table.lookup(&prompt);
    if matched_tokens == 0 {
        return (pick_p2c(scheds, rng), 0);
    }
    let idx = candidates
        .into_iter()
        .min_by_key(|&i| (scheds[i].load().requests_in_system(), i))
        .unwrap_or(0);
    (idx, matched_tokens)
}

/// Serve a trace across `cfg.replicas` engine replicas (virtual time
/// only: each replica gets its own [`SimClock`], all sharing the trace's
/// t = 0 origin). `engines[i]` / `prms[i]` back replica `i`; the caller
/// owns their construction so tests and benches can wire arbitrary
/// substrates.
pub fn serve_cluster(
    cfg: &ClusterConfig,
    engines: &mut [Box<dyn Engine>],
    prms: &mut [Box<dyn PrmScorer>],
    trace: &[Request],
) -> Result<ClusterResult> {
    let r = cfg.replicas;
    if r == 0 {
        bail!("cluster needs at least one replica");
    }
    if engines.len() != r || prms.len() != r {
        bail!(
            "cluster wiring mismatch: {r} replicas but {} engines, {} prms",
            engines.len(),
            prms.len()
        );
    }
    for w in trace.windows(2) {
        if w[1].arrival < w[0].arrival {
            bail!("trace not sorted by arrival");
        }
    }
    let wall0 = std::time::Instant::now();

    let mut scheds: Vec<Scheduler> = engines
        .iter_mut()
        .zip(prms.iter_mut())
        .enumerate()
        .map(|(i, (e, p))| {
            let mut sc = cfg.sched.clone();
            sc.seed ^= (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
            let mut s = Scheduler::new(
                sc,
                e.as_mut(),
                p.as_mut(),
                ClockHandle::Sim(SimClock::new()),
            );
            s.set_audit(cfg.audit);
            s
        })
        .collect();

    let mut rng = Rng::new(cfg.seed ^ 0x00D1_5BA7);
    let mut rr_next = 0usize;
    let mut assignments = Vec::with_capacity(trace.len());
    // Gossip state: the digest table, each replica's steps since its
    // last advertisement, and the table-promised match per dispatch
    // (compared against admission-time coverage to count stale hits).
    let gossip_on =
        cfg.gossip_rounds > 0 && cfg.lb == LbPolicy::PrefixAffinity && r > 1;
    let mut table = DigestTable::new(r, cfg.sched.kv_page_tokens);
    let mut steps_since_advert = vec![0usize; r];
    let mut expected_match = vec![0usize; trace.len()];
    let mut probe_calls = 0usize;
    for (pos, req) in trace.iter().enumerate() {
        // Advance every replica to the arrival instant so the policy sees
        // true loads, then dispatch.
        for (i, s) in scheds.iter_mut().enumerate() {
            steps_since_advert[i] += catch_up(s, req.arrival)?;
        }
        let idx = if gossip_on {
            // Advertisement stepping: a replica whose gossip period
            // elapsed (≥ G steps of its own since the last push)
            // refreshes its table row before this routing decision.
            for (i, steps) in steps_since_advert.iter_mut().enumerate() {
                if *steps >= cfg.gossip_rounds {
                    table.advertise(i, scheds[i].advertised_digests());
                    *steps = 0;
                }
            }
            let (idx, expected) = pick_gossip(&table, &scheds, req, &mut rng);
            expected_match[pos] = expected;
            idx
        } else {
            pick_replica(
                cfg.lb,
                &scheds,
                req,
                &mut rr_next,
                &mut rng,
                &mut probe_calls,
            )
        };
        scheds[idx].dispatch(req.clone())?;
        assignments.push(idx);
    }
    // Drain every replica to completion.
    for s in scheds.iter_mut() {
        while s.step()? == StepOutcome::Worked {}
    }
    let mut replica_results = Vec::with_capacity(r);
    for s in scheds.iter_mut() {
        replica_results.push(s.finish()?);
    }

    // Merge outcomes back into global dispatch order (each replica's
    // outcomes are already in its own dispatch order). The merge *moves*
    // each outcome out of its replica result — `RequestOutcome` carries a
    // per-response length vector, so cloning every outcome was an O(total
    // responses) allocation storm on large traces.
    let mut drained: Vec<std::vec::IntoIter<RequestOutcome>> = replica_results
        .iter_mut()
        .map(|rr| std::mem::take(&mut rr.outcomes).into_iter())
        .collect();
    let mut outcomes = Vec::with_capacity(trace.len());
    for &rep in &assignments {
        outcomes.push(
            drained[rep]
                .next()
                .expect("replica produced fewer outcomes than assignments"),
        );
    }

    // Stale gossip hits: the table promised a prefix match the replica
    // could no longer fully serve by the time the request was admitted
    // (evicted between advertisement and admission — the request simply
    // re-prefilled the difference).
    let stale_hits = expected_match
        .iter()
        .zip(&outcomes)
        .filter(|&(&exp, o)| exp > 0 && o.cached_prompt_tokens < exp)
        .count();

    Ok(ClusterResult {
        outcomes,
        replica_results,
        assignments,
        lb: cfg.lb,
        gossip: GossipStats {
            gossip_rounds: cfg.gossip_rounds,
            advertisements: table.advertisements_total(),
            digest_table_digests: table.len(),
            stale_hits,
            probe_calls,
        },
        wall_seconds: wall0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lb_policy_parse_roundtrip() {
        for lb in LbPolicy::ALL {
            assert_eq!(LbPolicy::parse(lb.label()).unwrap(), lb);
            assert_eq!(LbPolicy::parse(lb.slug()).unwrap(), lb);
        }
        assert!(LbPolicy::parse("nope").is_err());
    }

    #[test]
    fn skew_edge_cases() {
        assert_eq!(skew_f64(&[]), 1.0);
        assert_eq!(skew_f64(&[0.0, 0.0]), 1.0);
        assert!((skew_f64(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((skew_f64(&[3.0, 1.0]) - 1.5).abs() < 1e-12);
    }
}
