//! Multi-replica serving: R independent engine replicas behind a
//! dispatch layer.
//!
//! The paper's efficiency claims are about batching more requests under a
//! fixed memory budget; serving heavy traffic needs the next layer up —
//! horizontal scale. A [`serve_cluster`] run owns R *replicas*, each a
//! full single-engine stack (its own [`Engine`], `KvCacheManager` and
//! [`Scheduler`] state), and assigns every arriving request to exactly
//! one replica via a pluggable [`LbPolicy`].
//!
//! # Virtual-time co-simulation
//!
//! Replicas run in parallel in deployment, so their timelines are
//! independent: each replica advances its own [`SimClock`] by its own
//! engine costs only. All clocks share the trace's `t = 0` origin, which
//! keeps per-replica timelines directly comparable and lets the merged
//! outcome set report cluster-level latency percentiles. The dispatcher
//! drives the replicas event-by-event: before assigning a request that
//! arrives at time `t`, every running replica is stepped forward until
//! its clock reaches `t` (or it idles), so load-aware policies observe
//! each replica's true state *at the arrival instant* — not a stale
//! snapshot.
//!
//! A busy replica may overshoot `t` mid-round; that is exactly the
//! single-engine semantics, where a request arriving during a decode
//! round is admitted at the next round boundary.
//!
//! # Exact reduction at R = 1
//!
//! With one replica every request is dispatched to it in arrival order
//! and the step sequence is identical to [`Scheduler::serve`] on the same
//! trace, so outcomes and timeline are byte-identical — the property
//! tests assert this for every policy. The layer therefore costs nothing
//! to keep on the single-engine path.
//!
//! # Prefix-digest gossip (`gossip_rounds`)
//!
//! With `gossip_rounds = 0`, [`LbPolicy::PrefixAffinity`] probes every
//! replica's radix tree per arrival — O(R) tree walks on the dispatch
//! hot path. With `gossip_rounds = G ≥ 1`, each replica instead
//! re-advertises its resident prefix-digest set into a [`DigestTable`]
//! once it has run `G` scheduler steps since its last advertisement
//! (checked at each arrival instant, mirroring how a deployment's gossip
//! period is measured in replica rounds, not dispatcher events), and
//! routing becomes a table lookup: longest advertised prefix match, ties
//! broken by fewest requests in system, cold prompts falling back to
//! power-of-two-choices. `G = 1` keeps the table exactly as fresh as the
//! probes (a replica's tree only changes inside its own steps), which is
//! what the byte-identity property tests pin; larger `G` trades routing
//! freshness for advertisement traffic. Stale table entries are only a
//! placement pessimization — admission walks the real tree — and are
//! counted in [`GossipStats::stale_hits`].
//!
//! Advertisements travel as **version-keyed deltas**: a replica's first
//! push (and a cold rejoin after a failure) is a full snapshot, every
//! later one carries just the digests added and retracted since — see
//! [`crate::kvcache::Advertisement`]. A delta whose base version no
//! longer matches the table row falls back to a forced full snapshot,
//! so the table never applies a change set against the wrong base.
//! With `--gossip-adapt`, the dispatcher additionally tunes the period
//! at runtime from the replicas' own stale-admission counts: a window
//! with too many stale table routes halves the period (fresher table),
//! a clean window doubles it back toward the configured `G`.
//!
//! # Fault injection and elasticity
//!
//! A [`FaultPlan`] (`--fault-plan fail@2.5:1,restart@6.0:1`) scripts
//! replica failures and restarts in *virtual* time; the dispatcher
//! applies each event between steps, so a faulted serve is exactly as
//! deterministic as a fault-free one. On a failure the victim's
//! in-flight requests are re-dispatched to the surviving replicas
//! (re-prefilled — its KV cache died with it; outcomes record
//! [`RequestOutcome::redispatches`] and the added latency from the
//! *original* arrival), its [`DigestTable`] row is retracted so routing
//! degrades to power-of-two-choices instead of routing into a corpse,
//! and a later restart rejoins the replica cold, re-warming through the
//! ordinary gossip path. A [`ScaleConfig`] drives a queue-pressure scale
//! controller through the same join/drain machinery: sustained queue
//! depth (or chunked-prefill backlog) above threshold activates a
//! standby replica, pressure below the hysteresis band drains the live
//! replica with the smallest chunked-prefill backlog (highest index on
//! ties — draining a replica mid-prefill forfeits the most queued
//! work). The zero-fault path — empty plan, no scale controller — is
//! property-tested byte-identical to a plan-less serve.

pub mod fault;
pub mod gossip;

pub use fault::{FaultEvent, FaultKind, FaultPlan, FaultStats, ScaleConfig};
pub use gossip::DigestTable;

use crate::coordinator::{
    ClockHandle, DrainItem, RequestOutcome, SchedConfig, Scheduler,
    ServeEvent, ServeResult, StepOutcome,
};
use crate::engine::Engine;
use crate::kvcache::Advertisement;
use crate::metrics::{Timeline, TimelinePoint};
use crate::prm::PrmScorer;
use crate::util::clock::SimClock;
use crate::util::rng::Rng;
use crate::workload::Request;
use anyhow::{bail, Context, Result};

/// Multiplier used to decorrelate per-replica seed streams (replica 0
/// keeps the base seed, preserving the R = 1 reduction).
pub const REPLICA_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Table-routed admissions per adaptation window of the `--gossip-adapt`
/// controller: the period only moves once this many routing decisions
/// actually tested the table, so idle traffic cannot flap it.
const GOSSIP_ADAPT_WINDOW: usize = 8;

/// Load-balancing policy of the dispatch layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LbPolicy {
    /// Cyclic assignment, blind to load.
    RoundRobin,
    /// Fewest running (decoding) tokens at the arrival instant.
    LeastLoaded,
    /// Fewest requests in system (queued + in flight).
    JoinShortestQueue,
    /// Sample two distinct replicas, join the shorter queue — JSQ's tail
    /// behaviour at O(1) probe cost (Mitzenmacher's power of two choices).
    PowerOfTwoChoices,
    /// Route to the replica whose radix prefix cache holds the longest
    /// prefix of the request's prompt (ties broken by fewest requests in
    /// system); cold prompts fall back to power-of-two-choices. This is
    /// what turns the per-replica cache into a cluster-level one: the
    /// same few-shot template keeps landing where its pages already live.
    PrefixAffinity,
}

impl LbPolicy {
    pub const ALL: [LbPolicy; 5] = [
        LbPolicy::RoundRobin,
        LbPolicy::LeastLoaded,
        LbPolicy::JoinShortestQueue,
        LbPolicy::PowerOfTwoChoices,
        LbPolicy::PrefixAffinity,
    ];

    /// Parse a `--lb` flag value.
    pub fn parse(s: &str) -> Result<LbPolicy> {
        Ok(match s {
            "rr" | "round-robin" => LbPolicy::RoundRobin,
            "ll" | "least-loaded" => LbPolicy::LeastLoaded,
            "jsq" | "join-shortest-queue" => LbPolicy::JoinShortestQueue,
            "p2c" | "power-of-two" => LbPolicy::PowerOfTwoChoices,
            "aff" | "prefix-affinity" => LbPolicy::PrefixAffinity,
            _ => bail!(
                "unknown lb policy `{s}` (rr|least-loaded|jsq|p2c|\
                 prefix-affinity)"
            ),
        })
    }

    /// Canonical flag spelling.
    pub fn label(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "round-robin",
            LbPolicy::LeastLoaded => "least-loaded",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::PowerOfTwoChoices => "p2c",
            LbPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    /// Short identifier for metric keys (`BENCH_cluster.json`).
    pub fn slug(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "rr",
            LbPolicy::LeastLoaded => "ll",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::PowerOfTwoChoices => "p2c",
            LbPolicy::PrefixAffinity => "aff",
        }
    }
}

/// Everything one cluster serve needs beyond the engines themselves.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: usize,
    pub lb: LbPolicy,
    /// Per-replica scheduler configuration. The seed is decorrelated per
    /// replica (`seed ^ i * REPLICA_SEED_STRIDE`); replica 0 keeps it
    /// verbatim so R = 1 reduces exactly to the single-engine path.
    pub sched: SchedConfig,
    /// Dispatcher RNG seed (power-of-two-choices sampling).
    pub seed: u64,
    /// Enable per-round audit cross-checks in every replica (tests).
    pub audit: bool,
    /// Prefix-digest gossip period for [`LbPolicy::PrefixAffinity`]: a
    /// replica re-advertises its digest set after running this many
    /// scheduler steps since its last advertisement. 0 = probe every
    /// replica's tree per arrival (the pre-gossip behaviour, property-
    /// tested byte-identical to gossip with fresh advertisements).
    pub gossip_rounds: usize,
    /// Adapt the gossip period at runtime from observed stale table
    /// routes (halve on a stale window, double back toward
    /// `gossip_rounds` on a clean one). Off by default; the final period
    /// is reported in [`GossipStats::effective_gossip_rounds`].
    pub gossip_adapt: bool,
    /// Scripted replica failures/restarts in virtual time. The default
    /// empty plan is inert (property-tested byte-identical).
    pub fault_plan: FaultPlan,
    /// Queue-pressure scale controller; `None` keeps the replica set
    /// static (every replica live from t = 0).
    pub scale: Option<ScaleConfig>,
}

/// Gossip-layer accounting of one cluster serve (all zero when gossip is
/// off or the policy never consults it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GossipStats {
    /// The configured advertisement period (`ClusterConfig::gossip_rounds`).
    pub gossip_rounds: usize,
    /// The period in force when the serve ended — equal to
    /// `gossip_rounds` unless `--gossip-adapt` moved it.
    pub effective_gossip_rounds: usize,
    /// Advertisements replicas pushed into the digest table (full
    /// snapshots + applied deltas).
    pub advertisements: usize,
    /// Full-snapshot advertisements among them (first pushes, cold
    /// rejoins, delta-base-mismatch fallbacks).
    pub full_advertisements: usize,
    /// Delta advertisements successfully applied.
    pub delta_advertisements: usize,
    /// Σ digests carried on the wire by all advertisements — the traffic
    /// the delta protocol exists to shrink.
    pub digests_sent: usize,
    /// Σ advertised digests across replicas at the end of the serve.
    pub digest_table_digests: usize,
    /// Requests routed on a table match the replica could no longer fully
    /// honour at admission (evicted between advertisement and admission):
    /// the replica re-prefilled the difference. A routing pessimization,
    /// never a correctness issue.
    pub stale_hits: usize,
    /// Per-replica radix-tree probes made by routing decisions (O(R) per
    /// prefix-affinity arrival in probe mode; 0 with gossip on — the
    /// dispatch-cost headline of BENCH_gossip.json).
    pub probe_calls: usize,
}

/// Result of a cluster serve.
pub struct ClusterResult {
    /// Merged outcomes in trace (= arrival) order. A re-dispatched
    /// request's outcome keeps its *original* arrival — the re-dispatch
    /// delay shows up in its latencies — and records the re-dispatch
    /// count in [`RequestOutcome::redispatches`].
    pub outcomes: Vec<RequestOutcome>,
    /// Per-replica serve results (timelines share the t = 0 origin).
    /// Their `outcomes` vectors are empty: the merge *moves* each
    /// outcome into the merged list above instead of cloning it. A
    /// replica that failed and restarted contributes the concatenation
    /// of its incarnations' timelines (cumulative per-point counters
    /// restart from zero at the rejoin).
    pub replica_results: Vec<ServeResult>,
    /// Replica that ultimately *served* each trace position (the final
    /// dispatch target after any failure re-dispatches).
    pub assignments: Vec<usize>,
    pub lb: LbPolicy,
    /// Gossip-layer accounting (advertisements, table size, stale hits,
    /// probe calls). All zero except `gossip_rounds` when the policy
    /// never consulted the digest table.
    pub gossip: GossipStats,
    /// Fault/elasticity accounting (all zero on a fault-free static
    /// serve).
    pub fault: FaultStats,
    /// Digests each replica's table row advertised at the end of the
    /// serve — the re-warm observable: a restarted replica's row grows
    /// back from zero through the ordinary gossip path.
    pub digest_rows: Vec<usize>,
    pub wall_seconds: f64,
}

impl ClusterResult {
    /// Cluster-wide occupancy timeline: a sweep over every replica's
    /// sample times emitting, at each event, the *sum* of each replica's
    /// latest state — so `peak_branches()` etc. report cluster totals,
    /// not one replica's snapshot. (A drained replica's last sample is
    /// all-zero, so it stops contributing; a failed replica closes with
    /// an explicit zero-occupancy sample at the failure instant.)
    /// Per-replica views stay in `replica_results`.
    pub fn merged_timeline(&self) -> Timeline {
        let mut events: Vec<(f64, usize, usize)> = Vec::new();
        for (ri, r) in self.replica_results.iter().enumerate() {
            for (pi, p) in r.timeline.points.iter().enumerate() {
                events.push((p.t, ri, pi));
            }
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut last: Vec<Option<&TimelinePoint>> =
            vec![None; self.replica_results.len()];
        let mut points = Vec::with_capacity(events.len());
        for (t, ri, pi) in events {
            last[ri] = Some(&self.replica_results[ri].timeline.points[pi]);
            let mut agg = TimelinePoint {
                t,
                running_branches: 0,
                decoding_branches: 0,
                running_tokens: 0,
                kv_pages_used: 0,
                queued_requests: 0,
                cache_hit_tokens: 0,
                queued_prefill_tokens: 0,
                prefill_seconds: 0.0,
            };
            for l in last.iter().flatten() {
                agg.running_branches += l.running_branches;
                agg.decoding_branches += l.decoding_branches;
                agg.running_tokens += l.running_tokens;
                agg.kv_pages_used += l.kv_pages_used;
                agg.queued_requests += l.queued_requests;
                // Per-replica values are cumulative, so the sum is the
                // cluster-wide cumulative hit count (same for prefill
                // seconds below).
                agg.cache_hit_tokens += l.cache_hit_tokens;
                agg.queued_prefill_tokens += l.queued_prefill_tokens;
                agg.prefill_seconds += l.prefill_seconds;
            }
            points.push(agg);
        }
        Timeline { points }
    }

    /// Cluster-wide prefix-cache hit rate: Σ cache-covered prompt tokens
    /// over Σ admitted prompt tokens, across all replicas. 0.0 with the
    /// cache disabled (or before any admission).
    pub fn cache_hit_rate(&self) -> f64 {
        let hit: usize =
            self.replica_results.iter().map(|r| r.cache_hit_tokens).sum();
        let total: usize =
            self.replica_results.iter().map(|r| r.prompt_tokens).sum();
        if total > 0 {
            hit as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Aggregate per-replica occupancy / skew statistics.
    pub fn report(&self) -> ClusterReport {
        let replicas = self.replica_results.len();
        let mut per_replica_requests = vec![0usize; replicas];
        for &rep in &self.assignments {
            per_replica_requests[rep] += 1;
        }
        // Occupancy integrated over the *cluster* horizon, not each
        // replica's own busy span — a replica that drains early and then
        // idles must read as lightly loaded, or round-robin's
        // leave-one-idle imbalance would show a skew of ~1.0.
        let horizon = self
            .replica_results
            .iter()
            .filter_map(|r| r.timeline.points.last().map(|p| p.t))
            .fold(0.0f64, f64::max);
        let per_replica_mean_branches: Vec<f64> = self
            .replica_results
            .iter()
            .map(|r| {
                let mut area = 0.0;
                for w in r.timeline.points.windows(2) {
                    area += w[0].running_branches as f64
                        * (w[1].t - w[0].t).max(0.0);
                }
                if horizon > 0.0 {
                    area / horizon
                } else {
                    0.0
                }
            })
            .collect();
        let per_replica_tokens: Vec<usize> = {
            let mut tok = vec![0usize; replicas];
            for (i, &rep) in self.assignments.iter().enumerate() {
                tok[rep] += self.outcomes[i].tokens_generated;
            }
            tok
        };
        let per_replica_engine_seconds: Vec<f64> = self
            .replica_results
            .iter()
            .map(|r| r.engine_seconds)
            .collect();
        ClusterReport {
            replicas,
            lb: self.lb.label().to_string(),
            cache_hit_rate: self.cache_hit_rate(),
            occupancy_skew: skew_f64(&per_replica_mean_branches),
            request_skew: skew_f64(
                &per_replica_requests
                    .iter()
                    .map(|&c| c as f64)
                    .collect::<Vec<_>>(),
            ),
            per_replica_requests,
            per_replica_mean_branches,
            per_replica_tokens,
            per_replica_engine_seconds,
            gossip: self.gossip,
            fault: self.fault,
        }
    }
}

/// Cluster-level aggregate handed to reports/benches: how evenly did the
/// dispatch policy spread work across replicas?
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub replicas: usize,
    pub lb: String,
    pub per_replica_requests: Vec<usize>,
    /// Running branches per replica integrated over the cluster horizon
    /// (the latest sample time across all replicas), so idle tails count
    /// as zero load.
    pub per_replica_mean_branches: Vec<f64>,
    pub per_replica_tokens: Vec<usize>,
    pub per_replica_engine_seconds: Vec<f64>,
    /// max/mean of per-replica mean occupancy (1.0 = perfectly even).
    pub occupancy_skew: f64,
    /// max/mean of per-replica request counts (1.0 = perfectly even).
    pub request_skew: f64,
    /// Cluster-wide prefix-cache hit rate (0.0 with the cache disabled).
    pub cache_hit_rate: f64,
    /// Gossip-layer accounting (see [`GossipStats`]).
    pub gossip: GossipStats,
    /// Fault/elasticity accounting (see [`FaultStats`]).
    pub fault: FaultStats,
}

/// max/mean skew; 1.0 for empty or all-zero inputs.
fn skew_f64(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return 1.0;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if mean > 0.0 {
        max / mean
    } else {
        1.0
    }
}

/// Step `s` until its clock reaches `t` or it runs out of work. An idle
/// replica's state cannot change before its next dispatch, so stopping
/// early is exact, not an approximation. Returns the number of steps
/// worked (the gossip layer's advertisement clock — a replica's radix
/// tree can only change inside its own steps).
fn catch_up(s: &mut Scheduler, t: f64) -> Result<usize> {
    let mut steps = 0usize;
    while s.now() < t {
        match s.step()? {
            StepOutcome::Worked => steps += 1,
            StepOutcome::Idle => break,
        }
    }
    Ok(steps)
}

/// Where a replica is in its lifecycle, from the dispatcher's seat.
/// Shared with the wall-clock front end, whose live fault/scale path
/// tracks replicas through the same lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Routed to and stepped.
    Live,
    /// Draining (scale-down): no new requests, but still stepped until
    /// its in-flight work finishes. The scale controller re-activates
    /// draining replicas first — their caches are still warm.
    Draining,
    /// Failed or never started: neither routed to nor stepped. Restart
    /// or scale-up returns it to `Live` with its clock jumped forward.
    Down,
}

/// All mutable dispatcher state of one cluster serve, so the event pump
/// (arrivals, scripted faults, scale actions) is ordinary methods
/// instead of a parameter blizzard.
struct Fleet<'e> {
    lb: LbPolicy,
    gossip_on: bool,
    gossip_adapt: bool,
    /// Configured advertisement period (the adaptive period's ceiling).
    gossip_rounds_cfg: usize,
    scale: Option<ScaleConfig>,
    scheds: Vec<Scheduler<'e>>,
    state: Vec<ReplicaState>,
    table: DigestTable,
    steps_since_advert: Vec<usize>,
    /// Advertisement period currently in force (== `gossip_rounds_cfg`
    /// unless `--gossip-adapt` moved it).
    period: usize,
    /// `(table-routed, stale)` totals at the last adaptation decision.
    adapt_mark: (usize, usize),
    /// Gossip-observation counters retired by failed incarnations
    /// (`fail_and_drain` zeroes the scheduler's own), keeping the
    /// adaptation totals monotone across failures.
    retired_observed: (usize, usize),
    /// Trace positions dispatched to each replica's *current*
    /// incarnation, in dispatch order — the key that maps drained items
    /// and finished outcomes back to trace positions.
    dispatch_log: Vec<Vec<usize>>,
    /// Final dispatch target per trace position.
    assignments: Vec<usize>,
    outcomes_by_pos: Vec<Option<RequestOutcome>>,
    redispatch_count: Vec<usize>,
    /// Table-promised prefix match per trace position (stale-hit
    /// accounting; overwritten if the request is re-dispatched).
    expected_match: Vec<usize>,
    /// Partial results of failed incarnations, per replica.
    incarnations: Vec<Vec<ServeResult>>,
    stats: FaultStats,
    rr_next: usize,
    rng: Rng,
    probe_calls: usize,
    /// Arrivals since the last scale action (controller cooldown).
    since_scale: usize,
}

impl<'e> Fleet<'e> {
    fn live(&self) -> Vec<usize> {
        (0..self.state.len())
            .filter(|&i| self.state[i] == ReplicaState::Live)
            .collect()
    }

    /// Advance every running (live or draining) replica to `t`.
    fn catch_up_running(&mut self, t: f64) -> Result<()> {
        for i in 0..self.scheds.len() {
            if self.state[i] != ReplicaState::Down {
                self.steps_since_advert[i] +=
                    catch_up(&mut self.scheds[i], t)?;
            }
        }
        Ok(())
    }

    /// Two random probes among `live`, join the shorter queue (also the
    /// prefix-affinity fallback for cold prompts, so both spellings stay
    /// in lockstep). Caller guarantees ≥ 2 candidates.
    fn pick_p2c(&mut self, live: &[usize]) -> usize {
        debug_assert!(live.len() >= 2, "p2c needs two replicas to probe");
        let a = self.rng.below(live.len());
        let mut b = self.rng.below(live.len() - 1);
        if b >= a {
            b += 1;
        }
        let (a, b) = (live[a], live[b]);
        if self.scheds[b].load().requests_in_system()
            < self.scheds[a].load().requests_in_system()
        {
            b
        } else {
            a
        }
    }

    /// Probe-mode policy dispatch over the live replicas. All load reads
    /// happen at the arrival instant (the caller caught every replica up
    /// to it). `probe_calls` counts every per-replica radix-tree probe
    /// at the probe site, so the published counter can never drift from
    /// the work actually done.
    fn pick_replica(&mut self, live: &[usize], req: &Request) -> usize {
        debug_assert!(live.len() >= 2, "single-survivor routing is forced");
        match self.lb {
            LbPolicy::RoundRobin => {
                let i = live[self.rr_next % live.len()];
                self.rr_next += 1;
                i
            }
            // Token load counts the in-flight prefill backlog too: a
            // replica mid-way through streaming a long cold header has
            // committed to that compute even though no decode tokens
            // show it yet.
            LbPolicy::LeastLoaded => live
                .iter()
                .copied()
                .min_by_key(|&i| self.scheds[i].load().token_load())
                .unwrap_or(live[0]),
            LbPolicy::JoinShortestQueue => live
                .iter()
                .copied()
                .min_by_key(|&i| self.scheds[i].load().requests_in_system())
                .unwrap_or(live[0]),
            LbPolicy::PowerOfTwoChoices => self.pick_p2c(live),
            LbPolicy::PrefixAffinity => {
                // Probe every live replica's radix cache for the longest
                // resident prefix of this prompt; route to the best hit,
                // breaking ties by queue depth (then index, for
                // determinism). A cold prompt has no affinity anywhere —
                // fall back to p2c. (Gossip mode replaces this scan with
                // the digest-table lookup.)
                let prompt = req.prompt_tokens();
                let hits: Vec<(usize, usize)> = live
                    .iter()
                    .map(|&i| {
                        self.probe_calls += 1;
                        (i, self.scheds[i].cached_prefix_tokens(&prompt))
                    })
                    .collect();
                let best =
                    hits.iter().map(|&(_, h)| h).max().unwrap_or(0);
                if best == 0 {
                    return self.pick_p2c(live);
                }
                hits.into_iter()
                    .filter(|&(_, h)| h == best)
                    .map(|(i, _)| i)
                    .min_by_key(|&i| {
                        (self.scheds[i].load().requests_in_system(), i)
                    })
                    .unwrap_or(live[0])
            }
        }
    }

    /// Push due advertisements into the digest table: full snapshot on a
    /// replica's first take (or cold rejoin), deltas afterwards, with a
    /// forced full snapshot if a delta's base no longer matches the row.
    fn refresh_adverts(&mut self) {
        for i in 0..self.scheds.len() {
            if self.state[i] == ReplicaState::Down
                || self.steps_since_advert[i] < self.period
            {
                continue;
            }
            match self.scheds[i].take_advertisement() {
                Advertisement::Full { version, digests } => {
                    self.table.advertise_full(i, version, digests);
                }
                Advertisement::Delta(d) => {
                    if !self.table.apply_delta(i, &d) {
                        let (v, ds) = self.scheds[i].full_advertisement();
                        self.table.advertise_full(i, v, ds);
                    }
                }
            }
            self.steps_since_advert[i] = 0;
        }
    }

    /// `--gossip-adapt`: retune the advertisement period from the
    /// replicas' own admission-time staleness counts. Stale table routes
    /// above 1/4 of a window halve the period (fresher table at more
    /// advertisement traffic); a perfectly clean window doubles it back
    /// toward the configured ceiling.
    fn adapt_period(&mut self) {
        if !self.gossip_adapt {
            return;
        }
        let (mut routed, mut stale) = self.retired_observed;
        for s in &self.scheds {
            let (r0, s0) = s.gossip_observed();
            routed += r0;
            stale += s0;
        }
        let dr = routed - self.adapt_mark.0;
        if dr < GOSSIP_ADAPT_WINDOW {
            return;
        }
        let ds = stale - self.adapt_mark.1;
        if ds * 4 > dr {
            self.period = (self.period / 2).max(1);
        } else if ds == 0 {
            self.period = (self.period * 2).min(self.gossip_rounds_cfg);
        }
        self.adapt_mark = (routed, stale);
    }

    /// Choose the replica for one request (arrival or re-dispatch).
    /// Returns `(replica, table-promised match tokens)`; the promise is
    /// 0 on probe-mode, fallback and forced routes. Errors when nothing
    /// is live to route to.
    fn route(&mut self, req: &Request) -> Result<(usize, usize)> {
        let live = self.live();
        if live.is_empty() {
            bail!("no live replica to dispatch to (all failed or drained)");
        }
        if live.len() == 1 {
            // Forced choice: consume no randomness, probe nothing —
            // mirroring the pinned R = 1 reduction.
            return Ok((live[0], 0));
        }
        if self.gossip_on {
            self.adapt_period();
            self.refresh_adverts();
            let prompt = req.prompt_tokens();
            let (matched, candidates) = self.table.lookup(&prompt);
            let viable: Vec<usize> = candidates
                .into_iter()
                .filter(|&i| self.state[i] == ReplicaState::Live)
                .collect();
            if matched == 0 || viable.is_empty() {
                return Ok((self.pick_p2c(&live), 0));
            }
            let idx = viable
                .into_iter()
                .min_by_key(|&i| {
                    (self.scheds[i].load().requests_in_system(), i)
                })
                .unwrap_or(live[0]);
            return Ok((idx, matched));
        }
        Ok((self.pick_replica(&live, req), 0))
    }

    /// Hand `req` (trace position `pos`) to replica `idx` and record the
    /// bookkeeping that later maps its outcome back to `pos`.
    fn dispatch_to(
        &mut self,
        idx: usize,
        pos: usize,
        req: Request,
        expected: usize,
    ) -> Result<()> {
        self.scheds[idx].dispatch_routed(req, expected)?;
        self.dispatch_log[idx].push(pos);
        self.assignments[pos] = idx;
        self.expected_match[pos] = expected;
        Ok(())
    }

    /// Apply one scripted fault event.
    fn apply_event(&mut self, e: &FaultEvent) -> Result<()> {
        match e.kind {
            FaultKind::Fail => self.fail_replica(e.replica, e.t),
            FaultKind::Restart => self.restart_replica(e.replica, e.t),
        }
    }

    /// Replica `f` dies at virtual time `t`: its in-flight requests are
    /// re-dispatched to survivors (re-prefilled — the cache died with
    /// it), finished-but-unreported outcomes are banked, its digest-table
    /// row is retracted so routing degrades to p2c instead of routing
    /// into a corpse, and the scheduler resets to a cold just-booted
    /// state awaiting a possible restart.
    fn fail_replica(&mut self, f: usize, t: f64) -> Result<()> {
        if self.state[f] == ReplicaState::Down {
            bail!(
                "fault plan fails replica {f} at t={t} but it is already \
                 down"
            );
        }
        // Bring every running replica to the failure instant: the
        // victim's in-flight state is its true state at t, and the
        // survivors' loads are current for re-dispatch routing.
        self.catch_up_running(t)?;
        let (routed, stale) = self.scheds[f].gossip_observed();
        self.retired_observed.0 += routed;
        self.retired_observed.1 += stale;
        let (items, partial) = self.scheds[f].fail_and_drain()?;
        self.incarnations[f].push(partial);
        let positions = std::mem::take(&mut self.dispatch_log[f]);
        if items.len() != positions.len() {
            bail!(
                "replica {f} drained {} items for {} dispatches",
                items.len(),
                positions.len()
            );
        }
        self.table.retract(f);
        self.steps_since_advert[f] = 0;
        self.state[f] = ReplicaState::Down;
        self.stats.failures += 1;

        let mut unfinished = Vec::new();
        for (item, pos) in items.into_iter().zip(positions) {
            match item {
                DrainItem::Finished(o) => {
                    self.outcomes_by_pos[pos] = Some(o);
                }
                DrainItem::Unfinished(mut req) => {
                    // A lost request cannot rejoin a queue before the
                    // failure is observed: it re-arrives at the failure
                    // instant (also what keeps per-replica dispatch
                    // order sorted by arrival). The merged outcome
                    // restores the original arrival, so the latency it
                    // reports includes the whole detour.
                    req.arrival = t;
                    unfinished.push((pos, req));
                }
            }
        }
        for (pos, req) in unfinished {
            let (idx, expected) = self.route(&req).with_context(|| {
                format!(
                    "re-dispatching request {} after replica {f} failed \
                     at t={t}",
                    req.id
                )
            })?;
            self.redispatch_count[pos] += 1;
            self.stats.redispatches += 1;
            self.dispatch_to(idx, pos, req, expected)?;
        }
        Ok(())
    }

    /// Replica `f` rejoins cold at virtual time `t`: live again, clock
    /// jumped to the rejoin instant, empty cache re-warming through the
    /// ordinary gossip path (its first advertisement is a Full snapshot
    /// — the fresh manager has nothing advertised).
    fn restart_replica(&mut self, f: usize, t: f64) -> Result<()> {
        if self.state[f] != ReplicaState::Down {
            bail!(
                "fault plan restarts replica {f} at t={t} but it is not \
                 down"
            );
        }
        self.scheds[f].advance_clock_to(t);
        self.state[f] = ReplicaState::Live;
        self.steps_since_advert[f] = 0;
        self.stats.restarts += 1;
        Ok(())
    }

    /// Queue-pressure scale controller, evaluated once per arrival
    /// (after catch-up, before routing). At most one action per call;
    /// `cooldown_arrivals` throttles consecutive actions and the gap
    /// between the up and down thresholds is the hysteresis band.
    fn scale_tick(&mut self, now: f64) {
        let Some(sc) = self.scale else { return };
        self.since_scale += 1;
        if self.since_scale < sc.cooldown_arrivals {
            return;
        }
        let live = self.live();
        let n = live.len();
        let queued: usize = live
            .iter()
            .map(|&i| self.scheds[i].load().requests_in_system())
            .sum();
        let backlog: usize = live
            .iter()
            .map(|&i| self.scheds[i].load().pending_prefill_tokens)
            .sum();
        let pressure = live
            .iter()
            .map(|&i| self.scheds[i].load().kv_pressure)
            .fold(0.0, f64::max);
        if sc.wants_scale_up(queued, backlog, pressure, n) {
            // Draining replicas re-activate first: their caches are
            // still warm. Cold standbys join at the current instant.
            let target = (0..self.state.len())
                .find(|&i| self.state[i] == ReplicaState::Draining)
                .or_else(|| {
                    (0..self.state.len())
                        .find(|&i| self.state[i] == ReplicaState::Down)
                });
            if let Some(i) = target {
                if self.state[i] == ReplicaState::Down {
                    self.scheds[i].advance_clock_to(now);
                    self.steps_since_advert[i] = 0;
                }
                self.state[i] = ReplicaState::Live;
                self.stats.scale_ups += 1;
                self.since_scale = 0;
            }
            return;
        }
        if sc.wants_scale_down(queued, n) {
            let backlogs: Vec<usize> = self
                .scheds
                .iter()
                .map(|s| s.load().pending_prefill_tokens)
                .collect();
            if let Some(i) = pick_drain_candidate(&self.state, &backlogs) {
                self.state[i] = ReplicaState::Draining;
                self.stats.scale_downs += 1;
                self.since_scale = 0;
            }
        }
    }
}

/// Latency-aware scale-down selection: among the Live replicas, drain
/// the one with the shallowest streamed-prefill backlog, breaking ties
/// by highest index (the historical choice — before backlogs were
/// consulted, the highest-index live replica always drained, which this
/// reproduces exactly whenever no replica is mid-prefill). Draining a
/// replica that still owes committed prefill work would park exactly
/// the requests that are most expensive to finish — their headers are
/// half-streamed and cannot move — so the controller prefers the
/// replica that can empty fastest.
pub fn pick_drain_candidate(
    state: &[ReplicaState],
    prefill_backlog: &[usize],
) -> Option<usize> {
    debug_assert_eq!(state.len(), prefill_backlog.len());
    (0..state.len())
        .rev()
        .filter(|&i| state[i] == ReplicaState::Live)
        .min_by_key(|&i| prefill_backlog[i])
}

/// Forward every replica's buffered events to the sink, tagged with the
/// replica index (no-op without a sink — emission is off then, so the
/// buffers stay empty).
fn pump_events(
    fleet: &mut Fleet,
    sink: &mut Option<&mut dyn FnMut(usize, ServeEvent)>,
) {
    let Some(s) = sink.as_deref_mut() else { return };
    for i in 0..fleet.scheds.len() {
        for ev in fleet.scheds[i].drain_events() {
            s(i, ev);
        }
    }
}

/// Concatenate the partial results of a replica's incarnations (failed
/// ones plus the final `finish()`) into one per-replica [`ServeResult`].
/// Timelines chain in time order — each incarnation's samples start
/// after the previous one's failure instant.
fn merge_incarnations(mut parts: Vec<ServeResult>) -> ServeResult {
    let mut merged = parts.remove(0);
    for p in parts {
        merged.timeline.points.extend(p.timeline.points);
        merged.rounds += p.rounds;
        merged.engine_seconds += p.engine_seconds;
        merged.cache_hit_tokens += p.cache_hit_tokens;
        merged.prompt_tokens += p.prompt_tokens;
        merged.adaptive.merge(p.adaptive);
    }
    merged
}

/// Serve a trace across `cfg.replicas` engine replicas (virtual time
/// only: each replica gets its own [`SimClock`], all sharing the trace's
/// t = 0 origin). `engines[i]` / `prms[i]` back replica `i`; the caller
/// owns their construction so tests and benches can wire arbitrary
/// substrates. Scripted faults (`cfg.fault_plan`) and the scale
/// controller (`cfg.scale`) are applied between steps, in event-time
/// order interleaved with arrivals.
pub fn serve_cluster(
    cfg: &ClusterConfig,
    engines: &mut [Box<dyn Engine>],
    prms: &mut [Box<dyn PrmScorer>],
    trace: &[Request],
) -> Result<ClusterResult> {
    serve_cluster_impl(cfg, engines, prms, trace, None)
}

/// [`serve_cluster`] as an explicit event pump: every replica scheduler
/// emits [`ServeEvent`]s and the fleet forwards them to `sink` tagged
/// with the replica index, after each dispatch round and drain pass.
/// Events of one replica arrive in emission order; cross-replica
/// interleaving follows the dispatcher's pump points. Scheduling is
/// byte-identical to [`serve_cluster`] (property-tested).
pub fn serve_cluster_with(
    cfg: &ClusterConfig,
    engines: &mut [Box<dyn Engine>],
    prms: &mut [Box<dyn PrmScorer>],
    trace: &[Request],
    sink: &mut dyn FnMut(usize, ServeEvent),
) -> Result<ClusterResult> {
    serve_cluster_impl(cfg, engines, prms, trace, Some(sink))
}

fn serve_cluster_impl(
    cfg: &ClusterConfig,
    engines: &mut [Box<dyn Engine>],
    prms: &mut [Box<dyn PrmScorer>],
    trace: &[Request],
    mut sink: Option<&mut dyn FnMut(usize, ServeEvent)>,
) -> Result<ClusterResult> {
    let r = cfg.replicas;
    if r == 0 {
        bail!("cluster needs at least one replica");
    }
    if engines.len() != r || prms.len() != r {
        bail!(
            "cluster wiring mismatch: {r} replicas but {} engines, {} prms",
            engines.len(),
            prms.len()
        );
    }
    for w in trace.windows(2) {
        if w[1].arrival < w[0].arrival {
            bail!("trace not sorted by arrival");
        }
    }
    if let Some(m) = cfg.fault_plan.max_replica() {
        if m >= r {
            bail!("fault plan names replica {m} but the cluster has {r}");
        }
    }
    if let Some(sc) = &cfg.scale {
        sc.validate()?;
        if sc.min_live > r {
            bail!(
                "scale controller min_live {} exceeds the replica count {r}",
                sc.min_live
            );
        }
    }
    let wall0 = std::time::Instant::now();

    let scheds: Vec<Scheduler> = engines
        .iter_mut()
        .zip(prms.iter_mut())
        .enumerate()
        .map(|(i, (e, p))| {
            let mut sc = cfg.sched.clone();
            sc.seed ^= (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
            let mut s = Scheduler::new(
                sc,
                e.as_mut(),
                p.as_mut(),
                ClockHandle::Sim(SimClock::new()),
            );
            s.set_audit(cfg.audit);
            s.set_emit_events(sink.is_some());
            s
        })
        .collect();

    let gossip_on =
        cfg.gossip_rounds > 0 && cfg.lb == LbPolicy::PrefixAffinity && r > 1;
    // With a scale controller, only the first `min_live` replicas start
    // live; the rest are cold standbys the controller can activate.
    let mut state = vec![ReplicaState::Live; r];
    if let Some(sc) = &cfg.scale {
        for s in state.iter_mut().skip(sc.min_live) {
            *s = ReplicaState::Down;
        }
    }
    let mut fleet = Fleet {
        lb: cfg.lb,
        gossip_on,
        gossip_adapt: cfg.gossip_adapt,
        gossip_rounds_cfg: cfg.gossip_rounds,
        scale: cfg.scale,
        scheds,
        state,
        table: DigestTable::new(r, cfg.sched.kv.page_tokens),
        steps_since_advert: vec![0; r],
        period: cfg.gossip_rounds,
        adapt_mark: (0, 0),
        retired_observed: (0, 0),
        dispatch_log: vec![Vec::new(); r],
        assignments: vec![usize::MAX; trace.len()],
        outcomes_by_pos: (0..trace.len()).map(|_| None).collect(),
        redispatch_count: vec![0; trace.len()],
        expected_match: vec![0; trace.len()],
        incarnations: vec![Vec::new(); r],
        stats: FaultStats::default(),
        rr_next: 0,
        rng: Rng::new(cfg.seed ^ 0x00D1_5BA7),
        probe_calls: 0,
        since_scale: cfg
            .scale
            .map_or(0, |sc| sc.cooldown_arrivals),
    };

    let mut pending = cfg.fault_plan.events.iter().peekable();
    for (pos, req) in trace.iter().enumerate() {
        // Scripted events strictly precede the arrivals they don't
        // trail: everything at t ≤ this arrival fires first, so routing
        // observes the post-event replica set.
        while pending.peek().is_some_and(|e| e.t <= req.arrival) {
            let e = pending.next().unwrap();
            fleet.apply_event(e)?;
        }
        // Advance every running replica to the arrival instant so the
        // policy sees true loads, then dispatch.
        fleet.catch_up_running(req.arrival)?;
        fleet.scale_tick(req.arrival);
        let (idx, expected) = fleet.route(req)?;
        fleet.dispatch_to(idx, pos, req.clone(), expected)?;
        pump_events(&mut fleet, &mut sink);
    }
    // Events scripted past the last arrival (e.g. a failure during the
    // drain tail) still apply, in order.
    for e in pending {
        fleet.apply_event(e)?;
    }
    // Drain every running replica to completion.
    for i in 0..r {
        if fleet.state[i] != ReplicaState::Down {
            while fleet.scheds[i].step()? == StepOutcome::Worked {}
        }
    }
    pump_events(&mut fleet, &mut sink);

    // Collect outcomes by trace position: each replica's final
    // incarnation finishes in its own dispatch order, and failed
    // incarnations already banked their finished outcomes in
    // `fail_replica`. The merge *moves* outcomes — `RequestOutcome`
    // carries a per-response length vector, so cloning every outcome was
    // an O(total responses) allocation storm on large traces.
    let mut replica_results = Vec::with_capacity(r);
    for i in 0..r {
        let mut final_res = fleet.scheds[i].finish()?;
        let finals = std::mem::take(&mut final_res.outcomes);
        let positions = std::mem::take(&mut fleet.dispatch_log[i]);
        if finals.len() != positions.len() {
            bail!(
                "replica {i} produced {} outcomes for {} dispatches",
                finals.len(),
                positions.len()
            );
        }
        for (o, pos) in finals.into_iter().zip(positions) {
            fleet.outcomes_by_pos[pos] = Some(o);
        }
        let mut parts = std::mem::take(&mut fleet.incarnations[i]);
        parts.push(final_res);
        replica_results.push(merge_incarnations(parts));
    }
    let mut outcomes = Vec::with_capacity(trace.len());
    for (pos, slot) in fleet.outcomes_by_pos.iter_mut().enumerate() {
        let Some(mut o) = slot.take() else {
            bail!("request at trace position {pos} was lost (no outcome)");
        };
        // Re-dispatched requests were handed to survivors with the
        // failure instant as their arrival; the reported outcome
        // measures from the original arrival so the detour is visible
        // as latency, never hidden.
        o.arrival = trace[pos].arrival;
        o.redispatches = fleet.redispatch_count[pos];
        outcomes.push(o);
    }
    fleet.stats.requests_redispatched =
        fleet.redispatch_count.iter().filter(|&&c| c > 0).count();

    // Stale gossip hits: the table promised a prefix match the replica
    // could no longer fully serve by the time the request was admitted
    // (evicted between advertisement and admission — the request simply
    // re-prefilled the difference).
    let stale_hits = fleet
        .expected_match
        .iter()
        .zip(&outcomes)
        .filter(|&(&exp, o)| exp > 0 && o.cached_prompt_tokens < exp)
        .count();
    let digest_rows: Vec<usize> =
        (0..r).map(|i| fleet.table.replica_len(i)).collect();

    Ok(ClusterResult {
        outcomes,
        replica_results,
        assignments: fleet.assignments,
        lb: cfg.lb,
        gossip: GossipStats {
            gossip_rounds: cfg.gossip_rounds,
            effective_gossip_rounds: fleet.period,
            advertisements: fleet.table.advertisements_total(),
            full_advertisements: fleet.table.full_advertisements_total(),
            delta_advertisements: fleet.table.delta_advertisements_total(),
            digests_sent: fleet.table.digests_sent_total(),
            digest_table_digests: fleet.table.len(),
            stale_hits,
            probe_calls: fleet.probe_calls,
        },
        fault: fleet.stats,
        digest_rows,
        wall_seconds: wall0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lb_policy_parse_roundtrip() {
        for lb in LbPolicy::ALL {
            assert_eq!(LbPolicy::parse(lb.label()).unwrap(), lb);
            assert_eq!(LbPolicy::parse(lb.slug()).unwrap(), lb);
        }
        assert!(LbPolicy::parse("nope").is_err());
    }

    #[test]
    fn skew_edge_cases() {
        assert_eq!(skew_f64(&[]), 1.0);
        assert_eq!(skew_f64(&[0.0, 0.0]), 1.0);
        assert!((skew_f64(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((skew_f64(&[3.0, 1.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn drain_candidate_avoids_deep_prefill_backlog() {
        use ReplicaState::{Down, Draining, Live};
        // Historical tie-break: with no prefill backlog anywhere, the
        // highest-index live replica drains (pre-latency-aware behaviour,
        // reproduced exactly).
        assert_eq!(
            pick_drain_candidate(&[Live, Live, Live], &[0, 0, 0]),
            Some(2)
        );
        // A replica mid-way through streaming a deep prefill backlog is
        // not chosen to drain, even though index order prefers it.
        assert_eq!(
            pick_drain_candidate(&[Live, Live, Live], &[0, 0, 4096]),
            Some(1)
        );
        assert_eq!(
            pick_drain_candidate(&[Live, Live, Live], &[128, 4096, 64]),
            Some(2)
        );
        // Non-live replicas are never candidates, whatever their backlog.
        assert_eq!(
            pick_drain_candidate(&[Live, Down, Live], &[512, 0, 1024]),
            Some(0)
        );
        assert_eq!(pick_drain_candidate(&[Down, Draining], &[0, 0]), None);
        // All live replicas deep in prefill: the shallowest one drains
        // (the controller still honours the queue-depth decision).
        assert_eq!(
            pick_drain_candidate(&[Live, Live], &[900, 700]),
            Some(1)
        );
    }
}
