//! Cross-replica prefix-digest gossip: the dispatcher-side table that
//! replicas advertise their resident prefix digests into.
//!
//! The probe-based `PrefixAffinity` policy asks every replica's radix
//! tree for the longest resident prefix at each arrival — O(R) tree
//! walks per dispatch, and the knowledge dies with the dispatcher. With
//! gossip, each replica periodically advertises the digest set of its
//! interned full-page prefixes
//! ([`KvCacheManager::advertised_digests`](crate::kvcache::KvCacheManager::advertised_digests))
//! and routing becomes a [`DigestTable::lookup`]:
//! hash the arriving prompt's page prefixes with the same rolling
//! [`page_digest`](crate::kvcache::page_digest) chain and find the
//! longest one any replica advertises.
//!
//! The table is deliberately *stale-tolerant*: an advertisement is a
//! snapshot, and the replica may have evicted (or newly interned) pages
//! since. A stale hit only routes a request to a replica that must
//! re-prefill — admission walks the real tree, so outcomes are always
//! correct; the cluster layer counts these as `stale_hits` and the next
//! advertisement retracts the dead digests. That trade is what lets the
//! dispatch hot path drop its per-arrival probe scan.

use crate::kvcache::{page_digest, DigestDelta, DIGEST_SEED};
use crate::tokenizer::Token;
use std::collections::HashSet;

/// Per-replica advertised digest sets plus the bookkeeping the cluster
/// metrics report (advertisement count, table size).
#[derive(Debug, Clone)]
pub struct DigestTable {
    page_tokens: usize,
    sets: Vec<HashSet<u64>>,
    /// Digest-set version each row reflects. `None` means the row has no
    /// known version (never advertised, legacy full-replace, or retracted
    /// after a failure) — a delta cannot apply and the sender must fall
    /// back to a full snapshot.
    versions: Vec<Option<u64>>,
    advertisements: usize,
    full_advertisements: usize,
    delta_advertisements: usize,
    /// Σ digests carried on the wire (snapshot sizes + delta add/retract
    /// lists) — the traffic the delta protocol exists to shrink.
    digests_sent: usize,
}

impl DigestTable {
    /// Empty table for `replicas` replicas advertising `page_tokens`-page
    /// digests (must match the replicas' kv page size, or prompts hash to
    /// different chains than the trees advertise).
    pub fn new(replicas: usize, page_tokens: usize) -> DigestTable {
        assert!(page_tokens > 0, "digest table needs a page size");
        DigestTable {
            page_tokens,
            sets: vec![HashSet::new(); replicas],
            versions: vec![None; replicas],
            advertisements: 0,
            full_advertisements: 0,
            delta_advertisements: 0,
            digests_sent: 0,
        }
    }

    pub fn replicas(&self) -> usize {
        self.sets.len()
    }

    /// Replace `replica`'s advertised set wholesale (full-state
    /// advertisement; digests absent from the new set are retracted).
    /// Version-less legacy form: the row's version becomes unknown, so
    /// the next delta against it is rejected. Prefer
    /// [`Self::advertise_full`] / [`Self::apply_delta`].
    pub fn advertise(
        &mut self,
        replica: usize,
        digests: impl IntoIterator<Item = u64>,
    ) {
        self.advertisements += 1;
        self.full_advertisements += 1;
        let set = &mut self.sets[replica];
        set.clear();
        set.extend(digests);
        self.digests_sent += set.len();
        self.versions[replica] = None;
    }

    /// Replace `replica`'s row with a versioned full snapshot (cold
    /// rejoin, first advertisement, or the fallback after a delta base
    /// mismatch). Subsequent deltas chain off `version`.
    pub fn advertise_full(
        &mut self,
        replica: usize,
        version: u64,
        digests: impl IntoIterator<Item = u64>,
    ) {
        self.advertisements += 1;
        self.full_advertisements += 1;
        let set = &mut self.sets[replica];
        set.clear();
        set.extend(digests);
        self.digests_sent += set.len();
        self.versions[replica] = Some(version);
    }

    /// Apply a version-keyed change set to `replica`'s row. Returns
    /// `false` — leaving the row untouched — when the row is not at the
    /// delta's base version (missed advert, retracted row, legacy
    /// full-replace): the caller must fall back to a full snapshot.
    pub fn apply_delta(&mut self, replica: usize, delta: &DigestDelta) -> bool {
        if self.versions[replica] != Some(delta.base_version) {
            return false;
        }
        self.advertisements += 1;
        self.delta_advertisements += 1;
        self.digests_sent += delta.adds.len() + delta.retracts.len();
        let set = &mut self.sets[replica];
        for d in &delta.retracts {
            set.remove(d);
        }
        set.extend(delta.adds.iter().copied());
        self.versions[replica] = Some(delta.version);
        true
    }

    /// Drop everything `replica` ever advertised — the dispatcher's
    /// reaction to its failure. Routing on the row would send requests
    /// into a corpse; clearing it degrades those prompts to p2c until
    /// the replica rejoins and re-advertises (version unknown, so the
    /// rejoin advertisement is forced Full).
    pub fn retract(&mut self, replica: usize) {
        self.sets[replica].clear();
        self.versions[replica] = None;
    }

    /// Advertisements received since construction.
    pub fn advertisements_total(&self) -> usize {
        self.advertisements
    }

    /// Full-snapshot advertisements received (versioned or legacy).
    pub fn full_advertisements_total(&self) -> usize {
        self.full_advertisements
    }

    /// Delta advertisements successfully applied.
    pub fn delta_advertisements_total(&self) -> usize {
        self.delta_advertisements
    }

    /// Σ digests carried by all accepted advertisements (wire traffic).
    pub fn digests_sent_total(&self) -> usize {
        self.digests_sent
    }

    /// Digests currently advertised by one replica's row.
    pub fn replica_len(&self, replica: usize) -> usize {
        self.sets[replica].len()
    }

    /// Σ advertised digests over all replicas (table size metric).
    pub fn len(&self) -> usize {
        self.sets.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.iter().all(|s| s.is_empty())
    }

    /// Does `replica`'s advertised set name this digest? (Staleness
    /// regression tests.)
    pub fn contains(&self, replica: usize, digest: u64) -> bool {
        self.sets[replica].contains(&digest)
    }

    /// Longest advertised full-page prefix of `prompt`: the matched token
    /// count and every replica advertising that prefix (ascending index).
    /// `(0, [])` when no replica advertises any prefix of it.
    ///
    /// Advertised sets are ancestor-closed — interning creates whole
    /// root chains and eviction is leaf-only, so a replica advertising a
    /// depth-k prefix advertises every shallower one too. The advertised
    /// depths of any prompt therefore form a prefix of its digest chain:
    /// hash and test one page at a time, shallow→deep, and stop at the
    /// first depth nobody advertises. A cold prompt — the common case at
    /// low prefix share — costs one page's hashing and one
    /// short-circuited scan over the replica sets, not work per page.
    pub fn lookup(&self, prompt: &[Token]) -> (usize, Vec<usize>) {
        let mut matched = 0usize;
        let mut deepest = DIGEST_SEED;
        let mut h = DIGEST_SEED;
        for page in prompt.chunks_exact(self.page_tokens) {
            h = page_digest(h, page);
            if !self.sets.iter().any(|s| s.contains(&h)) {
                break;
            }
            matched += 1;
            deepest = h;
        }
        if matched == 0 {
            return (0, Vec::new());
        }
        let replicas: Vec<usize> = self
            .sets
            .iter()
            .enumerate()
            .filter(|(_, s)| s.contains(&deepest))
            .map(|(i, _)| i)
            .collect();
        (matched * self.page_tokens, replicas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{
        prompt_page_digests, AdmissionRequest, KvCacheManager,
    };

    fn prompt(base: i32, len: usize) -> Vec<Token> {
        (base..base + len as i32).collect()
    }

    #[test]
    fn lookup_finds_longest_advertised_prefix() {
        let mut t = DigestTable::new(3, 16);
        assert!(t.is_empty());
        let p = prompt(0, 64); // 4 pages
        let ds = prompt_page_digests(&p, 16);
        // Replica 0 advertises 2 pages deep, replica 2 all 4.
        t.advertise(0, ds[..2].to_vec());
        t.advertise(2, ds.clone());
        assert_eq!(t.advertisements_total(), 2);
        assert_eq!(t.len(), 6);
        let (matched, reps) = t.lookup(&p);
        assert_eq!(matched, 64);
        assert_eq!(reps, vec![2]);
        // A 2-page truncation matches both advertisers.
        let (matched, reps) = t.lookup(&p[..40]);
        assert_eq!(matched, 32);
        assert_eq!(reps, vec![0, 2]);
        // Cold prompt: no match, no candidates.
        assert_eq!(t.lookup(&prompt(500, 64)), (0, Vec::new()));
        // Sub-page prompts never match.
        assert_eq!(t.lookup(&p[..10]), (0, Vec::new()));
    }

    #[test]
    fn advertise_replaces_the_whole_set() {
        let mut t = DigestTable::new(2, 16);
        let a = prompt(0, 32);
        let b = prompt(100, 32);
        t.advertise(1, prompt_page_digests(&a, 16));
        assert_eq!(t.lookup(&a), (32, vec![1]));
        // Re-advertising with only b retracts a.
        t.advertise(1, prompt_page_digests(&b, 16));
        assert_eq!(t.lookup(&a), (0, Vec::new()));
        assert_eq!(t.lookup(&b), (32, vec![1]));
        assert_eq!(t.advertisements_total(), 2);
    }

    #[test]
    fn deltas_apply_only_on_matching_base_version() {
        use crate::kvcache::DigestDelta;
        let mut t = DigestTable::new(2, 16);
        let a = prompt(0, 32);
        let ds = prompt_page_digests(&a, 16);
        t.advertise_full(0, 5, ds.clone());
        assert_eq!(t.lookup(&a), (32, vec![0]));
        assert_eq!(t.full_advertisements_total(), 1);
        assert_eq!(t.digests_sent_total(), 2);

        // Chained delta: retract the deep page, add a new root.
        let b = prompt(100, 16);
        let db = prompt_page_digests(&b, 16);
        let d1 = DigestDelta {
            base_version: 5,
            version: 8,
            adds: db.clone(),
            retracts: vec![ds[1]],
        };
        assert!(t.apply_delta(0, &d1));
        assert_eq!(t.lookup(&a), (16, vec![0]));
        assert_eq!(t.lookup(&b), (16, vec![0]));
        assert_eq!(t.delta_advertisements_total(), 1);
        assert_eq!(t.digests_sent_total(), 4);

        // Stale base: rejected, row untouched.
        let stale = DigestDelta {
            base_version: 5,
            version: 9,
            adds: vec![],
            retracts: db.clone(),
        };
        assert!(!t.apply_delta(0, &stale));
        assert_eq!(t.lookup(&b), (16, vec![0]));
        // A replica that never advertised has no version to chain from.
        assert!(!t.apply_delta(1, &d1));
        // Legacy full-replace drops the version: deltas stop applying.
        t.advertise(0, ds.clone());
        let d2 = DigestDelta {
            base_version: 8,
            version: 10,
            adds: vec![],
            retracts: vec![],
        };
        assert!(!t.apply_delta(0, &d2));
        assert_eq!(t.advertisements_total(), 3);
    }

    #[test]
    fn retract_clears_row_and_forces_full_rejoin() {
        let mut t = DigestTable::new(2, 16);
        let a = prompt(0, 32);
        let ds = prompt_page_digests(&a, 16);
        t.advertise_full(0, 3, ds.clone());
        t.advertise_full(1, 3, ds.clone());
        assert_eq!(t.replica_len(0), 2);
        t.retract(0);
        assert_eq!(t.replica_len(0), 0);
        assert_eq!(t.lookup(&a), (32, vec![1]), "survivor row intact");
        // The retracted row lost its version: a chained delta is
        // rejected until a full snapshot re-bases it.
        let d = crate::kvcache::DigestDelta {
            base_version: 3,
            version: 4,
            adds: vec![],
            retracts: vec![],
        };
        assert!(!t.apply_delta(0, &d));
        t.advertise_full(0, 7, ds.clone());
        assert_eq!(t.lookup(&a), (32, vec![0, 1]));
    }

    #[test]
    fn table_matches_live_tree_after_fresh_advertisement() {
        // An advertisement taken from a real kv manager must reproduce
        // the tree's own longest-prefix answer for any probe prompt.
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let mut shared = prompt(0, 32);
        shared.extend(prompt(700, 32)); // 4 pages: 2 shared + 2 tail
        let other = prompt(300, 48);
        for p in [&shared, &other] {
            let a = kv
                .admit(&AdmissionRequest::monolithic(p, 16, 1))
                .unwrap()
                .into_admission()
                .unwrap();
            for br in a.branches {
                kv.release_branch(br).unwrap();
            }
        }
        let mut t = DigestTable::new(1, 16);
        t.advertise(0, kv.advertised_digests());
        for probe in [
            shared.clone(),
            shared[..40].to_vec(),
            {
                let mut div = prompt(0, 32);
                div.extend(prompt(900, 32));
                div
            },
            other.clone(),
            prompt(5000, 64),
        ] {
            let (matched, reps) = t.lookup(&probe);
            assert_eq!(
                matched,
                kv.cached_prefix_tokens(&probe),
                "table disagrees with the tree on {probe:?}"
            );
            assert_eq!(reps.is_empty(), matched == 0);
        }
    }
}
