//! Serving metrics: latency percentiles, accuracy, resource timelines.
//!
//! Produces exactly the quantities the paper's evaluation section reports:
//! P50/P90/P97/P99 end-to-end and inference latencies (Figs. 5, 7),
//! accuracy (ratio of correctly answered requests), response-length and
//! queuing-time distributions (Figs. 2, 6), and the running-branch /
//! running-token timelines of Fig. 3.

use crate::coordinator::RequestOutcome;
use crate::util::json::Json;
use crate::util::stats::{percentile, Summary};

/// One sample of engine/queue occupancy (taken once per decode round).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    pub t: f64,
    /// Occupied engine slots (in chunked-prefill serves this includes
    /// slots whose prompt is still streaming in).
    pub running_branches: usize,
    /// Occupied slots that are actually decodable — `running_branches`
    /// minus mid-prefill slots (equal to it in monolithic serves). The
    /// decode-stall series gates on this: a round whose only residents
    /// were still streaming their own prompts stalled nobody.
    pub decoding_branches: usize,
    pub running_tokens: usize,
    pub kv_pages_used: usize,
    pub queued_requests: usize,
    /// Cumulative prompt tokens served from the cross-request prefix
    /// cache up to this round (0 with the cache disabled).
    pub cache_hit_tokens: usize,
    /// Prompt tokens still waiting to stream into mid-prefill slots
    /// (the chunked-prefill backlog; 0 in monolithic serves).
    pub queued_prefill_tokens: usize,
    /// Cumulative engine seconds spent on prefill dispatches up to this
    /// round — the per-round delta is the decode stall that round's
    /// resident branches absorbed (the chunked-prefill headline).
    pub prefill_seconds: f64,
}

/// Occupancy over a serve run (Fig. 3's x-axis is `t`).
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub points: Vec<TimelinePoint>,
}

impl Timeline {
    /// Down-sample to at most `n` evenly spaced points (plot-friendly).
    /// The first and last samples are always kept — dropping the last
    /// point made plots lose the end-of-run occupancy (drain tail).
    pub fn downsample(&self, n: usize) -> Vec<TimelinePoint> {
        if self.points.len() <= n || n == 0 {
            return self.points.clone();
        }
        if n == 1 {
            return vec![*self.points.last().unwrap()];
        }
        let last = self.points.len() - 1;
        (0..n).map(|i| self.points[i * last / (n - 1)]).collect()
    }

    pub fn peak_branches(&self) -> usize {
        self.points.iter().map(|p| p.running_branches).max().unwrap_or(0)
    }

    pub fn peak_tokens(&self) -> usize {
        self.points.iter().map(|p| p.running_tokens).max().unwrap_or(0)
    }

    /// Per-round decode-stall series: the prefill seconds charged in each
    /// round whose *preceding* sample still had resident branches (those
    /// branches sat through that round's prompt processing). This is the
    /// quantity behind BENCH_chunked's
    /// `p99_decode_stall_ratio_chunked_vs_mono` headline; the bench and
    /// the regression tests both read it from here so the gate and the
    /// tests can never measure different things.
    pub fn decode_stall_series(&self) -> Vec<f64> {
        let mut out = Vec::new();
        let mut prev_prefill = 0.0f64;
        let mut prev_decoding = 0usize;
        for p in &self.points {
            let d = p.prefill_seconds - prev_prefill;
            // Gate on *decodable* residents: a cold header streaming
            // into an otherwise empty batch stalls nobody, and counting
            // it would bias the chunked-vs-mono ratio against chunked
            // (monolithic prefill into an empty batch records zero).
            if prev_decoding > 0 {
                out.push(d);
            }
            prev_prefill = p.prefill_seconds;
            prev_decoding = p.decoding_branches;
        }
        out
    }

    /// Time-weighted mean of running branches.
    pub fn mean_branches(&self) -> f64 {
        if self.points.len() < 2 {
            return self.points.first().map(|p| p.running_branches as f64)
                .unwrap_or(0.0);
        }
        let mut area = 0.0;
        let mut dur = 0.0;
        for w in self.points.windows(2) {
            let dt = (w[1].t - w[0].t).max(0.0);
            area += w[0].running_branches as f64 * dt;
            dur += dt;
        }
        if dur > 0.0 {
            area / dur
        } else {
            0.0
        }
    }
}

/// Aggregate report over one serve run (one method × one workload).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub label: String,
    pub n_requests: usize,
    pub accuracy: f64,
    pub answered: f64,
    pub e2e: Summary,
    pub queue: Summary,
    pub inference: Summary,
    pub response_lengths: Vec<f64>,
    pub queue_latencies: Vec<f64>,
    pub e2e_latencies: Vec<f64>,
    pub inference_latencies: Vec<f64>,
    pub total_tokens: usize,
    pub tokens_per_request: f64,
    pub branches_started_per_request: f64,
    pub branches_pruned_per_request: f64,
}

impl ServeReport {
    pub fn from_outcomes(label: &str, outcomes: &[RequestOutcome]) -> ServeReport {
        assert!(!outcomes.is_empty(), "empty outcome set");
        let e2e: Vec<f64> = outcomes.iter().map(|o| o.e2e_latency()).collect();
        let queue: Vec<f64> =
            outcomes.iter().map(|o| o.queue_latency()).collect();
        let inference: Vec<f64> =
            outcomes.iter().map(|o| o.inference_latency()).collect();
        let lengths: Vec<f64> = outcomes
            .iter()
            .flat_map(|o| o.response_lengths.iter().map(|&l| l as f64))
            .collect();
        let correct =
            outcomes.iter().filter(|o| o.correct()).count() as f64;
        let answered =
            outcomes.iter().filter(|o| o.answer.is_some()).count() as f64;
        let total_tokens: usize =
            outcomes.iter().map(|o| o.tokens_generated).sum();
        let n = outcomes.len() as f64;
        ServeReport {
            label: label.to_string(),
            n_requests: outcomes.len(),
            accuracy: correct / n,
            answered: answered / n,
            e2e: Summary::of(&e2e),
            queue: Summary::of(&queue),
            inference: Summary::of(&inference),
            response_lengths: lengths,
            queue_latencies: queue.clone(),
            e2e_latencies: e2e,
            inference_latencies: inference,
            total_tokens,
            tokens_per_request: total_tokens as f64 / n,
            branches_started_per_request: outcomes
                .iter()
                .map(|o| o.branches_started as f64)
                .sum::<f64>()
                / n,
            branches_pruned_per_request: outcomes
                .iter()
                .map(|o| o.branches_pruned as f64)
                .sum::<f64>()
                / n,
        }
    }

    /// Percentile of the E2E latency distribution.
    pub fn e2e_percentile(&self, p: f64) -> f64 {
        percentile(&self.e2e_latencies, p)
    }

    /// One-line summary (comparison tables).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.label.clone(),
            format!("{}", self.n_requests),
            format!("{:.3}", self.accuracy),
            format!("{:.2}", self.e2e.p50),
            format!("{:.2}", self.e2e.p90),
            format!("{:.2}", self.e2e.p97),
            format!("{:.2}", self.e2e.p99),
            format!("{:.2}", self.queue.p50),
            format!("{:.1}", self.tokens_per_request),
        ]
    }

    pub const ROW_HEADERS: [&'static str; 9] = [
        "method", "reqs", "acc", "e2e-p50", "e2e-p90", "e2e-p97", "e2e-p99",
        "queue-p50", "tok/req",
    ];

    /// JSON form of the aggregate report (the `report` key of a
    /// `RunOutput` dump — live replays write the same schema so every
    /// bench/gate tool reads live and virtual runs identically).
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("label".into(), Json::Str(self.label.clone()));
        o.insert("n_requests".into(), Json::Num(self.n_requests as f64));
        o.insert("accuracy".into(), Json::Num(self.accuracy));
        o.insert("answered".into(), Json::Num(self.answered));
        o.insert("e2e".into(), summary_to_json(&self.e2e));
        o.insert("queue".into(), summary_to_json(&self.queue));
        o.insert("inference".into(), summary_to_json(&self.inference));
        o.insert("total_tokens".into(), Json::Num(self.total_tokens as f64));
        o.insert(
            "tokens_per_request".into(),
            Json::Num(self.tokens_per_request),
        );
        o.insert(
            "branches_started_per_request".into(),
            Json::Num(self.branches_started_per_request),
        );
        o.insert(
            "branches_pruned_per_request".into(),
            Json::Num(self.branches_pruned_per_request),
        );
        Json::Obj(o)
    }
}

fn summary_to_json(s: &Summary) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("n".into(), Json::Num(s.n as f64));
    o.insert("mean".into(), Json::Num(s.mean));
    o.insert("p50".into(), Json::Num(s.p50));
    o.insert("p90".into(), Json::Num(s.p90));
    o.insert("p97".into(), Json::Num(s.p97));
    o.insert("p99".into(), Json::Num(s.p99));
    o.insert("max".into(), Json::Num(s.max));
    Json::Obj(o)
}

/// One-line TTFT decomposition for serve reports: the mean time to first
/// token split into its queue-wait and prefill components, plus the tail.
/// The split is the actionable part — a high-queue TTFT wants more
/// replicas or admission headroom, a high-prefill TTFT wants chunking or
/// a warmer prefix cache.
pub fn ttft_split_line(outcomes: &[RequestOutcome]) -> String {
    assert!(!outcomes.is_empty(), "empty outcome set");
    let n = outcomes.len() as f64;
    let ttft: Vec<f64> = outcomes.iter().map(|o| o.ttft()).collect();
    let queue: f64 =
        outcomes.iter().map(|o| o.queue_latency()).sum::<f64>() / n;
    let prefill: f64 =
        outcomes.iter().map(|o| o.prefill_latency()).sum::<f64>() / n;
    format!(
        "ttft mean {:.3}s = queue {:.3}s + prefill {:.3}s (p99 {:.3}s)",
        ttft.iter().sum::<f64>() / n,
        queue,
        prefill,
        percentile(&ttft, 99.0),
    )
}

/// One-line resilience summary for live replays: how much of the fault
/// machinery actually fired. All-zero on a clean replay against a
/// healthy listener — the line still prints so operators can grep for
/// it unconditionally.
pub fn live_resilience_line(
    migrated_sessions: usize,
    retries: usize,
    deadline_expired: usize,
) -> String {
    format!(
        "resilience: {migrated_sessions} migrated sessions, {retries} \
         retries, {deadline_expired} deadline-expired"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: usize, arrival: f64, admit: f64, finish: f64,
               correct: bool) -> RequestOutcome {
        RequestOutcome {
            id,
            dataset: "d".into(),
            arrival,
            admitted_at: admit,
            prefill_done_at: admit,
            finished_at: finish,
            answer: Some(if correct { 1 } else { 2 }),
            truth: 1,
            branches_started: 4,
            branches_pruned: 1,
            branches_completed: 2,
            tokens_generated: 50,
            response_lengths: vec![10, 30],
            cached_prompt_tokens: 0,
            redispatches: 0,
            preemptions: 0,
        }
    }

    #[test]
    fn report_aggregates() {
        let outs = vec![
            outcome(0, 0.0, 1.0, 5.0, true),
            outcome(1, 0.0, 2.0, 8.0, false),
        ];
        let r = ServeReport::from_outcomes("x", &outs);
        assert_eq!(r.n_requests, 2);
        assert_eq!(r.accuracy, 0.5);
        assert_eq!(r.answered, 1.0);
        assert_eq!(r.total_tokens, 100);
        assert_eq!(r.response_lengths.len(), 4);
        assert!((r.e2e.mean - 6.5).abs() < 1e-12);
        assert!((r.queue.mean - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ttft_split_line_formats() {
        // A: queue 1.0 + prefill 0.5 (ttft 1.5); B: queue 2.0 +
        // prefill 0.5 (ttft 2.5). p99 over [1.5, 2.5] interpolates to
        // 2.49.
        let mut a = outcome(0, 0.0, 1.0, 5.0, true);
        a.prefill_done_at = 1.5;
        let mut b = outcome(1, 0.0, 2.0, 8.0, false);
        b.prefill_done_at = 2.5;
        assert_eq!(
            ttft_split_line(&[a, b]),
            "ttft mean 2.000s = queue 1.500s + prefill 0.500s \
             (p99 2.490s)"
        );
    }

    #[test]
    fn report_to_json_round_trips_headline_numbers() {
        let outs = vec![
            outcome(0, 0.0, 1.0, 5.0, true),
            outcome(1, 0.0, 2.0, 8.0, false),
        ];
        let r = ServeReport::from_outcomes("x", &outs);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req("label").unwrap().as_str().unwrap(), "x");
        assert_eq!(
            parsed.req("n_requests").unwrap().as_usize().unwrap(),
            2
        );
        let e2e = parsed.req("e2e").unwrap();
        assert!(
            (e2e.req("mean").unwrap().as_f64().unwrap() - r.e2e.mean)
                .abs()
                < 1e-9
        );
        assert!(
            (parsed.req("accuracy").unwrap().as_f64().unwrap() - 0.5)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn timeline_stats() {
        let tl = Timeline {
            points: vec![
                TimelinePoint { t: 0.0, running_branches: 2,
                                decoding_branches: 2,
                                running_tokens: 10, kv_pages_used: 3,
                                queued_requests: 0, cache_hit_tokens: 0,
                                queued_prefill_tokens: 0,
                                prefill_seconds: 0.0 },
                TimelinePoint { t: 1.0, running_branches: 6,
                                decoding_branches: 5,
                                running_tokens: 50, kv_pages_used: 9,
                                queued_requests: 2, cache_hit_tokens: 8,
                                queued_prefill_tokens: 4,
                                prefill_seconds: 0.5 },
                TimelinePoint { t: 3.0, running_branches: 1,
                                decoding_branches: 0,
                                running_tokens: 5, kv_pages_used: 1,
                                queued_requests: 0, cache_hit_tokens: 8,
                                queued_prefill_tokens: 0,
                                prefill_seconds: 0.5 },
            ],
        };
        assert_eq!(tl.peak_branches(), 6);
        assert_eq!(tl.peak_tokens(), 50);
        // Stall series: point 0 has no predecessor (skipped); point 1
        // follows a round with 2 decodable branches (0.5 - 0.0
        // absorbed); point 2 follows one with 5 (0.5 - 0.5 = 0.0). A
        // 4th point after the decodable count hit 0 would be skipped.
        assert_eq!(tl.decode_stall_series(), vec![0.5, 0.0]);
        // (2*1 + 6*2) / 3 = 14/3
        assert!((tl.mean_branches() - 14.0 / 3.0).abs() < 1e-12);
        assert_eq!(tl.downsample(2).len(), 2);
        assert_eq!(tl.downsample(100).len(), 3);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let points: Vec<TimelinePoint> = (0..10)
            .map(|i| TimelinePoint {
                t: i as f64,
                running_branches: i,
                decoding_branches: i,
                running_tokens: 10 * i,
                kv_pages_used: i,
                queued_requests: 0,
                cache_hit_tokens: 2 * i,
                queued_prefill_tokens: i,
                prefill_seconds: 0.25 * i as f64,
            })
            .collect();
        let tl = Timeline { points };
        for n in [2, 3, 4, 7, 9] {
            let ds = tl.downsample(n);
            assert_eq!(ds.len(), n, "n={n}");
            assert_eq!(ds[0], tl.points[0], "first dropped at n={n}");
            assert_eq!(
                ds[n - 1],
                *tl.points.last().unwrap(),
                "last dropped at n={n}"
            );
            // Strictly forward in time: no duplicated samples.
            for w in ds.windows(2) {
                assert!(w[1].t > w[0].t, "non-monotone at n={n}");
            }
        }
        // n == 1 keeps the end-of-run sample.
        assert_eq!(tl.downsample(1), vec![*tl.points.last().unwrap()]);
        // Exact-fit and oversize requests return everything.
        assert_eq!(tl.downsample(10).len(), 10);
        assert_eq!(tl.downsample(0).len(), 10);
    }
}
