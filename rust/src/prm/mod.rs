//! Process-reward-model client.
//!
//! SART judges branch quality with a PRM every T decode steps (paper §3,
//! Solution 2). The coordinator talks to a [`PrmScorer`]; two
//! implementations:
//!
//! * [`HloPrm`] — the trained PRM transformer, AOT-compiled and executed
//!   via PJRT in batches (never on the per-token path — scoring is
//!   amortized over rounds, exactly as in the paper where reward
//!   calculation happens every T=400 steps).
//! * [`OraclePrm`] — a noisy oracle for simulation runs: it parses the
//!   branch prefix, checks whether the latest derivation is still
//!   consistent with the question's map, and emits
//!   `on-track → N(mu_good, sigma)` / `off-track → N(mu_bad, sigma)`
//!   clamped to [0.02, 0.98]. `sigma` is the PRM-quality knob used by the
//!   ablation benches.

use crate::tokenizer as tok;
use crate::tokenizer::Token;
use crate::util::rng::Rng;
use crate::workload::Question;
use anyhow::Result;

/// Scores branch prefixes (prompt + generated tokens so far).
pub trait PrmScorer {
    /// One reward in [0, 1] per sequence.
    fn score(&mut self, seqs: &[&[Token]]) -> Result<Vec<f32>>;

    fn describe(&self) -> String;
}

// ---------------------------------------------------------------------------
// HLO-backed PRM.
// ---------------------------------------------------------------------------

/// The trained PRM executed via PJRT, with sequence-bucketed executables:
/// queries are sorted by length and chunked so short prefixes run through
/// the cheap 64-position bucket instead of paying the full-context cost
/// (the §Perf L3 fix — PRM scoring was dominating SART's serve rounds).
pub struct HloPrm {
    rt: crate::runtime::Runtime,
    /// seq bucket -> executable (fixed batch).
    exes: std::collections::BTreeMap<usize, crate::runtime::Executable>,
    batch: usize,
    /// Total scoring dispatches (metrics).
    pub calls: usize,
}

impl HloPrm {
    pub fn load(
        rt: crate::runtime::Runtime,
        manifest: &crate::runtime::Manifest,
        _batch_hint: usize,
    ) -> Result<HloPrm> {
        let exes = rt.load_prm(&manifest.prm)?;
        Ok(HloPrm { rt, exes, batch: manifest.prm.batch, calls: 0 })
    }

    fn bucket_for(&self, len: usize) -> usize {
        self.exes
            .keys()
            .copied()
            .find(|&s| s >= len)
            .unwrap_or_else(|| *self.exes.keys().last().unwrap())
    }
}

impl PrmScorer for HloPrm {
    fn score(&mut self, seqs: &[&[Token]]) -> Result<Vec<f32>> {
        // Sort by length so chunks are bucket-homogeneous.
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by_key(|&i| seqs[i].len());
        let mut out = vec![0f32; seqs.len()];
        for chunk in order.chunks(self.batch) {
            let b = self.batch;
            let max_len = chunk
                .iter()
                .map(|&i| seqs[i].len())
                .max()
                .unwrap_or(1)
                .max(1);
            let seq_bucket = self.bucket_for(max_len);
            let mut toks = vec![tok::PAD; b * seq_bucket];
            let mut lens = vec![1i32; b];
            for (row, &i) in chunk.iter().enumerate() {
                let l = seqs[i].len().min(seq_bucket);
                toks[row * seq_bucket..row * seq_bucket + l]
                    .copy_from_slice(&seqs[i][..l]);
                lens[row] = l.max(1) as i32;
            }
            let toks_buf = self.rt.upload_i32(&toks, &[b, seq_bucket])?;
            let lens_buf = self.rt.upload_i32(&lens, &[b])?;
            let exe = &self.exes[&seq_bucket];
            let res = exe.run(&[&toks_buf, &lens_buf])?;
            let scores = crate::runtime::read_f32(&res, 0, b)?;
            for (row, &i) in chunk.iter().enumerate() {
                out[i] = scores[row];
            }
            self.calls += 1;
        }
        Ok(out)
    }

    fn describe(&self) -> String {
        format!("HloPrm(batch={}, seq_buckets={:?})",
                self.batch,
                self.exes.keys().collect::<Vec<_>>())
    }
}

// ---------------------------------------------------------------------------
// Oracle PRM (simulation).
// ---------------------------------------------------------------------------

/// Noisy-oracle PRM for virtual-time runs and tests.
pub struct OraclePrm {
    pub mu_good: f64,
    pub mu_bad: f64,
    pub sigma: f64,
    rng: Rng,
    pub calls: usize,
}

impl OraclePrm {
    pub fn new(sigma: f64, seed: u64) -> OraclePrm {
        OraclePrm { mu_good: 0.72, mu_bad: 0.32, sigma, rng: Rng::new(seed),
                    calls: 0 }
    }

    /// Is the *latest* derivation in the generated suffix still consistent
    /// with the question's map? (Process-quality proxy.)
    fn on_track(question: &Question, generated: &[Token]) -> bool {
        // Find the start of the latest derivation (after the last
        // <recheck>), then verify each step `<step> cur = next`.
        let start = generated
            .iter()
            .rposition(|&t| t == tok::RECHECK)
            .map(|i| i + 1)
            .unwrap_or(0);
        let mut expected = question.start;
        let seg = &generated[start..];
        let mut it = seg.iter().peekable();
        while let Some(&&t) = it.peek() {
            if t != tok::STEP {
                break; // reached </think>/<ans> tail or an in-flight token
            }
            it.next();
            let cur = it.next().and_then(|&t| tok::digit_value(t));
            let eq = it.next().copied();
            let nxt = it.next().and_then(|&t| tok::digit_value(t));
            let (Some(cur), Some(tok::EQUALS), Some(nxt)) = (cur, eq, nxt)
            else {
                // Partially generated step: judge what exists so far.
                break;
            };
            if cur != expected || question.mapping[cur as usize] != nxt {
                return false; // lost the chain / wrong lookup
            }
            expected = nxt;
        }
        // An empty or still-streaming derivation counts as on-track.
        true
    }
}

impl PrmScorer for OraclePrm {
    fn score(&mut self, seqs: &[&[Token]]) -> Result<Vec<f32>> {
        self.calls += 1;
        seqs.iter()
            .map(|seq| {
                // Split prompt from generation at the <think> marker: the
                // prompt is everything up to and including it (a shared
                // few-shot header never contains <think>, and generated
                // suffixes never re-emit it). Bare 27-token prompts split
                // exactly where the old fixed-offset code did.
                let (prompt, generated) =
                    match seq.iter().position(|&t| t == tok::THINK) {
                        Some(i) => seq.split_at(i + 1),
                        None => (&seq[..], &[][..]),
                    };
                let mu = match Question::from_serving_prompt(prompt) {
                    Ok(q) => {
                        if Self::on_track(&q, generated) {
                            self.mu_good
                        } else {
                            self.mu_bad
                        }
                    }
                    Err(_) => self.mu_bad,
                };
                let r = mu + self.sigma * self.rng.normal();
                Ok(r.clamp(0.02, 0.98) as f32)
            })
            .collect()
    }

    fn describe(&self) -> String {
        format!("OraclePrm(sigma={})", self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskSpec;

    fn question() -> Question {
        let mut rng = Rng::new(11);
        Question::sample(&TaskSpec::synth_gaokao(), &mut rng)
    }

    fn good_steps(q: &Question, n: usize) -> Vec<Token> {
        let mut out = Vec::new();
        let mut cur = q.start;
        for _ in 0..n {
            let nxt = q.mapping[cur as usize];
            out.extend([tok::STEP, tok::digit(cur), tok::EQUALS,
                        tok::digit(nxt)]);
            cur = nxt;
        }
        out
    }

    #[test]
    fn oracle_separates_good_and_bad() {
        let q = question();
        let mut prm = OraclePrm::new(0.05, 1);
        let mut good = q.prompt_tokens();
        good.extend(good_steps(&q, 3));
        let mut bad = q.prompt_tokens();
        let mut steps = good_steps(&q, 3);
        // Corrupt the last lookup value by +1.
        let last = steps.len() - 1;
        steps[last] = tok::digit(
            (tok::digit_value(steps[last]).unwrap() + 1) % 10,
        );
        bad.extend(steps);
        let scores =
            prm.score(&[&good, &bad]).unwrap();
        assert!(scores[0] > scores[1],
                "good {} should beat bad {}", scores[0], scores[1]);
        assert!(scores[0] > 0.5 && scores[1] < 0.5);
    }

    #[test]
    fn oracle_recheck_resets_chain() {
        let q = question();
        let mut prm = OraclePrm::new(0.01, 2);
        // First derivation corrupt, then a <recheck> with a clean one:
        // only the latest derivation counts.
        let mut seq = q.prompt_tokens();
        seq.extend([tok::STEP, tok::digit(q.start), tok::EQUALS,
                    tok::digit((q.mapping[q.start as usize] + 1) % 10)]);
        seq.push(tok::RECHECK);
        seq.extend(good_steps(&q, 2));
        let s = prm.score(&[&seq]).unwrap()[0];
        assert!(s > 0.5, "latest-derivation reset not honored: {s}");
    }

    #[test]
    fn oracle_empty_generation_on_track() {
        let q = question();
        let mut prm = OraclePrm::new(0.01, 3);
        let seq = q.prompt_tokens();
        assert!(prm.score(&[&seq]).unwrap()[0] > 0.5);
    }

    #[test]
    fn oracle_clamps_to_unit_interval() {
        let q = question();
        let mut prm = OraclePrm::new(5.0, 4); // huge noise
        let seq = q.prompt_tokens();
        for _ in 0..100 {
            let s = prm.score(&[&seq]).unwrap()[0];
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn oracle_scores_headered_prompts_like_bare_ones() {
        // A shared few-shot header ahead of the question must not change
        // the on-track judgement: the oracle locates the question at the
        // <think> marker.
        let q = question();
        let mut bare = q.prompt_tokens();
        bare.extend(good_steps(&q, 3));
        let mut headered =
            crate::workload::few_shot_header(&TaskSpec::synth_gaokao(), 8, 2);
        headered.extend(q.prompt_tokens());
        headered.extend(good_steps(&q, 3));
        let mut a = OraclePrm::new(0.0, 5);
        let mut b = OraclePrm::new(0.0, 5);
        let sa = a.score(&[&bare]).unwrap()[0];
        let sb = b.score(&[&headered]).unwrap()[0];
        assert_eq!(sa, sb, "header changed the oracle verdict");
        assert!(sb > 0.5, "on-track chain scored badly: {sb}");
    }

    #[test]
    fn oracle_deterministic_per_seed() {
        let q = question();
        let seq = q.prompt_tokens();
        let mut a = OraclePrm::new(0.1, 9);
        let mut b = OraclePrm::new(0.1, 9);
        assert_eq!(a.score(&[&seq]).unwrap(), b.score(&[&seq]).unwrap());
    }
}
