//! `sart` — the serving CLI (leader entrypoint).
//!
//! Subcommands:
//!   serve     run one serving experiment and print the report
//!   bench     run all methods on one shared workload (comparison table)
//!   listen    serve live sessions over TCP, paced against the wall clock
//!   replay    fire a workload trace at a live listener at trace rate
//!   inspect   print artifact manifest / model inventory
//!
//! Examples:
//!   sart serve --method sart:8 --dataset synth-gpqa --rate 4 --requests 64
//!   sart serve --engine hlo --model r1mini-tiny --method sart:4 --slots 8
//!   sart bench --requests 32 --rate 2
//!   sart listen --addr 127.0.0.1:8477 --method sart:4 --time-scale 0.01
//!   sart replay --addr 127.0.0.1:8477 --requests 64 --rate 4 \
//!       --time-scale 0.01 --shutdown
//!   sart inspect

use anyhow::{bail, Result};
use sart::config::{
    Args, ListenerTuning, LiveConfig, Method, ReplayConfig, ServeSpec,
};
use sart::frontend;
use sart::metrics::{live_resilience_line, ttft_split_line, ServeReport};
use sart::server;
use sart::util::stats::render_table;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// SIGTERM observed (set asynchronously by the signal handler; polled by
/// the listener's watcher thread). Stored rather than acted on — only
/// async-signal-safe work is allowed inside a handler.
#[cfg(unix)]
static SIGTERM_SEEN: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigterm(_signum: i32) {
    SIGTERM_SEEN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Install the SIGTERM flag-setter via libc's `signal` (declared here —
/// the crate is std-only and this is the one libc symbol it needs; std
/// itself links libc on unix).
#[cfg(unix)]
fn install_sigterm_handler() {
    extern "C" {
        fn signal(
            signum: i32,
            handler: extern "C" fn(i32),
        ) -> extern "C" fn(i32);
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_sigterm);
    }
}

#[cfg(unix)]
fn sigterm_seen() -> bool {
    SIGTERM_SEEN.load(std::sync::atomic::Ordering::SeqCst)
}

#[cfg(not(unix))]
fn install_sigterm_handler() {}

#[cfg(not(unix))]
fn sigterm_seen() -> bool {
    false
}

fn real_main() -> Result<()> {
    let all: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match all.split_first() {
        Some((c, r)) if !c.starts_with("--") => (c.clone(), r.to_vec()),
        _ => ("serve".to_string(), all),
    };
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "listen" => cmd_listen(&args),
        "replay" => cmd_replay(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!(
            "unknown command `{other}` (serve|bench|listen|replay|inspect)"
        ),
    }
}

const HELP: &str = "sart <serve|bench|inspect> [flags]
  --method   vanilla|self-consistency|sart|sart-noprune|rebase (suffix :N)
  --n/--m/--alpha/--beta   SART knobs (defaults N=8, M=N/2, 0.5, N/2)
  --engine   sim|hlo        --model  r1mini-tiny|r1mini-small
  --dataset  synth-gaokao|synth-gpqa
  --requests INT  --rate REQ/S (0=batch)  --slots INT  --kv-tokens INT
  --t-round INT  --temp F  --seed INT  --stepwise (disable fused decode)
  --replicas INT  engine replicas behind the dispatch layer (sim only)
  --lb rr|least-loaded|jsq|p2c|prefix-affinity   dispatch policy
  --gossip-rounds N  prefix-affinity: replicas advertise digest sets every
                     N scheduler steps; routing reads the table (0=probe)
  --gossip-adapt     retune the gossip period at runtime from stale routes
  --fault-plan PLAN  scripted failures, e.g. fail@2.5:1,restart@6.0:1
  --scale-min INT    enable the scale controller with INT replicas live
  --scale-up-queue N / --scale-down-queue N / --scale-up-prefill TOK
                     controller thresholds (down<up = hysteresis band)
  --scale-cooldown N arrivals between two scaling actions
  --prefix-cache PAGES   cross-request radix prefix cache budget (0=off)
  --prefix-share F       fraction of requests sharing a few-shot header
  --prefix-templates INT / --prefix-shots INT   header pool shape
  --prefill-chunk TOK    stream prompt prefill in TOK-token chunks (0=off)
  --prefill-budget TOK   per-round streamed-prefill budget (default=chunk)
  --adaptive             adapt N/M/thinking-cap per request at runtime
  --adaptive-spread F    reward spread below which extra branches prune
  --adaptive-keep N      branches kept by a spread prune (default 2)
  --adaptive-tail PCT / --adaptive-slack F   per-request cap = slack x
                     the PCT-th percentile of finished completion lengths
  --adaptive-min-samples N   observations before the policy acts
  --fast-reward F / --fast-len TOK   easy-dataset thresholds for the
                     1-branch no-think fast path
  --hard-share F     mixed workload: fraction of requests drawn from
                     synth-gpqa (the rest from --dataset)
  live serving (listen/replay):
  --addr HOST:PORT   listen/connect address (default 127.0.0.1:8477; :0
                     binds an ephemeral port and prints it)
  --time-scale F     wall seconds per virtual second (1.0 real time,
                     0.01 replays 100x faster)
  --max-sessions N   listen: reject submits past N in-flight sessions
  --idle-timeout S   listen: reap session-less connections idle S seconds
  --session-queue N  listen: shed `tokens` lines past N queued per session
                     (terminal lines are never shed; 0 = headers only)
  --fault-plan/--scale-*  listen: also arm the live fault/scale path —
                     event times are virtual, mapped via --time-scale
  --retry-max N      replay: reconnect/resubmit budget per session (0=off;
                     >0 adds idempotent client ids)
  --retry-base-ms N  replay: backoff base (doubles per attempt, jittered
                     50-100% by --seed; server retry_after_ms overrides)
  --session-deadline S  replay: drop sessions not finalized in S wall
                     seconds (counted as lost; 0 = none)
  --shutdown         replay: send {\"op\":\"shutdown\"} after the trace
  --json PATH        replay: write the RunOutput record to PATH";

fn print_report(r: &ServeReport) {
    let rows = vec![r.row()];
    println!("{}", render_table(&ServeReport::ROW_HEADERS, &rows));
}

fn cmd_serve(args: &Args) -> Result<()> {
    let spec = ServeSpec::from_args(args)?;
    eprintln!("# spec: {spec:?}");
    let out = server::run(&spec)?;
    eprintln!("# engine: {}", out.engine_desc);
    print_report(&out.report);
    println!(
        "answered={:.3} tokens/req={:.1} branches/req={:.2} pruned/req={:.2}",
        out.report.answered,
        out.report.tokens_per_request,
        out.report.branches_started_per_request,
        out.report.branches_pruned_per_request,
    );
    if spec.prefill_chunk_tokens > 0 {
        let mean =
            |f: fn(&sart::coordinator::RequestOutcome) -> f64| -> f64 {
                out.outcomes.iter().map(f).sum::<f64>()
                    / out.outcomes.len().max(1) as f64
            };
        println!(
            "chunked prefill ({} tok/chunk, {} tok/round): \
             ttft mean {:.3}s = queue {:.3}s + prefill-stream {:.3}s",
            spec.prefill_chunk_tokens,
            spec.max_batched_prefill_tokens,
            mean(|o| o.ttft()),
            mean(|o| o.queue_latency()),
            mean(|o| o.prefill_latency()),
        );
    }
    if !out.adaptive.is_empty() {
        let a = &out.adaptive;
        println!(
            "adaptive: {} fast-path | {} spread-pruned branches | \
             {} caps tightened | {} static fallbacks",
            a.fast_path_requests,
            a.spread_pruned_branches,
            a.cap_tightened_requests,
            a.static_fallbacks,
        );
    }
    if out.prompt_tokens > 0 && out.cache_hit_tokens > 0 {
        println!(
            "prefix-cache: {}/{} prompt tokens served from cache ({:.1}%)",
            out.cache_hit_tokens,
            out.prompt_tokens,
            100.0 * out.cache_hit_tokens as f64 / out.prompt_tokens as f64,
        );
    }
    if let Some(c) = &out.cluster {
        println!(
            "cluster: {} replicas, lb={} | req/replica {:?} | \
             occupancy-skew {:.2} request-skew {:.2} | cache-hit {:.1}%",
            c.replicas,
            c.lb,
            c.per_replica_requests,
            c.occupancy_skew,
            c.request_skew,
            100.0 * c.cache_hit_rate,
        );
        println!("{}", ttft_split_line(&out.outcomes));
        let g = &c.gossip;
        if g.gossip_rounds > 0 || g.probe_calls > 0 {
            println!(
                "gossip: period {} steps (effective {}) | {} advertisements \
                 ({} full + {} delta, {} digests sent) | {} digests in \
                 table | {} stale hits | {} probe calls",
                g.gossip_rounds,
                g.effective_gossip_rounds,
                g.advertisements,
                g.full_advertisements,
                g.delta_advertisements,
                g.digests_sent,
                g.digest_table_digests,
                g.stale_hits,
                g.probe_calls,
            );
        }
        let f = &c.fault;
        if *f != Default::default() {
            println!(
                "faults: {} failures, {} restarts | {} re-dispatches over \
                 {} requests | scale {} up / {} down",
                f.failures,
                f.restarts,
                f.redispatches,
                f.requests_redispatched,
                f.scale_ups,
                f.scale_downs,
            );
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let base = ServeSpec::from_args(args)?;
    let n = args.usize_or("n", 8)?;
    let trace = server::trace_for(&base)?;
    let methods = [
        Method::Vanilla,
        Method::SelfConsistency { n },
        Method::Rebase { n },
        Method::Sart {
            n,
            m: (n / 2).max(1),
            alpha: 0.5,
            beta: (n / 2).max(1),
        },
    ];
    let mut rows = Vec::new();
    for m in methods {
        if matches!(m, Method::Rebase { .. })
            && (base.replicas > 1
                || base.prefix_share > 0.0
                || base.prefill_chunk_tokens > 0)
        {
            // rebase has no cluster, prefix-workload or chunked path
            continue;
        }
        let mut spec = base.clone();
        spec.method = m;
        let out = server::run_on_trace(&spec, &trace)?;
        rows.push(out.report.row());
    }
    println!("{}", render_table(&ServeReport::ROW_HEADERS, &rows));
    Ok(())
}

/// `sart listen`: bind a socket and serve live NDJSON sessions against
/// the wall clock until a client sends `{"op":"shutdown"}` or the
/// process receives SIGTERM (both drain in-flight sessions first).
fn cmd_listen(args: &Args) -> Result<()> {
    let spec = ServeSpec::from_args(args)?;
    let live = LiveConfig::from_args(args)?;
    let tuning = ListenerTuning::from_args(args)?;
    eprintln!("# spec: {spec:?}");
    let handle = frontend::listen_with(&spec, &live, &tuning)?;
    println!("listening on {}", handle.addr());
    println!(
        "time-scale {} (1 virtual second = {} wall seconds), \
         max-sessions {}",
        live.time_scale, live.time_scale, live.max_sessions
    );
    install_sigterm_handler();
    let watcher = handle.shutdown_handle();
    std::thread::spawn(move || loop {
        if sigterm_seen() {
            eprintln!("# SIGTERM: draining in-flight sessions");
            watcher.shutdown();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    let res = handle.join();
    eprintln!("# listener drained and exiting");
    res
}

/// `sart replay`: generate the spec's trace and fire it at a live
/// listener at trace rate, then print the same report a virtual-time
/// serve would.
fn cmd_replay(args: &Args) -> Result<()> {
    let spec = ServeSpec::from_args(args)?;
    let live = LiveConfig::from_args(args)?;
    let replay_cfg = ReplayConfig::from_args(args)?;
    let trace = server::trace_for(&spec)?;
    eprintln!("# replaying {} requests at {}", trace.len(), live.addr);
    let res = frontend::replay_with(
        &live.addr,
        &trace,
        live.time_scale,
        args.flag("shutdown"),
        &replay_cfg,
    )?;
    println!(
        "live: {} finalized, {} rejected, {} lost ({} submitted)",
        res.outcomes.len(),
        res.rejected,
        res.requests_lost,
        trace.len()
    );
    println!(
        "{}",
        live_resilience_line(
            res.migrated_sessions,
            res.retries,
            res.deadline_expired,
        )
    );
    if !res.outcomes.is_empty() {
        let report =
            ServeReport::from_outcomes(&spec.method.label(), &res.outcomes);
        print_report(&report);
        println!("{}", ttft_split_line(&res.outcomes));
        let wall_p99 =
            sart::util::stats::percentile(&res.wall_e2e, 99.0);
        println!(
            "wall: ttft p99 {:.3}s | e2e p99 {:.3}s over {} sessions",
            sart::util::stats::percentile(&res.wall_ttft, 99.0),
            wall_p99,
            res.outcomes.len()
        );
        if let Some(path) = args.get("json") {
            let run = server::RunOutput {
                report,
                timeline: sart::metrics::Timeline::default(),
                engine_desc: format!("live({})", live.addr),
                cluster: None,
                cache_hit_tokens: res
                    .outcomes
                    .iter()
                    .map(|o| o.cached_prompt_tokens)
                    .sum(),
                prompt_tokens: 0,
                adaptive: Default::default(),
                outcomes: res.outcomes,
            };
            std::fs::write(path, format!("{}\n", run.to_json()))?;
            eprintln!("# wrote {path}");
        }
    }
    if res.requests_lost > 0 {
        bail!("{} requests lost (accepted but never finalized)",
              res.requests_lost);
    }
    Ok(())
}

fn cmd_inspect(_args: &Args) -> Result<()> {
    let dir = sart::runtime::artifacts_dir();
    let manifest = sart::runtime::Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    for (name, m) in &manifest.models {
        println!(
            "model {name}: d={} L={} H={} ff={} vocab={} max_seq={} \
             prompt={} chunk_t={}",
            m.config.d_model,
            m.config.n_layers,
            m.config.n_heads,
            m.config.d_ff,
            m.config.vocab_size,
            m.config.max_seq,
            m.config.prompt_len,
            m.chunk_t
        );
        println!(
            "  params: {} tensors, {} elements",
            m.params.len(),
            m.params.iter().map(|p| p.num_elements).sum::<usize>()
        );
        println!("  decode buckets: {:?}", m.decode.batches());
    }
    println!(
        "prm {}: {} tensors; score buckets {:?}",
        manifest.prm.name,
        manifest.prm.params.len(),
        manifest.prm.score.batches()
    );
    for (name, d) in &manifest.datasets {
        println!("dataset {name}: {d:?}");
    }
    Ok(())
}
