//! `sart` — the serving CLI (leader entrypoint).
//!
//! Subcommands:
//!   serve     run one serving experiment and print the report
//!   bench     run all methods on one shared workload (comparison table)
//!   inspect   print artifact manifest / model inventory
//!
//! Examples:
//!   sart serve --method sart:8 --dataset synth-gpqa --rate 4 --requests 64
//!   sart serve --engine hlo --model r1mini-tiny --method sart:4 --slots 8
//!   sart bench --requests 32 --rate 2
//!   sart inspect

use anyhow::{bail, Result};
use sart::config::{Args, Method, ServeSpec};
use sart::metrics::ServeReport;
use sart::server;
use sart::util::stats::render_table;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let all: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match all.split_first() {
        Some((c, r)) if !c.starts_with("--") => (c.clone(), r.to_vec()),
        _ => ("serve".to_string(), all),
    };
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}` (serve|bench|inspect)"),
    }
}

const HELP: &str = "sart <serve|bench|inspect> [flags]
  --method   vanilla|self-consistency|sart|sart-noprune|rebase (suffix :N)
  --n/--m/--alpha/--beta   SART knobs (defaults N=8, M=N/2, 0.5, N/2)
  --engine   sim|hlo        --model  r1mini-tiny|r1mini-small
  --dataset  synth-gaokao|synth-gpqa
  --requests INT  --rate REQ/S (0=batch)  --slots INT  --kv-tokens INT
  --t-round INT  --temp F  --seed INT  --stepwise (disable fused decode)
  --replicas INT  engine replicas behind the dispatch layer (sim only)
  --lb rr|least-loaded|jsq|p2c|prefix-affinity   dispatch policy
  --gossip-rounds N  prefix-affinity: replicas advertise digest sets every
                     N scheduler steps; routing reads the table (0=probe)
  --gossip-adapt     retune the gossip period at runtime from stale routes
  --fault-plan PLAN  scripted failures, e.g. fail@2.5:1,restart@6.0:1
  --scale-min INT    enable the scale controller with INT replicas live
  --scale-up-queue N / --scale-down-queue N / --scale-up-prefill TOK
                     controller thresholds (down<up = hysteresis band)
  --scale-cooldown N arrivals between two scaling actions
  --prefix-cache PAGES   cross-request radix prefix cache budget (0=off)
  --prefix-share F       fraction of requests sharing a few-shot header
  --prefix-templates INT / --prefix-shots INT   header pool shape
  --prefill-chunk TOK    stream prompt prefill in TOK-token chunks (0=off)
  --prefill-budget TOK   per-round streamed-prefill budget (default=chunk)";

fn print_report(r: &ServeReport) {
    let rows = vec![r.row()];
    println!("{}", render_table(&ServeReport::ROW_HEADERS, &rows));
}

fn cmd_serve(args: &Args) -> Result<()> {
    let spec = ServeSpec::from_args(args)?;
    eprintln!("# spec: {spec:?}");
    let out = server::run(&spec)?;
    eprintln!("# engine: {}", out.engine_desc);
    print_report(&out.report);
    println!(
        "answered={:.3} tokens/req={:.1} branches/req={:.2} pruned/req={:.2}",
        out.report.answered,
        out.report.tokens_per_request,
        out.report.branches_started_per_request,
        out.report.branches_pruned_per_request,
    );
    if spec.prefill_chunk_tokens > 0 {
        let mean =
            |f: fn(&sart::coordinator::RequestOutcome) -> f64| -> f64 {
                out.outcomes.iter().map(f).sum::<f64>()
                    / out.outcomes.len().max(1) as f64
            };
        println!(
            "chunked prefill ({} tok/chunk, {} tok/round): \
             ttft mean {:.3}s = queue {:.3}s + prefill-stream {:.3}s",
            spec.prefill_chunk_tokens,
            spec.max_batched_prefill_tokens,
            mean(|o| o.ttft()),
            mean(|o| o.queue_latency()),
            mean(|o| o.prefill_latency()),
        );
    }
    if out.prompt_tokens > 0 && out.cache_hit_tokens > 0 {
        println!(
            "prefix-cache: {}/{} prompt tokens served from cache ({:.1}%)",
            out.cache_hit_tokens,
            out.prompt_tokens,
            100.0 * out.cache_hit_tokens as f64 / out.prompt_tokens as f64,
        );
    }
    if let Some(c) = &out.cluster {
        println!(
            "cluster: {} replicas, lb={} | req/replica {:?} | \
             occupancy-skew {:.2} request-skew {:.2} | cache-hit {:.1}%",
            c.replicas,
            c.lb,
            c.per_replica_requests,
            c.occupancy_skew,
            c.request_skew,
            100.0 * c.cache_hit_rate,
        );
        let g = &c.gossip;
        if g.gossip_rounds > 0 || g.probe_calls > 0 {
            println!(
                "gossip: period {} steps (effective {}) | {} advertisements \
                 ({} full + {} delta, {} digests sent) | {} digests in \
                 table | {} stale hits | {} probe calls",
                g.gossip_rounds,
                g.effective_gossip_rounds,
                g.advertisements,
                g.full_advertisements,
                g.delta_advertisements,
                g.digests_sent,
                g.digest_table_digests,
                g.stale_hits,
                g.probe_calls,
            );
        }
        let f = &c.fault;
        if *f != Default::default() {
            println!(
                "faults: {} failures, {} restarts | {} re-dispatches over \
                 {} requests | scale {} up / {} down",
                f.failures,
                f.restarts,
                f.redispatches,
                f.requests_redispatched,
                f.scale_ups,
                f.scale_downs,
            );
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let base = ServeSpec::from_args(args)?;
    let n = args.usize_or("n", 8)?;
    let trace = server::trace_for(&base)?;
    let methods = [
        Method::Vanilla,
        Method::SelfConsistency { n },
        Method::Rebase { n },
        Method::Sart {
            n,
            m: (n / 2).max(1),
            alpha: 0.5,
            beta: (n / 2).max(1),
        },
    ];
    let mut rows = Vec::new();
    for m in methods {
        if matches!(m, Method::Rebase { .. })
            && (base.replicas > 1
                || base.prefix_share > 0.0
                || base.prefill_chunk_tokens > 0)
        {
            // rebase has no cluster, prefix-workload or chunked path
            continue;
        }
        let mut spec = base.clone();
        spec.method = m;
        let out = server::run_on_trace(&spec, &trace)?;
        rows.push(out.report.row());
    }
    println!("{}", render_table(&ServeReport::ROW_HEADERS, &rows));
    Ok(())
}

fn cmd_inspect(_args: &Args) -> Result<()> {
    let dir = sart::runtime::artifacts_dir();
    let manifest = sart::runtime::Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    for (name, m) in &manifest.models {
        println!(
            "model {name}: d={} L={} H={} ff={} vocab={} max_seq={} \
             prompt={} chunk_t={}",
            m.config.d_model,
            m.config.n_layers,
            m.config.n_heads,
            m.config.d_ff,
            m.config.vocab_size,
            m.config.max_seq,
            m.config.prompt_len,
            m.chunk_t
        );
        println!(
            "  params: {} tensors, {} elements",
            m.params.len(),
            m.params.iter().map(|p| p.num_elements).sum::<usize>()
        );
        println!("  decode buckets: {:?}", m.decode.batches());
    }
    println!(
        "prm {}: {} tensors; score buckets {:?}",
        manifest.prm.name,
        manifest.prm.params.len(),
        manifest.prm.score.batches()
    );
    for (name, d) in &manifest.datasets {
        println!("dataset {name}: {d:?}");
    }
    Ok(())
}
