//! Adaptive per-request test-time-compute policy.
//!
//! SART's branch count `N`, early-stop quorum `M` and per-branch
//! thinking cap are global CLI constants; the related work (Thinkless,
//! "Don't Overthink it", Hybrid TTS) says they should be set per
//! request. [`AdaptiveConfig`] arms three online rules in the scheduler,
//! all driven by signals the serve loop already computes:
//!
//! * **Spread prune-to-k** — at a request's first scored round, if the
//!   finite PRM rewards of its running branches concentrate (max − min ≤
//!   `spread_tol`), the branches agree and the extras are redundant:
//!   keep the top `prune_keep` by reward, prune the rest through the
//!   ordinary pruning path, and lower the quorum to what can still
//!   answer. Fewer than two finite rewards (all-NaN, unscored, or an
//!   empty round) falls back to the static policy — a NaN never drives
//!   a decision.
//! * **Cap tightening** — once `min_samples` completion lengths have
//!   been observed serve-wide, a request whose running branches reach
//!   the `tail_pct` percentile of that distribution is in the
//!   over-thinking tail; its per-branch cap tightens to
//!   `tail × cap_slack` (never above the static cap, never below 1).
//! * **Easy fast path** — a dataset whose finished requests average a
//!   first-round reward ≥ `fast_reward` and a completion length ≤
//!   `fast_len` (after `min_samples` finishes) classifies easy: new
//!   arrivals route to a 1-branch no-think path (N = M = 1, cap =
//!   mean length × `cap_slack`) decided at arrival, before admission,
//!   so the KV reservation shrinks with the branch count. A fast-path
//!   branch capped without an answer still finalizes through the
//!   ordinary exhaustion (capped-vote) path — it can never hang on a
//!   quorum larger than its branch count.
//!
//! The layer is decision-only: it consumes no RNG draws and, with
//! `SchedConfig::adaptive` unset, every per-request knob equals the
//! static configuration — property-tested byte-identical to the
//! historical serve (single-engine and R = 2, audit on). Only the SART
//! policy scores running branches, so the spread and fast-path rules are
//! inert (static fallback) under policies that never produce per-round
//! rewards.

/// Knobs of the adaptive layer. `Some(AdaptiveConfig)` on
/// [`SchedConfig::adaptive`] arms it; `None` (the default) keeps the
/// static policy byte-for-byte.
///
/// [`SchedConfig::adaptive`]: super::SchedConfig::adaptive
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Max spread (max − min) of a request's finite first-round rewards
    /// for its branches to count as agreeing.
    pub spread_tol: f32,
    /// Branches kept (top by reward) when a spread prune fires. ≥ 1 —
    /// a prune may never leave a request without a live branch.
    pub prune_keep: usize,
    /// Percentile of the observed completion-length distribution that
    /// defines the over-thinking tail, in (0, 100].
    pub tail_pct: f64,
    /// Multiplier on the tail length (cap tightening) and on the mean
    /// easy-dataset length (fast-path cap). > 0.
    pub cap_slack: f64,
    /// Observations required before a distribution-driven rule fires:
    /// completion lengths serve-wide (cap tightening) and finished
    /// requests per dataset (fast path).
    pub min_samples: usize,
    /// Mean first-round reward a dataset must reach to classify easy.
    pub fast_reward: f32,
    /// Mean completion length a dataset must stay under to classify
    /// easy (tokens). > 0.
    pub fast_len: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            spread_tol: 0.05,
            prune_keep: 2,
            tail_pct: 90.0,
            cap_slack: 1.25,
            min_samples: 8,
            fast_reward: 0.55,
            fast_len: 48.0,
        }
    }
}

/// One adaptive decision, recorded in request order for determinism
/// tests (same seed ⇒ identical trace ⇒ identical decisions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptiveDecision {
    /// External request id (`Request::id`).
    pub request: usize,
    pub kind: AdaptiveDecisionKind,
}

/// What the adaptive layer did to one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptiveDecisionKind {
    /// Routed to the 1-branch no-think path at arrival (N = M = 1) with
    /// this per-branch cap.
    FastPath { cap: usize },
    /// First-round rewards concentrated: this many surplus branches
    /// were pruned, keeping the top `prune_keep`.
    SpreadPrune { pruned: usize },
    /// Running length reached the over-thinking tail: the per-branch
    /// cap tightened to this value.
    CapTighten { cap: usize },
    /// The first scored round had fewer than two finite rewards
    /// (all-NaN, unscored, or empty) — the static policy stands.
    StaticFallback,
}

/// Counters and the decision log of one serve (or one replica
/// incarnation — the cluster layer merges them per replica).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdaptiveStats {
    /// Requests routed to the 1-branch no-think path at arrival.
    pub fast_path_requests: usize,
    /// Branches pruned by the spread rule (on top of SART's own
    /// threshold pruning).
    pub spread_pruned_branches: usize,
    /// Requests whose per-branch cap was tightened mid-flight.
    pub cap_tightened_requests: usize,
    /// Requests whose first scored round could not produce a spread
    /// (fewer than two finite rewards) and kept the static policy.
    pub static_fallbacks: usize,
    /// Every decision in the order it landed.
    pub decisions: Vec<AdaptiveDecision>,
}

impl AdaptiveStats {
    /// Fold another incarnation's stats into this one (cluster merge;
    /// decision order follows incarnation order).
    pub fn merge(&mut self, other: AdaptiveStats) {
        self.fast_path_requests += other.fast_path_requests;
        self.spread_pruned_branches += other.spread_pruned_branches;
        self.cap_tightened_requests += other.cap_tightened_requests;
        self.static_fallbacks += other.static_fallbacks;
        self.decisions.extend(other.decisions);
    }

    /// Nothing recorded — what a policy-off serve must report.
    pub fn is_empty(&self) -> bool {
        *self == AdaptiveStats::default()
    }
}

/// Running per-dataset aggregates behind the easy classification
/// (updated at finalization; read at arrival).
#[derive(Debug, Clone, Copy, Default)]
pub struct DatasetStats {
    /// Finished requests of this dataset.
    pub finished: usize,
    /// Σ / count of mean first-round rewards (finite only).
    pub reward_sum: f64,
    pub reward_n: usize,
    /// Σ / count of harvested completion lengths.
    pub len_sum: f64,
    pub len_n: usize,
}

impl DatasetStats {
    /// Does this dataset classify easy under `cfg`? Requires
    /// `min_samples` finishes plus at least one reward and one length
    /// observation — an unscored dataset can never classify easy.
    pub fn is_easy(&self, cfg: &AdaptiveConfig) -> bool {
        self.finished >= cfg.min_samples.max(1)
            && self.reward_n > 0
            && self.len_n > 0
            && self.reward_sum / self.reward_n as f64
                >= cfg.fast_reward as f64
            && self.len_sum / self.len_n as f64 <= cfg.fast_len
    }

    /// Mean harvested completion length (caller checks `len_n > 0`).
    pub fn mean_len(&self) -> f64 {
        self.len_sum / self.len_n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = AdaptiveConfig::default();
        assert!(c.prune_keep >= 1);
        assert!(c.tail_pct > 0.0 && c.tail_pct <= 100.0);
        assert!(c.cap_slack > 0.0 && c.fast_len > 0.0);
    }

    #[test]
    fn stats_merge_accumulates() {
        let mut a = AdaptiveStats {
            fast_path_requests: 1,
            spread_pruned_branches: 2,
            cap_tightened_requests: 0,
            static_fallbacks: 1,
            decisions: vec![AdaptiveDecision {
                request: 0,
                kind: AdaptiveDecisionKind::StaticFallback,
            }],
        };
        let b = AdaptiveStats {
            fast_path_requests: 2,
            spread_pruned_branches: 0,
            cap_tightened_requests: 3,
            static_fallbacks: 0,
            decisions: vec![AdaptiveDecision {
                request: 7,
                kind: AdaptiveDecisionKind::FastPath { cap: 32 },
            }],
        };
        a.merge(b);
        assert_eq!(a.fast_path_requests, 3);
        assert_eq!(a.spread_pruned_branches, 2);
        assert_eq!(a.cap_tightened_requests, 3);
        assert_eq!(a.static_fallbacks, 1);
        assert_eq!(a.decisions.len(), 2);
        assert!(!a.is_empty());
        assert!(AdaptiveStats::default().is_empty());
    }

    #[test]
    fn easy_classification_needs_samples_rewards_and_short_lengths() {
        let cfg = AdaptiveConfig::default();
        let mut d = DatasetStats::default();
        assert!(!d.is_easy(&cfg));
        d.finished = cfg.min_samples;
        d.reward_sum = 0.9 * cfg.min_samples as f64;
        d.reward_n = cfg.min_samples;
        d.len_sum = 20.0 * cfg.min_samples as f64;
        d.len_n = cfg.min_samples;
        assert!(d.is_easy(&cfg));
        // Long chains disqualify, whatever the reward says.
        d.len_sum = 400.0 * cfg.min_samples as f64;
        assert!(!d.is_easy(&cfg));
        // Low rewards disqualify short chains too.
        d.len_sum = 20.0 * cfg.min_samples as f64;
        d.reward_sum = 0.1 * cfg.min_samples as f64;
        assert!(!d.is_easy(&cfg));
    }
}
