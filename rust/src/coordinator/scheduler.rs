//! The SART scheduling workflow (paper Algorithm 1) with continuous
//! batching, plus the Vanilla / Self-Consistency policies as degenerate
//! configurations of the same loop (the Rebase baseline lives in
//! `baselines::rebase`, sharing the same engine substrate).
//!
//! One loop iteration = one *round*:
//!
//! 1. admit arrivals into the request queue (FCFS);
//! 2. fill free engine slots from the branch queue, else by prefilling
//!    the request at the head of the request queue (which enqueues its N
//!    branches) — Algorithm 1 lines 3-11;
//! 3. batch-decode up to T steps (line 12 / 22);
//! 4. per involved request: phase transition explore→exploit on first
//!    completion (lines 24-27), harvest completed branches (28-31),
//!    prune low-reward branches (32-37), finalize on early stopping or
//!    exhaustion (38-40).
//!
//! KV-cache accounting (prefix sharing, reservation admission) gates
//! request admission; engine-slot availability gates branch starts. Both
//! scarcities produce the queuing behaviour the paper measures.
//!
//! # Per-round bookkeeping is O(batch), not O(lifetime requests)
//!
//! The paper's pitch only holds if coordination stays negligible next to
//! decoding (`benches/scheduler_tick.rs` tracks this), so every per-round
//! structure is incremental:
//!
//! * free engine slots live in a min-heap (lowest slot first, matching
//!   the previous linear scan's assignment order);
//! * the involved-request set is deduplicated with a per-request round
//!   stamp instead of a `contains` scan;
//! * each request keeps an ordered index of its Running branches, so
//!   round processing never scans terminated branches;
//! * `running_tokens` / running-branch counts for the per-round
//!   [`TimelinePoint`] are maintained incrementally instead of scanning
//!   every request ever admitted (which made a serve O(R²) in the
//!   lifetime request count R);
//! * prompts are tokenized once at arrival and PRM query buffers are
//!   reused across rounds.
//!
//! [`Scheduler::set_audit`] enables a cross-checking mode in which every
//! round recomputes each incremental quantity from scratch with the
//! straightforward scans and fails on any divergence — the property tests
//! serve random workloads under audit and additionally assert the audit
//! and fast paths produce byte-identical outcomes.
//!
//! # Stepped interface (cluster dispatch)
//!
//! [`Scheduler::serve`] is a thin loop over the incremental API —
//! [`Scheduler::dispatch`] queues a request, [`Scheduler::step`] runs one
//! round, [`Scheduler::finish`] assembles the [`ServeResult`] — so the
//! `cluster` dispatch layer can co-simulate R replicas event-by-event
//! (feeding each replica requests at their arrival times and advancing
//! whichever replica lags) while a single-replica cluster serve stays
//! byte-identical to `serve` on the same trace: both drive the exact same
//! step sequence.

use super::adaptive::{
    AdaptiveConfig, AdaptiveDecision, AdaptiveDecisionKind, AdaptiveStats,
    DatasetStats,
};
use super::types::*;
use crate::engine::{
    ChunkResult, Engine, PrefillChunkEntry, PrefillEntry, ReplayEntry,
    SlotId,
};
use crate::kvcache::{
    AdmissionOutcome, AdmissionRequest, KvCacheManager,
};
use crate::metrics::{Timeline, TimelinePoint};
use crate::prm::PrmScorer;
use crate::sampler;
use crate::tokenizer as tok;
use crate::util::rng::Rng;
use crate::workload::Request;
use anyhow::{bail, Context, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// KV-manager knobs, nested under [`SchedConfig::kv`] so the pressure
/// and preemption additions don't keep widening an already-flat struct.
/// Built with the `with_*` chain; the defaults reproduce the historical
/// behaviour exactly (pressure features off, property-tested
/// byte-identical).
#[derive(Debug, Clone)]
pub struct KvConfig {
    pub capacity_tokens: usize,
    pub page_tokens: usize,
    /// Retention budget (pages) of the cross-request radix prefix cache;
    /// 0 disables it, reproducing the pre-cache admission accounting
    /// byte for byte (property-tested).
    pub prefix_cache_pages: usize,
    /// Chunked prefill: stream each admission's uncovered prompt suffix
    /// into its slot in chunks of at most this many tokens, interleaved
    /// with decode rounds, instead of prefilling it in one dispatch. 0 =
    /// monolithic prefill — the historical behaviour, property-tested
    /// byte-identical (outcomes + timeline, audit on).
    pub prefill_chunk_tokens: usize,
    /// Per-round token budget across all streaming prefills (chunked mode
    /// only; 0 = unlimited). At least one chunk is always dispatched per
    /// round so prefill cannot starve; the budget is what bounds the
    /// decode stall one round can absorb.
    pub max_batched_prefill_tokens: usize,
    /// Stream-aware admission: admit a request once its *first* prefill
    /// chunk fits and grow the page pledge as the stream progresses,
    /// instead of pledging the whole uncovered suffix up front. Requires
    /// chunked prefill (`prefill_chunk_tokens > 0`); ignored otherwise.
    /// Streams pump strictly FIFO, so a pledge-stalled front stream
    /// blocks later ones (and new streamed admissions) rather than being
    /// overtaken — the head-of-line rule that prevents half-grown
    /// streams from livelocking each other.
    pub stream_admission: bool,
    /// Reward-driven preemption: when an admission is deferred for pages,
    /// swap out the lowest-reward running branches (release their pages,
    /// keep the generated tokens, resume later by recomputation) and
    /// retry. Rewards come from the scheduler's per-round PRM scores, so
    /// the manager reclaims exactly the branches SART was about to
    /// prune; policies that never score running branches (vanilla,
    /// self-consistency) leave the candidate pool empty.
    pub preempt: bool,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            capacity_tokens: 4096,
            page_tokens: 16,
            prefix_cache_pages: 0,
            prefill_chunk_tokens: 0,
            max_batched_prefill_tokens: 0,
            stream_admission: false,
            preempt: false,
        }
    }
}

impl KvConfig {
    pub fn new(capacity_tokens: usize, page_tokens: usize) -> Self {
        KvConfig { capacity_tokens, page_tokens, ..KvConfig::default() }
    }

    pub fn with_prefix_cache(mut self, pages: usize) -> Self {
        self.prefix_cache_pages = pages;
        self
    }

    pub fn with_chunked_prefill(
        mut self,
        chunk_tokens: usize,
        round_budget_tokens: usize,
    ) -> Self {
        self.prefill_chunk_tokens = chunk_tokens;
        self.max_batched_prefill_tokens = round_budget_tokens;
        self
    }

    pub fn with_stream_admission(mut self, on: bool) -> Self {
        self.stream_admission = on;
        self
    }

    pub fn with_preemption(mut self, on: bool) -> Self {
        self.preempt = on;
        self
    }
}

/// Scheduler knobs (paper defaults: M = N/2, alpha = 0.5, beta = N/2,
/// T = 400 — scaled to this testbed's token scale in `config`).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub policy: Policy,
    /// Decode steps per round (the paper's T).
    pub t_round: usize,
    pub temperature: f32,
    /// Per-branch generation cap (tokens after the prompt).
    pub max_new: usize,
    /// KV budget, paging, prefix cache and pressure knobs.
    pub kv: KvConfig,
    /// Adaptive test-time-compute policy (`--adaptive`): per-request
    /// N / M / cap set online from reward spread, the completion-length
    /// distribution and per-dataset difficulty. `None` (the default)
    /// reproduces the static policy byte for byte (property-tested).
    pub adaptive: Option<AdaptiveConfig>,
    pub seed: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            policy: Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::default(),
            adaptive: None,
            seed: 0,
        }
    }
}

// The single time authority lives in `util::clock` now (the wall-clock
// front end threads the same handle); re-exported here so existing
// `coordinator::ClockHandle` imports keep working.
pub use crate::util::clock::ClockHandle;

/// Result of a serve run.
pub struct ServeResult {
    pub outcomes: Vec<RequestOutcome>,
    pub timeline: Timeline,
    pub rounds: usize,
    pub engine_seconds: f64,
    pub wall_seconds: f64,
    /// Σ prompt tokens served from the cross-request prefix cache
    /// (0 with the cache disabled).
    pub cache_hit_tokens: usize,
    /// Σ prompt tokens over all admitted requests — the denominator for
    /// `prefill_tokens_saved_frac` in the prefix bench.
    pub prompt_tokens: usize,
    /// What the adaptive policy did (empty with `--adaptive` off).
    pub adaptive: AdaptiveStats,
}

/// What one [`Scheduler::step`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// A round was processed or virtual time advanced; call again.
    Worked,
    /// No active branches, no queued work, no pending arrivals.
    Idle,
}

/// One dispatched request exported by [`Scheduler::fail_and_drain`], in
/// dispatch order: either it already finished on the failing replica
/// (its outcome survives the failure), or it was still in flight and the
/// cluster layer must re-dispatch it to a survivor.
#[derive(Debug)]
pub enum DrainItem {
    Finished(RequestOutcome),
    Unfinished(Request),
}

/// Point-in-time load of one scheduler, read by cluster dispatch policies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadSnapshot {
    pub now: f64,
    /// Dispatched-but-unadmitted requests (incoming + FCFS queue).
    pub queued_requests: usize,
    /// Admitted, not yet finalized.
    pub inflight_requests: usize,
    /// Occupied engine slots.
    pub running_branches: usize,
    /// Σ generated tokens over running branches.
    pub running_tokens: usize,
    /// Prompt tokens still waiting to stream into mid-prefill slots —
    /// work this replica has committed to but not yet computed (0 in
    /// monolithic serves). Load-aware dispatch must count it: a replica
    /// swallowing a long cold header is busier than its decoded tokens
    /// alone suggest.
    pub pending_prefill_tokens: usize,
    /// Lifetime requests dispatched to this scheduler.
    pub dispatched_total: usize,
    /// KV memory pressure: (used + pledged) / capacity pages, in [0, 1].
    /// The cluster's scale controller can treat a saturated cache like a
    /// deep queue (`--scale-pressure`); routing policies may shy away
    /// from replicas about to preempt.
    pub kv_pressure: f64,
}

impl LoadSnapshot {
    /// Requests anywhere in this replica (queue discipline metric for
    /// JSQ / power-of-two-choices).
    pub fn requests_in_system(&self) -> usize {
        self.queued_requests + self.inflight_requests
    }

    /// Token-load metric for least-loaded dispatch: decoded tokens plus
    /// the in-flight prefill backlog.
    pub fn token_load(&self) -> usize {
        self.running_tokens + self.pending_prefill_tokens
    }
}

/// Progress of one streaming (chunked) prefill: the slot's branch owns
/// the stream; `cursor` is the next prompt position to dispatch. The
/// prompt is shared (`Arc`) so per-chunk dispatches never copy tokens.
#[derive(Debug, Clone)]
struct PrefillCursor {
    ridx: usize,
    bidx: usize,
    cursor: usize,
    prompt: std::sync::Arc<[tok::Token]>,
}

/// The continuous-batching scheduler (Algorithm 1).
pub struct Scheduler<'e> {
    cfg: SchedConfig,
    engine: &'e mut dyn Engine,
    prm: &'e mut dyn PrmScorer,
    pub clock: ClockHandle,
    kv: KvCacheManager,
    requests: Vec<RequestState>,
    truths: Vec<u8>,
    /// Dispatched requests that have not yet reached their arrival time
    /// (the scheduler admits them once its clock passes `arrival`),
    /// paired with the routing layer's promised cached-token count
    /// (0 unless a gossip digest-table match routed the request here).
    incoming: VecDeque<(Request, usize)>,
    request_queue: VecDeque<usize>,
    branch_queue: VecDeque<(usize, usize)>,
    slots: Vec<Option<(usize, usize)>>,
    /// Free engine slots, lowest first (same assignment order as the
    /// linear `position(is_none)` scan this replaces).
    free_slots: BinaryHeap<Reverse<SlotId>>,
    /// Monotone decode-round counter; pairs with
    /// `RequestState::round_stamp` for O(1) involved-set dedup.
    round: u64,
    /// Σ generated tokens over Running branches (the `TimelinePoint`
    /// quantity), maintained incrementally.
    running_tokens: usize,
    /// Σ prompt tokens covered by the cross-request prefix cache at
    /// admission (cumulative; audit recomputes it from the per-request
    /// records).
    cache_hit_tokens_total: usize,
    /// Σ prompt tokens over admitted requests (cumulative).
    prompt_tokens_total: usize,
    /// Chunked prefill: per-slot stream cursors (`None` = the slot is
    /// decodable or free).
    prefilling: Vec<Option<PrefillCursor>>,
    /// Mid-prefill slots, FIFO — the per-round token budget is spent
    /// front-first, so the oldest admission's header completes first.
    prefill_queue: VecDeque<SlotId>,
    /// Σ not-yet-streamed prompt tokens over mid-prefill slots
    /// (incremental; audited).
    queued_prefill_tokens: usize,
    /// Install-only chunk entries (fully cached starts) accumulated by
    /// `fill_batch` for this round's `pump_prefill` dispatch.
    pending_installs: Vec<PrefillChunkEntry>,
    /// Preempted branches resuming this round: their slots recompute
    /// prompt + kept generated tokens (`Engine::replay`), charged like a
    /// prefill — the honest cost of a swap-in. Drained every `step`.
    pending_replays: Vec<ReplayEntry>,
    /// KV branch handle → (request, branch) — the scheduler's side of the
    /// preemption handshake (the manager ranks handles, the scheduler
    /// maps them back to branches). Maintained for every live
    /// reservation; audit-rebuilt.
    kv_index: HashMap<crate::kvcache::BranchId, (usize, usize)>,
    /// Lifetime branch/stream swap-outs (audited against the per-request
    /// counts).
    preemptions_total: usize,
    /// Streamed admission: the front stream could not grow its pledge
    /// last pump. While set, no new streamed admission may enter (the
    /// head-of-line anti-livelock rule); cleared when the front stream
    /// makes progress or resolves.
    stream_stalled: bool,
    /// Requests whose prompt became fully resident this round; stamped
    /// with `prefill_done_at` *after* the round's prefill dispatches are
    /// charged, so the TTFT split includes the dispatch cost in both
    /// modes (reused buffer, drained every round).
    prefill_done_buf: Vec<usize>,
    /// Σ engine seconds spent on prefill dispatches (timeline metric:
    /// the per-round delta is that round's decode stall).
    prefill_seconds: f64,
    /// Occupancy timeline, one point per decode round.
    timeline: Timeline,
    /// Σ engine compute seconds charged so far.
    engine_seconds: f64,
    /// Requests finalized so far (load accounting).
    finished_count: usize,
    /// Lifetime requests dispatched to this scheduler.
    dispatched_total: usize,
    /// Admissions that arrived via a gossip digest-table route (their
    /// `expected_cached_tokens > 0`), and how many of those the local
    /// radix cache could no longer fully honour — the staleness signal
    /// the cluster's adaptive gossip period polls. Reset with the other
    /// counters on `fail_and_drain`.
    table_routed_admissions: usize,
    stale_admissions: usize,
    /// Reused across rounds: decode result, involved list, PRM sequences,
    /// running-branch snapshot scratch.
    chunk: ChunkResult,
    involved_buf: Vec<usize>,
    prm_seqs: Vec<Vec<tok::Token>>,
    scratch: Vec<usize>,
    /// Cross-check every incremental structure against a from-scratch
    /// recomputation each round (tests; see module docs).
    audit: bool,
    /// Record [`ServeEvent`]s as scheduling decisions land (off by
    /// default). Emission is strictly write-only — no scheduling decision
    /// reads the buffer — so enabling it cannot perturb outcomes or
    /// timelines (the byte-identity property test pins this).
    emit_events: bool,
    events: Vec<ServeEvent>,
    /// Harvested completion lengths serve-wide, in harvest order — the
    /// distribution behind the adaptive over-thinking-tail rule. Empty
    /// with `--adaptive` off (audited).
    adaptive_lengths: Vec<f64>,
    /// Per-dataset difficulty aggregates behind the easy fast path,
    /// updated at finalization and read (key lookup only, never
    /// iterated — decisions stay deterministic) at arrival. Empty with
    /// `--adaptive` off (audited).
    dataset_stats: HashMap<String, DatasetStats>,
    /// Adaptive decision counters + log, exported via [`ServeResult`].
    adaptive_stats: AdaptiveStats,
    rng: Rng,
}

impl<'e> Scheduler<'e> {
    pub fn new(
        cfg: SchedConfig,
        engine: &'e mut dyn Engine,
        prm: &'e mut dyn PrmScorer,
        clock: ClockHandle,
    ) -> Scheduler<'e> {
        let slots = engine.caps().slots;
        let kv = KvCacheManager::with_prefix_cache(
            cfg.kv.capacity_tokens,
            cfg.kv.page_tokens,
            cfg.kv.prefix_cache_pages,
        );
        let rng = Rng::new(cfg.seed ^ 0xC0FFEE);
        Scheduler {
            cfg,
            engine,
            prm,
            clock,
            kv,
            requests: Vec::new(),
            truths: Vec::new(),
            incoming: VecDeque::new(),
            request_queue: VecDeque::new(),
            branch_queue: VecDeque::new(),
            slots: vec![None; slots],
            free_slots: (0..slots).map(Reverse).collect(),
            round: 0,
            running_tokens: 0,
            cache_hit_tokens_total: 0,
            prompt_tokens_total: 0,
            prefilling: vec![None; slots],
            prefill_queue: VecDeque::new(),
            queued_prefill_tokens: 0,
            pending_installs: Vec::new(),
            pending_replays: Vec::new(),
            kv_index: HashMap::new(),
            preemptions_total: 0,
            stream_stalled: false,
            prefill_done_buf: Vec::new(),
            prefill_seconds: 0.0,
            timeline: Timeline::default(),
            engine_seconds: 0.0,
            finished_count: 0,
            dispatched_total: 0,
            table_routed_admissions: 0,
            stale_admissions: 0,
            chunk: ChunkResult::default(),
            involved_buf: Vec::new(),
            prm_seqs: Vec::new(),
            scratch: Vec::new(),
            audit: false,
            emit_events: false,
            events: Vec::new(),
            adaptive_lengths: Vec::new(),
            dataset_stats: HashMap::new(),
            adaptive_stats: AdaptiveStats::default(),
            rng,
        }
    }

    /// Enable per-round cross-checking of every incremental structure
    /// against the straightforward full scans (slow; for tests).
    pub fn set_audit(&mut self, on: bool) {
        self.audit = on;
    }

    /// Record structured [`ServeEvent`]s as scheduling decisions land
    /// (drain them with [`Scheduler::drain_events`]). Off by default:
    /// recording is write-only and cannot change scheduling, it only
    /// costs the buffer and the token clones.
    pub fn set_emit_events(&mut self, on: bool) {
        self.emit_events = on;
    }

    /// Take the events recorded since the last drain, in emission order.
    pub fn drain_events(&mut self) -> Vec<ServeEvent> {
        std::mem::take(&mut self.events)
    }

    /// Drop any recorded-but-undrained events. [`fail_and_drain`]
    /// deliberately leaves the buffer alone (its branch terminations are
    /// event-silent, but events from steps before the failure may still
    /// be sitting there); a live front end that has already forwarded
    /// them calls this so a dead incarnation's leftovers never leak into
    /// the restarted one's stream.
    ///
    /// [`fail_and_drain`]: Scheduler::fail_and_drain
    pub fn discard_events(&mut self) {
        self.events.clear();
    }

    /// Serve a full trace to completion; requests must be sorted by
    /// arrival time. Equivalent to dispatching every request up front and
    /// stepping until idle.
    pub fn serve(&mut self, trace: &[Request]) -> Result<ServeResult> {
        self.serve_pump(trace, None)
    }

    /// [`Scheduler::serve`] as an explicit event pump: emission is
    /// enabled for the duration and every [`ServeEvent`] is forwarded to
    /// `sink` right after the step that produced it. Scheduling is
    /// byte-identical to `serve` (property-tested).
    pub fn serve_with(
        &mut self,
        trace: &[Request],
        sink: &mut dyn FnMut(ServeEvent),
    ) -> Result<ServeResult> {
        let prev = self.emit_events;
        self.emit_events = true;
        let res = self.serve_pump(trace, Some(sink));
        self.emit_events = prev;
        res
    }

    fn serve_pump(
        &mut self,
        trace: &[Request],
        mut sink: Option<&mut dyn FnMut(ServeEvent)>,
    ) -> Result<ServeResult> {
        let wall0 = std::time::Instant::now();
        for w in trace.windows(2) {
            if w[1].arrival < w[0].arrival {
                bail!("trace not sorted by arrival");
            }
        }
        for r in trace {
            self.dispatch(r.clone())?;
        }
        loop {
            let out = self.step()?;
            if let Some(s) = sink.as_deref_mut() {
                for ev in self.drain_events() {
                    s(ev);
                }
            }
            if out == StepOutcome::Idle {
                break;
            }
        }
        let mut res = self.finish()?;
        res.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(res)
    }

    /// Virtual (or wall) time of this scheduler's clock.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Hand a request to this scheduler (by value — callers that own the
    /// request hand it over without a clone). It enters the FCFS queue
    /// once the scheduler's clock reaches `arrival`. Dispatch order must
    /// be sorted by arrival (the cluster layer dispatches in global
    /// arrival order, so any per-replica subsequence is too).
    pub fn dispatch(&mut self, r: Request) -> Result<()> {
        self.dispatch_routed(r, 0)
    }

    /// [`Scheduler::dispatch`], additionally recording how many prompt
    /// tokens the cluster's routing layer promised were cached here (a
    /// gossip digest-table match; 0 = not a table route). The admission
    /// compares the promise against the radix cache's actual coverage
    /// and counts the shortfalls — the staleness signal behind the
    /// adaptive gossip period.
    pub fn dispatch_routed(
        &mut self,
        r: Request,
        expected_cached_tokens: usize,
    ) -> Result<()> {
        if let Some((last, _)) = self.incoming.back() {
            if r.arrival < last.arrival {
                bail!("trace not sorted by arrival");
            }
        }
        self.dispatched_total += 1;
        self.incoming.push_back((r, expected_cached_tokens));
        Ok(())
    }

    /// Tokens of `prompt` resident in this scheduler's radix prefix cache
    /// (longest interned full-page prefix). The cluster's prefix-affinity
    /// policy probes replicas with this at dispatch time (gossip off).
    pub fn cached_prefix_tokens(&self, prompt: &[tok::Token]) -> usize {
        self.kv.cached_prefix_tokens(prompt)
    }

    /// Distinct digests of the interned full-page prefixes resident in
    /// this scheduler's radix cache — what the cluster's gossip layer
    /// advertises into its `DigestTable` (`--gossip-rounds`). O(distinct
    /// digests); no tree walk.
    pub fn advertised_digests(&self) -> Vec<u64> {
        self.kv.advertised_digests()
    }

    /// Current load (cluster dispatch policies read this).
    pub fn load(&self) -> LoadSnapshot {
        LoadSnapshot {
            now: self.clock.now(),
            queued_requests: self.incoming.len() + self.request_queue.len(),
            inflight_requests: self.requests.len()
                - self.request_queue.len()
                - self.finished_count,
            running_branches: self.slots.len() - self.free_slots.len(),
            running_tokens: self.running_tokens,
            pending_prefill_tokens: self.queued_prefill_tokens,
            dispatched_total: self.dispatched_total,
            kv_pressure: self.kv.pressure(),
        }
    }

    /// One scheduling iteration: admit arrivals, fill the batch, decode a
    /// round and process it — or, with an empty batch, jump the clock to
    /// the next pending arrival. Returns [`StepOutcome::Idle`] when fully
    /// drained; errors on a stalled queue (a request too large for the KV
    /// budget).
    pub fn step(&mut self) -> Result<StepOutcome> {
        let now = self.clock.now();
        // 1. Move arrived requests into the FCFS queue.
        while self
            .incoming
            .front()
            .map(|(r, _)| r.arrival <= now)
            .unwrap_or(false)
        {
            let (r, expected) = self.incoming.pop_front().unwrap();
            let idx = self.requests.len();
            self.truths.push(r.question.answer());
            let prompt = r.prompt_tokens();
            // Adaptive fast path, decided at arrival (before admission,
            // so the KV reservation shrinks with the branch count): a
            // dataset whose finished requests classified easy routes to
            // N = M = 1 with a mean-length-derived cap. Reads only the
            // per-dataset aggregates — no RNG draw, no iteration order.
            let mut n_limit = self.cfg.policy.n_branches();
            let mut m_req = self.cfg.policy.m_required();
            let mut cap = self.cfg.max_new;
            let mut fast_path = false;
            if let Some(acfg) = self.cfg.adaptive {
                if let Some(ds) = self.dataset_stats.get(&r.dataset) {
                    if ds.is_easy(&acfg) {
                        n_limit = 1;
                        m_req = 1;
                        cap = ((ds.mean_len() * acfg.cap_slack)
                            .ceil()
                            .max(1.0) as usize)
                            .min(self.cfg.max_new);
                        fast_path = true;
                    }
                }
            }
            let mut meta = self.initial_meta();
            if fast_path {
                // A 1-branch request must never explore-prune its only
                // branch; exploit's `n_limit - 1` keeps this at 0.
                meta.max_num_pruned = 0;
                self.adaptive_stats.fast_path_requests += 1;
                self.adaptive_stats.decisions.push(AdaptiveDecision {
                    request: r.id,
                    kind: AdaptiveDecisionKind::FastPath { cap },
                });
            }
            self.requests.push(RequestState {
                id: r.id,
                prompt,
                header: r.header,
                question: r.question,
                dataset: r.dataset,
                arrival: r.arrival,
                admitted_at: None,
                prefill_done_at: None,
                stream_slot: None,
                finished_at: None,
                meta,
                branches: Vec::new(),
                running: Vec::new(),
                completed: Vec::new(),
                round_stamp: 0,
                prefix: None,
                cached_prompt_tokens: 0,
                expected_cached_tokens: expected,
                final_answer: None,
                preemptions: 0,
                n_limit,
                m_req,
                cap,
                fast_path,
                spread_checked: false,
                cap_tightened: false,
                first_round_reward: None,
            });
            self.request_queue.push_back(idx);
        }

        // 2. Fill the batch (Algorithm 1 lines 3-11).
        let prefills = self.fill_batch()?;
        if !prefills.is_empty() {
            let cost = self.engine.prefill(&prefills)?;
            self.engine_seconds += cost;
            self.prefill_seconds += cost;
            self.clock.charge(cost);
        }
        // 2a. Resuming preempted branches recompute their prompt + kept
        // generated tokens; charged like a prefill (the swap-in cost).
        if !self.pending_replays.is_empty() {
            let replays = std::mem::take(&mut self.pending_replays);
            let cost = self.engine.replay(&replays)?;
            self.engine_seconds += cost;
            self.prefill_seconds += cost;
            self.clock.charge(cost);
        }
        // 2b. Chunked mode: dispatch this round's prefill work (installs
        // + budget-bounded stream chunks), so a long cold header trickles
        // in across rounds while resident branches keep decoding.
        let streamed = if self.cfg.kv.prefill_chunk_tokens > 0 {
            self.pump_prefill()?
        } else {
            false
        };

        // Stamp prompts that became fully resident this round, *after*
        // the prefill dispatches above were charged — so the TTFT split
        // (`prefill_latency`) includes the dispatch cost symmetrically
        // in monolithic and chunked modes.
        if !self.prefill_done_buf.is_empty() {
            let done_at = self.clock.now();
            let mut buf = std::mem::take(&mut self.prefill_done_buf);
            for ridx in buf.drain(..) {
                self.requests[ridx].prefill_done_at.get_or_insert(done_at);
            }
            self.prefill_done_buf = buf;
        }

        // Decodable slots: occupied and not mid-prefill.
        let active: Vec<SlotId> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(s, o)| {
                (o.is_some() && self.prefilling[s].is_none()).then_some(s)
            })
            .collect();

        if active.is_empty() {
            if streamed {
                // A prefill-only round: virtual time advanced by the
                // chunk dispatch; decode resumes once a stream completes.
                // Sample the timeline so the queued-prefill backlog is
                // visible while a cold header streams into an empty
                // batch.
                if self.audit {
                    self.audit_check()?;
                }
                self.push_timeline_point();
                return Ok(StepOutcome::Worked);
            }
            // Streamed admission deadlock: nothing decodes and the front
            // stream cannot grow its pledge. Evict the *youngest* stream
            // (pages fully released, request re-queued at the FCFS front)
            // so the head of line finishes first — the anti-livelock rule
            // between half-grown streams.
            if self.cfg.kv.stream_admission
                && self.stream_stalled
                && !self.prefill_queue.is_empty()
                && self.preempt_youngest_stream(now)?
            {
                return Ok(StepOutcome::Worked);
            }
            if let Some((next, _)) = self.incoming.front() {
                self.clock.idle_until(next.arrival);
                return Ok(StepOutcome::Worked);
            }
            if self.request_queue.is_empty() && self.branch_queue.is_empty() {
                return Ok(StepOutcome::Idle); // fully drained
            }
            // Queued work but nothing admissible: this can only mean a
            // deadlock (e.g. a single request too large for the budget).
            bail!(
                "scheduler stalled: {} queued requests cannot be admitted \
                 (kv capacity {} pages, {} free)",
                self.request_queue.len(),
                self.kv.capacity_pages(),
                self.kv.free_pages()
            );
        }

        // 3. Decode up to T steps (line 12). The ChunkResult is kept
        // across rounds so the engine can recycle emit buffers.
        let mut chunk = std::mem::take(&mut self.chunk);
        self.engine.decode_into(
            &active,
            self.cfg.t_round,
            self.cfg.temperature,
            &mut chunk,
        )?;
        self.engine_seconds += chunk.cost;
        self.clock.charge(chunk.cost);
        self.round += 1;
        let round = self.round;

        // Append emitted tokens; stamp involved requests (O(1) dedup).
        let mut involved = std::mem::take(&mut self.involved_buf);
        involved.clear();
        for (slot, toks) in &chunk.emitted {
            let Some((ridx, bidx)) = self.slots[*slot] else {
                bail!("engine emitted for empty slot {slot}");
            };
            let req = &mut self.requests[ridx];
            if req.round_stamp != round {
                req.round_stamp = round;
                involved.push(ridx);
            }
            let branch = &mut req.branches[bidx];
            branch.generated.extend_from_slice(toks);
            let kvb = branch.kv;
            self.running_tokens += toks.len();
            if let Some(kvb) = kvb {
                self.kv.note_decode(kvb, toks.len())?;
            }
            if self.emit_events && !toks.is_empty() {
                self.events.push(ServeEvent::BranchTokens {
                    request: self.requests[ridx].id,
                    branch: bidx,
                    tokens: toks.clone(),
                });
            }
        }
        self.chunk = chunk;

        // 4. Per-request round processing (lines 23-41).
        self.process_round(&involved)?;
        self.involved_buf = involved;

        if self.audit {
            self.audit_check()?;
        }

        self.push_timeline_point();
        Ok(StepOutcome::Worked)
    }

    /// Append the end-of-round occupancy sample (one per round, plus one
    /// per prefill-only round in chunked mode).
    fn push_timeline_point(&mut self) {
        let occupied = self.slots.len() - self.free_slots.len();
        let streaming = self.prefilling.iter().flatten().count();
        self.timeline.points.push(TimelinePoint {
            t: self.clock.now(),
            running_branches: occupied,
            // Residents who will sit through the next round's prefill
            // dispatches — mid-prefill slots stall nobody.
            decoding_branches: occupied - streaming,
            running_tokens: self.running_tokens,
            kv_pages_used: self.kv.used_pages(),
            queued_requests: self.request_queue.len(),
            cache_hit_tokens: self.cache_hit_tokens_total,
            queued_prefill_tokens: self.queued_prefill_tokens,
            prefill_seconds: self.prefill_seconds,
        });
    }

    /// Assemble the [`ServeResult`] after the last [`Scheduler::step`]
    /// returned [`StepOutcome::Idle`]. Outcomes are in dispatch (arrival)
    /// order. Errors if any request never finished. `wall_seconds` is left
    /// at 0 — the driving loop owns wall time.
    pub fn finish(&mut self) -> Result<ServeResult> {
        let mut outcomes = Vec::with_capacity(self.requests.len());
        for (i, r) in self.requests.iter().enumerate() {
            outcomes.push(Self::build_outcome(r, self.truths[i])?);
        }
        self.kv.check_invariants()?;
        Ok(ServeResult {
            outcomes,
            timeline: std::mem::take(&mut self.timeline),
            rounds: self.round as usize,
            engine_seconds: self.engine_seconds,
            wall_seconds: 0.0,
            cache_hit_tokens: self.cache_hit_tokens_total,
            prompt_tokens: self.prompt_tokens_total,
            adaptive: std::mem::take(&mut self.adaptive_stats),
        })
    }

    /// Non-destructive outcome lookup by external request id — the live
    /// front end reads this the moment a `Finalized` event lands, while
    /// [`Scheduler::finish`] stays the batch path. `None` if the id is
    /// unknown here or the request has not finished. The latest
    /// same-id dispatch wins (re-dispatched requests reuse ids).
    pub fn outcome_by_id(&self, id: usize) -> Option<RequestOutcome> {
        self.requests
            .iter()
            .enumerate()
            .rev()
            .find(|(_, r)| r.id == id && r.is_finished())
            .and_then(|(i, r)| Self::build_outcome(r, self.truths[i]).ok())
    }

    /// The final per-request record for a finished [`RequestState`] —
    /// shared by [`Scheduler::finish`] and the fault path's
    /// [`Scheduler::fail_and_drain`] so the two cannot drift.
    /// `redispatches` is left at 0; the cluster layer owns that count.
    fn build_outcome(r: &RequestState, truth: u8) -> Result<RequestOutcome> {
        let finished_at = r
            .finished_at
            .with_context(|| format!("request {} never finished", r.id))?;
        let admitted_at = r.admitted_at.unwrap_or(finished_at);
        Ok(RequestOutcome {
            id: r.id,
            dataset: r.dataset.clone(),
            arrival: r.arrival,
            admitted_at,
            prefill_done_at: r.prefill_done_at.unwrap_or(admitted_at),
            finished_at,
            answer: r.final_answer,
            truth,
            branches_started: r
                .branches
                .iter()
                .filter(|b| b.started_at.is_some())
                .count(),
            branches_pruned: r.meta.num_pruned,
            branches_completed: r.meta.num_completed,
            tokens_generated: r
                .branches
                .iter()
                .map(|b| b.generated.len())
                .sum(),
            response_lengths: r
                .completed
                .iter()
                .map(|c| c.length)
                .collect(),
            cached_prompt_tokens: r.cached_prompt_tokens,
            redispatches: 0,
            preemptions: r.preemptions,
        })
    }

    /// Simulate this replica dying right now: kill every in-flight
    /// branch, export every dispatched request — finished ones as their
    /// final outcomes, unfinished ones as the original [`Request`] for
    /// re-dispatch on a survivor — and reset to a cold just-booted state
    /// (fresh KV cache and counters; the clock and RNG carry forward, so
    /// a later restart rejoins at a sane virtual time).
    ///
    /// Items come back in dispatch order. The partial [`ServeResult`]
    /// carries this incarnation's timeline and cumulative counters (its
    /// `outcomes` list is empty — outcomes travel in the items). Errors
    /// if the teardown strands any KV state: every page and pledge must
    /// be released by the same paths early stopping uses.
    pub fn fail_and_drain(&mut self) -> Result<(Vec<DrainItem>, ServeResult)> {
        let now = self.clock.now();
        for ridx in 0..self.requests.len() {
            for bidx in 0..self.requests[ridx].branches.len() {
                if !self.requests[ridx].branches[bidx].is_terminal() {
                    self.terminate_branch(
                        ridx,
                        bidx,
                        BranchStatus::Stopped,
                        now,
                    )?;
                }
            }
        }
        self.request_queue.clear();
        self.branch_queue.clear();
        self.pending_installs.clear();
        self.pending_replays.clear();
        self.kv_index.clear();
        self.stream_stalled = false;
        self.prefill_done_buf.clear();
        // Every lease and pledge must be gone now — a page still charged
        // is stranded budget the restarted incarnation would inherit.
        self.kv.check_invariants()?;
        if self.kv.used_pages() != 0 || self.kv.pledged_pages() != 0 {
            bail!(
                "fail_and_drain stranded {} used / {} pledged pages",
                self.kv.used_pages(),
                self.kv.pledged_pages()
            );
        }
        // Close the timeline with a zero-occupancy sample at the failure
        // instant so downtime integrates as zero load in cluster reports.
        self.push_timeline_point();

        let truths = std::mem::take(&mut self.truths);
        let mut items =
            Vec::with_capacity(self.requests.len() + self.incoming.len());
        for (r, truth) in
            std::mem::take(&mut self.requests).into_iter().zip(truths)
        {
            if r.is_finished() {
                items.push(DrainItem::Finished(Self::build_outcome(
                    &r, truth,
                )?));
            } else {
                items.push(DrainItem::Unfinished(Request {
                    id: r.id,
                    question: r.question,
                    arrival: r.arrival,
                    dataset: r.dataset,
                    header: r.header,
                }));
            }
        }
        for (r, _expected) in std::mem::take(&mut self.incoming) {
            items.push(DrainItem::Unfinished(r));
        }

        let partial = ServeResult {
            outcomes: Vec::new(),
            timeline: std::mem::take(&mut self.timeline),
            rounds: self.round as usize,
            engine_seconds: self.engine_seconds,
            wall_seconds: 0.0,
            cache_hit_tokens: self.cache_hit_tokens_total,
            prompt_tokens: self.prompt_tokens_total,
            adaptive: std::mem::take(&mut self.adaptive_stats),
        };

        // Cold reset: the next incarnation boots with an empty radix
        // cache (it re-warms through gossip) and fresh counters.
        self.kv = KvCacheManager::with_prefix_cache(
            self.cfg.kv.capacity_tokens,
            self.cfg.kv.page_tokens,
            self.cfg.kv.prefix_cache_pages,
        );
        self.round = 0;
        self.running_tokens = 0;
        self.cache_hit_tokens_total = 0;
        self.prompt_tokens_total = 0;
        self.queued_prefill_tokens = 0;
        self.prefill_seconds = 0.0;
        self.engine_seconds = 0.0;
        self.finished_count = 0;
        self.dispatched_total = 0;
        self.table_routed_admissions = 0;
        self.stale_admissions = 0;
        self.preemptions_total = 0;
        // The restarted incarnation re-learns the workload from scratch,
        // like the radix cache it boots without.
        self.adaptive_lengths.clear();
        self.dataset_stats.clear();
        Ok((items, partial))
    }

    /// Jump this scheduler's clock forward to absolute time `t` (no-op
    /// if already past it). The cluster layer rejoins a restarted or
    /// newly activated replica at the current virtual instant with this.
    pub fn advance_clock_to(&mut self, t: f64) {
        self.clock.idle_until(t);
    }

    /// `(table-routed admissions, stale among them)` since construction
    /// or the last [`Scheduler::fail_and_drain`] reset. The cluster's
    /// adaptive gossip controller polls the deltas to tighten or relax
    /// the advertisement period.
    pub fn gossip_observed(&self) -> (usize, usize) {
        (self.table_routed_admissions, self.stale_admissions)
    }

    /// Take the next gossip advertisement for this replica's digest set:
    /// a full snapshot on the first take after construction or reset,
    /// deltas afterwards. See `KvCacheManager::take_advertisement`.
    pub fn take_advertisement(&mut self) -> crate::kvcache::Advertisement {
        self.kv.take_advertisement()
    }

    /// Force a full-snapshot advertisement (the digest-table's recovery
    /// path when a delta's base version no longer matches its row).
    pub fn full_advertisement(&mut self) -> (u64, Vec<u64>) {
        self.kv.full_advertisement()
    }

    fn initial_meta(&self) -> RequestMeta {
        let (threshold, max_pruned) = match self.cfg.policy {
            Policy::Sart { alpha, beta, .. } => (alpha, beta),
            _ => (f32::NEG_INFINITY, 0),
        };
        RequestMeta {
            phase: PrunePhase::Explore,
            threshold,
            max_num_pruned: max_pruned,
            num_completed: 0,
            num_harvested: 0,
            num_pruned: 0,
        }
    }

    /// Algorithm 1 lines 3-11: fill free slots from the branch queue,
    /// else by admitting + prefilling the head request.
    ///
    /// Monolithic mode returns the round's [`PrefillEntry`] batch. In
    /// chunked mode it returns nothing: branch starts either register a
    /// stream cursor (uncovered suffix > 0) or queue an install-only
    /// chunk, and `pump_prefill` dispatches both.
    fn fill_batch(&mut self) -> Result<Vec<PrefillEntry>> {
        let chunked = self.cfg.kv.prefill_chunk_tokens > 0;
        let streamed_mode = chunked && self.cfg.kv.stream_admission;
        let mut entries = Vec::new();
        let mut deferred: Vec<(usize, usize)> = Vec::new();
        let mut resume_blocked = false;
        let now = self.clock.now();
        loop {
            let Some(&Reverse(free_slot)) = self.free_slots.peek() else {
                break;
            };
            // Prefer an awaiting branch (lines 4-5); skip stale entries of
            // already-finalized requests.
            let mut assigned = false;
            while let Some((ridx, bidx)) = self.branch_queue.pop_front() {
                if self.requests[ridx].is_finished()
                    || self.requests[ridx].branches[bidx].status
                        != BranchStatus::Queued
                {
                    continue; // lazily dropped
                }
                // Chunked mode: a sibling cannot fork from a shared
                // prefix that is still streaming in — hold it aside
                // (order preserved, re-queued below) until the streaming
                // branch commits the prefix.
                if chunked && self.requests[ridx].stream_slot.is_some() {
                    deferred.push((ridx, bidx));
                    continue;
                }
                // A queued branch without a page reservation was
                // preempted: re-grow its reservation and replay its kept
                // tokens into the slot instead of starting fresh.
                if self.requests[ridx].branches[bidx].kv.is_none() {
                    let Some(prefix) = self.requests[ridx].prefix else {
                        // Stream-preemption leftover: the whole request
                        // was un-admitted and re-queued; this stale
                        // entry re-queues with the re-admission.
                        continue;
                    };
                    let has_holder = self.requests[ridx]
                        .branches
                        .iter()
                        .any(|b| b.kv.is_some());
                    let cap = self.requests[ridx].cap;
                    let outcome = if has_holder {
                        self.kv.admit(&AdmissionRequest::grow(
                            prefix, cap, 1,
                        ))?
                    } else {
                        // The prefix died with its last running sibling;
                        // re-admit this branch's pages from scratch (the
                        // prompt usually re-covers through the radix
                        // cache its commit interned).
                        self.kv.admit(&AdmissionRequest::monolithic(
                            &self.requests[ridx].prompt,
                            cap,
                            1,
                        ))?
                    };
                    let Some(adm) = outcome.admitted() else {
                        // A half-done branch outranks new admissions:
                        // hold the line until pages free up (strict
                        // resume priority — the alternative livelocks
                        // half-resumed requests behind fresh arrivals).
                        self.branch_queue.push_front((ridx, bidx));
                        resume_blocked = true;
                        break;
                    };
                    let kvb = adm.branches[0];
                    let gen_len;
                    {
                        let req = &mut self.requests[ridx];
                        if !has_holder {
                            req.prefix = Some(adm.prefix);
                        }
                        let b = &mut req.branches[bidx];
                        gen_len = b.generated.len();
                        b.kv = Some(kvb);
                        b.status = BranchStatus::Running;
                        b.slot = Some(free_slot);
                        b.started_at.get_or_insert(now);
                        let pos = req.running.partition_point(|&x| x < bidx);
                        req.running.insert(pos, bidx);
                    }
                    self.kv.note_decode(kvb, gen_len)?;
                    self.kv_index.insert(kvb, (ridx, bidx));
                    self.running_tokens += gen_len;
                    self.slots[free_slot] = Some((ridx, bidx));
                    self.free_slots.pop();
                    self.pending_replays.push(ReplayEntry {
                        slot: free_slot,
                        prompt: self.requests[ridx].prompt.clone(),
                        forced: self.requests[ridx].branches[bidx]
                            .generated
                            .clone(),
                        seed: self.requests[ridx].branches[bidx].seed,
                    });
                    assigned = true;
                    break;
                }
                let req = &mut self.requests[ridx];
                let prompt_len = req.prompt.len();
                // Prompt tokens the engine's cost model may skip: the
                // request's first branch pays for everything the
                // cross-request cache did not cover; sibling branches
                // fork from the request's own shared prefix pages, so
                // their whole prompt is already resident (charging each
                // sibling a full prefill would overstate cold cost N×).
                let first_start =
                    !req.branches.iter().any(|b| b.started_at.is_some());
                let cached_tokens = if first_start {
                    req.cached_prompt_tokens
                } else {
                    prompt_len
                };
                let seed = req.branches[bidx].seed;
                let b = &mut req.branches[bidx];
                b.status = BranchStatus::Running;
                b.slot = Some(free_slot);
                b.started_at = Some(now);
                let pos = req.running.partition_point(|&x| x < bidx);
                req.running.insert(pos, bidx);
                if chunked && cached_tokens < prompt_len {
                    // Streaming start: siblings block on this slot.
                    req.stream_slot = Some(free_slot);
                }
                self.slots[free_slot] = Some((ridx, bidx));
                self.free_slots.pop();
                if !chunked {
                    self.prefill_done_buf.push(ridx);
                    entries.push(PrefillEntry {
                        slot: free_slot,
                        prompt: self.requests[ridx].prompt.clone(),
                        seed,
                        cached_tokens,
                    });
                } else if cached_tokens == prompt_len {
                    // Zero uncovered tokens: install-only, dispatched
                    // this round; the slot decodes immediately.
                    self.prefill_done_buf.push(ridx);
                    self.pending_installs.push(PrefillChunkEntry {
                        slot: free_slot,
                        prompt: self.requests[ridx].prompt.as_slice().into(),
                        seed,
                        cached_tokens,
                        start: prompt_len,
                        len: 0,
                    });
                } else {
                    // Streaming start: the slot decodes only once its
                    // last chunk lands (`pump_prefill`). One token copy
                    // here; every chunk dispatch shares it.
                    self.queued_prefill_tokens += prompt_len - cached_tokens;
                    self.prefilling[free_slot] = Some(PrefillCursor {
                        ridx,
                        bidx,
                        cursor: cached_tokens,
                        prompt: self.requests[ridx]
                            .prompt
                            .as_slice()
                            .into(),
                    });
                    self.prefill_queue.push_back(free_slot);
                }
                assigned = true;
                break;
            }
            if assigned {
                continue;
            }
            if resume_blocked {
                break; // a preempted branch waits for pages: no new work
            }
            // Lines 6-7: admit the head request (FCFS, blocking on
            // budget). Token-level admission: the radix cache discounts
            // the covered prompt prefix, so a warm few-shot header costs
            // pages (and prefill) only for the uncovered suffix. Deferred
            // is a side-effect-free head-of-line block. Chunked
            // admissions pledge the uncovered suffix instead of
            // materializing it (pages lease in per chunk, the radix tree
            // interns on completion); streamed admissions pledge only the
            // first chunk and grow in `pump_prefill`.
            let Some(&ridx) = self.request_queue.front() else {
                break;
            };
            // Head-of-line rule: while the front stream cannot grow its
            // pledge, admitting more half-grown streams only deepens the
            // livelock they would form.
            if streamed_mode && self.stream_stalled {
                break;
            }
            let mut outcome = self.try_admit_head(ridx)?;
            if self.cfg.kv.preempt {
                if let AdmissionOutcome::Deferred { need_pages, free_pages } =
                    outcome
                {
                    // Under pressure: swap out the lowest-reward running
                    // branches to cover the shortfall, then retry once.
                    let deficit = need_pages.saturating_sub(free_pages);
                    if deficit > 0 && self.preempt_pages(deficit, now)? {
                        outcome = self.try_admit_head(ridx)?;
                    }
                }
            }
            let Some(admission) = outcome.admitted() else {
                break; // head-of-line blocks until memory frees up
            };
            self.request_queue.pop_front();
            self.cache_hit_tokens_total += admission.cached_tokens;
            self.prompt_tokens_total += self.requests[ridx].prompt.len();
            let req = &mut self.requests[ridx];
            req.admitted_at = Some(now);
            req.prefix = Some(admission.prefix);
            req.cached_prompt_tokens = admission.cached_tokens;
            // Table-routed admission: check the routing layer's promise
            // against what the radix cache actually still held.
            if req.expected_cached_tokens > 0 {
                self.table_routed_admissions += 1;
                if admission.cached_tokens < req.expected_cached_tokens {
                    self.stale_admissions += 1;
                }
            }
            if req.branches.is_empty() {
                for kvb in admission.branches {
                    let seed = self.rng.next_u64();
                    let mut b = Branch::new(seed);
                    b.kv = Some(kvb);
                    req.branches.push(b);
                    let bidx = req.branches.len() - 1;
                    self.kv_index.insert(kvb, (ridx, bidx));
                    self.branch_queue.push_back((ridx, bidx));
                }
            } else {
                // Re-admission after a stream preemption: the branches
                // (and their sampling seeds) survived un-admission; only
                // the page reservations are new.
                debug_assert_eq!(
                    req.branches.len(),
                    admission.branches.len()
                );
                for (bidx, (b, kvb)) in req
                    .branches
                    .iter_mut()
                    .zip(admission.branches)
                    .enumerate()
                {
                    debug_assert!(b.kv.is_none());
                    b.kv = Some(kvb);
                    self.kv_index.insert(kvb, (ridx, bidx));
                    self.branch_queue.push_back((ridx, bidx));
                }
            }
            if self.emit_events {
                self.events.push(ServeEvent::Admitted {
                    request: self.requests[ridx].id,
                    at: now,
                });
            }
        }
        // Blocked siblings go back to the queue front, order preserved.
        for &e in deferred.iter().rev() {
            self.branch_queue.push_front(e);
        }
        Ok(entries)
    }

    /// Build and run the head request's admission under the configured
    /// mode: monolithic charges the uncovered prompt up front, chunked
    /// pledges the whole uncovered suffix, streamed pledges only the
    /// first chunk (the pledge then grows per chunk in `pump_prefill`).
    fn try_admit_head(&mut self, ridx: usize) -> Result<AdmissionOutcome> {
        // Per-request effective values: equal to the static config unless
        // the adaptive layer routed this request to the fast path (then
        // the reservation shrinks to one branch with a tighter cap).
        let n = self.requests[ridx].n_limit;
        let cap = self.requests[ridx].cap;
        let prompt = &self.requests[ridx].prompt;
        let req = if self.cfg.kv.prefill_chunk_tokens == 0 {
            AdmissionRequest::monolithic(prompt, cap, n)
        } else if self.cfg.kv.stream_admission {
            AdmissionRequest::streamed(
                prompt,
                cap,
                n,
                self.cfg.kv.prefill_chunk_tokens,
            )
        } else {
            AdmissionRequest::chunked(prompt, cap, n)
        };
        self.kv.admit(&req)
    }

    /// Reward-driven preemption (`--kv-preempt`): swap out the
    /// lowest-reward running branches until `need` pages come free or the
    /// candidate pool runs dry. A candidate is skipped unless it is
    /// decoding (Running, not mid-prefill) and at least one sibling keeps
    /// a page reservation — the prefix lease must survive so the resume
    /// can grow from it. Returns whether anything was swapped out.
    fn preempt_pages(&mut self, need: usize, now: f64) -> Result<bool> {
        let free0 = self.kv.free_pages();
        let mut any = false;
        for kvb in self.kv.preemption_candidates(need) {
            if self.kv.free_pages() - free0 >= need {
                break;
            }
            let Some(&(ridx, bidx)) = self.kv_index.get(&kvb) else {
                bail!("preemption candidate {kvb:?} missing from kv index");
            };
            let req = &self.requests[ridx];
            let b = &req.branches[bidx];
            if b.status != BranchStatus::Running {
                continue;
            }
            let Some(slot) = b.slot else { continue };
            if self.prefilling[slot].is_some() {
                continue; // streams are evicted whole, not mid-chunk
            }
            if req.branches.iter().filter(|b| b.kv.is_some()).count() < 2 {
                continue; // the last holder keeps the prefix leased
            }
            self.preempt_branch(ridx, bidx, now)?;
            any = true;
        }
        Ok(any)
    }

    /// Swap one running branch out: release its pages and engine slot,
    /// keep its generated tokens, PRM reward and sampling seed, and
    /// re-queue it. It resumes through a `Grow` admission plus an engine
    /// replay of the kept tokens (recompute-on-resume) in `fill_batch`.
    fn preempt_branch(
        &mut self,
        ridx: usize,
        bidx: usize,
        now: f64,
    ) -> Result<()> {
        let req = &mut self.requests[ridx];
        let b = &mut req.branches[bidx];
        debug_assert_eq!(b.status, BranchStatus::Running);
        let gen_len = b.generated.len();
        b.status = BranchStatus::Queued;
        let slot = b.slot.take();
        let kvb = b.kv.take();
        if let Some(p) = req.running.iter().position(|&x| x == bidx) {
            req.running.remove(p);
        }
        req.preemptions += 1;
        self.running_tokens -= gen_len;
        if let Some(slot) = slot {
            self.slots[slot] = None;
            self.free_slots.push(Reverse(slot));
            self.engine.release(slot);
        }
        if let Some(kvb) = kvb {
            self.kv.release_branch(kvb)?;
            self.kv_index.remove(&kvb);
        }
        self.preemptions_total += 1;
        self.branch_queue.push_back((ridx, bidx));
        if self.emit_events {
            self.events.push(ServeEvent::BranchPreempted {
                request: self.requests[ridx].id,
                branch: bidx,
                at: now,
            });
        }
        Ok(())
    }

    /// Resolve a streamed-admission deadlock: evict the *youngest*
    /// half-grown stream entirely — release every page its request holds
    /// (the last release cancels the staged prefix's outstanding pledge),
    /// forget the admission, and push the request back to the FCFS queue
    /// front — so the older streams finish growing first. FCFS order is
    /// preserved: the youngest admission is the first to re-admit.
    ///
    /// Returns false when fewer than two streams are in flight: a lone
    /// stream can always grow (admission rejects oversized streams up
    /// front), so such a stall is a genuine budget deadlock and falls
    /// through to the stalled-scheduler error.
    fn preempt_youngest_stream(&mut self, now: f64) -> Result<bool> {
        if self.prefill_queue.len() < 2 {
            return Ok(false);
        }
        let slot = *self.prefill_queue.back().unwrap();
        let Some(cur) = self.prefilling[slot].take() else {
            bail!("stream preemption hit slot {slot} without a cursor");
        };
        let (ridx, bidx) = (cur.ridx, cur.bidx);
        self.prefill_queue.pop_back();
        let remaining = self.requests[ridx].prompt.len() - cur.cursor;
        self.queued_prefill_tokens -= remaining;
        // Roll the admission's counters back — the re-admission below
        // re-counts them, and the audit scans per-request records.
        self.cache_hit_tokens_total -=
            self.requests[ridx].cached_prompt_tokens;
        self.prompt_tokens_total -= self.requests[ridx].prompt.len();
        if self.requests[ridx].expected_cached_tokens > 0 {
            self.table_routed_admissions -= 1;
            if self.requests[ridx].cached_prompt_tokens
                < self.requests[ridx].expected_cached_tokens
            {
                self.stale_admissions -= 1;
            }
        }
        // Tear the streaming branch out of the batch…
        {
            let req = &mut self.requests[ridx];
            let b = &mut req.branches[bidx];
            debug_assert_eq!(b.status, BranchStatus::Running);
            debug_assert!(b.generated.is_empty());
            b.status = BranchStatus::Queued;
            b.slot = None;
            b.started_at = None;
            if let Some(p) = req.running.iter().position(|&x| x == bidx) {
                req.running.remove(p);
            }
            req.stream_slot = None;
            req.admitted_at = None;
            req.prefill_done_at = None;
            req.cached_prompt_tokens = 0;
            req.prefix = None;
            req.preemptions += 1;
        }
        self.slots[slot] = None;
        self.free_slots.push(Reverse(slot));
        self.engine.release(slot);
        // …and release every sibling's reservation: the last release
        // drops the staged prefix and cancels the outstanding pledge.
        for b in self.requests[ridx].branches.iter_mut() {
            if let Some(kvb) = b.kv.take() {
                self.kv.release_branch(kvb)?;
                self.kv_index.remove(&kvb);
            }
        }
        // Un-admit: the request rejoins the queue head; its branches
        // (seeds intact) wait for the re-admission to re-attach pages.
        self.branch_queue.retain(|&(r, _)| r != ridx);
        self.request_queue.push_front(ridx);
        self.stream_stalled = false;
        self.preemptions_total += 1;
        if self.emit_events {
            self.events.push(ServeEvent::BranchPreempted {
                request: self.requests[ridx].id,
                branch: bidx,
                at: now,
            });
        }
        Ok(true)
    }

    /// Chunked mode, once per round: dispatch every install-only entry
    /// plus streamed chunks from the FIFO queue under the per-round token
    /// budget (the first chunk always goes, so prefill cannot starve; the
    /// final chunk of a round may overshoot the budget by less than one
    /// chunk). Advances the KV lease cursor per chunk and commits the
    /// prefix — making the slot decodable and unblocking its siblings —
    /// when a stream completes. Returns whether anything was dispatched.
    fn pump_prefill(&mut self) -> Result<bool> {
        // Re-evaluated every pump: decode may have freed the pages the
        // front stream was stalled on.
        self.stream_stalled = false;
        let mut entries = std::mem::take(&mut self.pending_installs);
        let budget = match self.cfg.kv.max_batched_prefill_tokens {
            0 => usize::MAX,
            b => b,
        };
        let mut spent = 0usize;
        while spent < budget {
            let Some(&slot) = self.prefill_queue.front() else {
                break;
            };
            let (ridx, bidx, cursor, prompt) = {
                let Some(cur) = self.prefilling[slot].as_ref() else {
                    bail!("prefill queue holds slot {slot} without a cursor");
                };
                // Arc clone: the chunk shares the stream's prompt.
                (cur.ridx, cur.bidx, cur.cursor, cur.prompt.clone())
            };
            let req = &self.requests[ridx];
            let prompt_len = req.prompt.len();
            debug_assert!(cursor < prompt_len);
            let len = self.cfg.kv.prefill_chunk_tokens.min(prompt_len - cursor);
            let seed = req.branches[bidx].seed;
            let cached_tokens = req.cached_prompt_tokens;
            let prefix = req
                .prefix
                .context("streaming request lost its kv prefix")?;
            // Stream-aware admission pledged only the first chunk: grow
            // the pledge to cover this chunk before leasing it. A stall
            // blocks the whole FIFO (no overtaking — the head-of-line
            // rule) and flags `fill_batch` to stop admitting streams.
            if self.cfg.kv.stream_admission
                && !self.kv.ensure_pledged(prefix, len)?
            {
                self.stream_stalled = true;
                break;
            }
            // Lease the pages this chunk spans (pledge → used).
            self.kv.note_prefill(prefix, len)?;
            self.queued_prefill_tokens -= len;
            spent += len;
            if cursor + len == prompt_len {
                // Completing chunk: intern the prompt into the radix
                // cache and open the slot (and the request's siblings)
                // for decoding from the next active-set computation on.
                // The prefill-done stamp happens in step(), after this
                // round's dispatch cost is charged.
                self.kv.commit_prefix(prefix, &prompt)?;
                self.prefilling[slot] = None;
                self.prefill_queue.pop_front();
                self.requests[ridx].stream_slot = None;
                self.prefill_done_buf.push(ridx);
            } else {
                self.prefilling[slot].as_mut().unwrap().cursor =
                    cursor + len;
            }
            entries.push(PrefillChunkEntry {
                slot,
                prompt,
                seed,
                cached_tokens,
                start: cursor,
                len,
            });
        }
        if entries.is_empty() {
            return Ok(false);
        }
        let cost = self.engine.prefill_chunk(&entries)?;
        self.engine_seconds += cost;
        self.prefill_seconds += cost;
        self.clock.charge(cost);
        Ok(true)
    }

    /// Algorithm 1 lines 23-41 for every involved request.
    fn process_round(&mut self, involved: &[usize]) -> Result<()> {
        let now = self.clock.now();
        // Classify branch completions first (EOS / cap). Only the Running
        // branches of involved requests can complete this round.
        let mut completed_now: Vec<(usize, usize)> = Vec::new();
        for &ridx in involved {
            let mut snapshot = std::mem::take(&mut self.scratch);
            snapshot.clear();
            snapshot.extend_from_slice(&self.requests[ridx].running);
            for &bidx in &snapshot {
                let req = &mut self.requests[ridx];
                let b = &req.branches[bidx];
                debug_assert_eq!(b.status, BranchStatus::Running);
                let done = b.generated.last() == Some(&tok::EOS);
                let capped = b.generated.len() >= req.cap;
                if !(done || capped) {
                    continue;
                }
                let gen_len = b.generated.len();
                let b = &mut req.branches[bidx];
                b.status = if done {
                    BranchStatus::Completed
                } else {
                    BranchStatus::Capped
                };
                b.finished_at = Some(now);
                if let Some(p) = req.running.iter().position(|&x| x == bidx) {
                    req.running.remove(p);
                }
                self.running_tokens -= gen_len;
                completed_now.push((ridx, bidx));
                if self.emit_events && !done {
                    self.events.push(ServeEvent::BranchCapped {
                        request: self.requests[ridx].id,
                        branch: bidx,
                        at: now,
                    });
                }
            }
            self.scratch = snapshot;
        }

        // Batch all PRM queries for this round: completed branches (final
        // rewards) + running branches of pruning requests.
        let needs_prm = self.cfg.policy.needs_prm();
        let mut queries: Vec<(usize, usize)> = Vec::new();
        if needs_prm {
            queries.extend_from_slice(&completed_now);
            if self.cfg.policy.prunes() {
                for &ridx in involved {
                    if self.requests[ridx].is_finished() {
                        continue;
                    }
                    queries.extend(
                        self.requests[ridx]
                            .running
                            .iter()
                            .map(|&bidx| (ridx, bidx)),
                    );
                }
            }
        }
        if !queries.is_empty() {
            // Reuse the sequence buffers across rounds (prompt + generated
            // concatenation dominated round processing before).
            let mut seqs = std::mem::take(&mut self.prm_seqs);
            while seqs.len() < queries.len() {
                seqs.push(Vec::new());
            }
            for (qi, &(ridx, bidx)) in queries.iter().enumerate() {
                let r = &self.requests[ridx];
                let s = &mut seqs[qi];
                s.clear();
                s.extend_from_slice(&r.prompt);
                s.extend_from_slice(&r.branches[bidx].generated);
            }
            let refs: Vec<&[tok::Token]> = seqs[..queries.len()]
                .iter()
                .map(|s| s.as_slice())
                .collect();
            let scores = self.prm.score(&refs)?;
            for (&(ridx, bidx), score) in queries.iter().zip(scores) {
                self.requests[ridx].branches[bidx].reward = score;
            }
            // Reward-driven preemption: mirror the fresh PRM rewards into
            // the KV manager's eviction priorities, so under pressure it
            // ranks exactly the branches SART would prune first.
            if self.cfg.kv.preempt {
                for &(ridx, bidx) in &queries {
                    let b = &self.requests[ridx].branches[bidx];
                    if b.status != BranchStatus::Running
                        || b.reward.is_nan()
                    {
                        continue;
                    }
                    if let Some(kvb) = b.kv {
                        self.kv.set_branch_priority(kvb, b.reward)?;
                    }
                }
            }
            self.prm_seqs = seqs;
        }

        // Adaptive over-thinking tail, computed once per round: the
        // `tail_pct` percentile of every completion length harvested so
        // far. `None` until `min_samples` observations exist (or with the
        // adaptive layer off), so the rule cannot fire off noise.
        let tail = match self.cfg.adaptive {
            Some(acfg)
                if self.adaptive_lengths.len()
                    >= acfg.min_samples.max(1) =>
            {
                Some(crate::util::stats::percentile(
                    &self.adaptive_lengths,
                    acfg.tail_pct,
                ))
            }
            _ => None,
        };

        for &ridx in involved {
            if self.requests[ridx].is_finished() {
                continue;
            }
            // Phase transition (lines 24-27): the first completion flips
            // to exploitation with threshold α′ = that branch's reward.
            // Several branches can complete in the same round (they are
            // decoded in lockstep chunks), in which case α′ is the *max*
            // reward among them — taking an arbitrary sibling's reward
            // instead would leave the bar below a completion we already
            // know is reachable, under-pruning for the request's whole
            // exploit phase.
            let max_completed_reward = completed_now
                .iter()
                .filter(|&&(r, _)| r == ridx)
                .map(|&(r, b)| self.requests[r].branches[b].reward)
                .filter(|r| !r.is_nan())
                .fold(None, |acc: Option<f32>, r| {
                    Some(acc.map_or(r, |a| a.max(r)))
                });
            if needs_prm
                && self.cfg.policy.prunes()
                && self.requests[ridx].meta.phase == PrunePhase::Explore
            {
                if let Some(alpha_prime) = max_completed_reward {
                    let n = self.requests[ridx].n_limit;
                    let meta = &mut self.requests[ridx].meta;
                    meta.phase = PrunePhase::Exploit;
                    meta.threshold = alpha_prime;
                    meta.max_num_pruned = n - 1;
                }
            }

            // Harvest completions (lines 28-31).
            for &(r, bidx) in
                completed_now.iter().filter(|&&(r, _)| r == ridx)
            {
                self.harvest(r, bidx, now)?;
            }

            // Adaptive spread prune-to-k, evaluated exactly once per
            // request at its first scored round (whatever the outcome).
            if self.cfg.adaptive.is_some()
                && self.cfg.policy.prunes()
                && !self.requests[ridx].spread_checked
                && !self.requests[ridx].fast_path
                && !self.requests[ridx].is_finished()
            {
                self.adaptive_spread_check(ridx, now)?;
            }

            // Prune low-reward running branches (lines 32-37).
            if self.cfg.policy.prunes() {
                let mut snapshot = std::mem::take(&mut self.scratch);
                snapshot.clear();
                snapshot.extend_from_slice(&self.requests[ridx].running);
                for &bidx in &snapshot {
                    let meta = &self.requests[ridx].meta;
                    if meta.num_pruned >= meta.max_num_pruned {
                        break;
                    }
                    let b = &self.requests[ridx].branches[bidx];
                    if b.status != BranchStatus::Running {
                        continue;
                    }
                    if b.reward.is_nan() || b.reward >= meta.threshold {
                        continue;
                    }
                    self.terminate_branch(ridx, bidx, BranchStatus::Pruned, now)?;
                    self.requests[ridx].meta.num_pruned += 1;
                    if self.emit_events {
                        self.events.push(ServeEvent::BranchPruned {
                            request: self.requests[ridx].id,
                            branch: bidx,
                            at: now,
                        });
                    }
                }
                self.scratch = snapshot;
            }

            // Adaptive cap tightening: a request whose running branches
            // reach the over-thinking tail gets its per-branch cap pulled
            // down to `tail × cap_slack` (at most once; takes effect at
            // the next round's cap classification).
            if let Some(tail_len) = tail {
                let req = &self.requests[ridx];
                if !req.cap_tightened
                    && !req.is_finished()
                    && req.running.iter().any(|&b| {
                        req.branches[b].generated.len() as f64 >= tail_len
                    })
                {
                    let slack = self.cfg.adaptive.unwrap().cap_slack;
                    let new_cap = ((tail_len * slack).ceil().max(1.0)
                        as usize)
                        .min(self.cfg.max_new);
                    if new_cap < req.cap {
                        let rid = req.id;
                        let r = &mut self.requests[ridx];
                        r.cap = new_cap;
                        r.cap_tightened = true;
                        self.adaptive_stats.cap_tightened_requests += 1;
                        self.adaptive_stats.decisions.push(
                            AdaptiveDecision {
                                request: rid,
                                kind: AdaptiveDecisionKind::CapTighten {
                                    cap: new_cap,
                                },
                            },
                        );
                    }
                }
            }

            // Finalize (lines 38-40): M *answered* completions, or
            // exhaustion — every branch harvested or pruned, so waiting
            // longer cannot produce another answer. Counting answerless
            // (capped) harvests toward M would let junk responses finalize
            // a request early with nothing to vote on. `n_limit` / `m_req`
            // equal the static policy unless the adaptive layer shrank
            // them — a fast-path request (N = M = 1) whose only branch
            // capped without an answer exhausts here and finalizes through
            // the ordinary capped-vote path instead of hanging on an
            // unreachable quorum.
            let n = self.requests[ridx].n_limit;
            let m = self.requests[ridx].m_req;
            let meta = &self.requests[ridx].meta;
            let quorum = meta.num_completed >= m;
            let exhausted = meta.num_harvested + meta.num_pruned >= n;
            if quorum || exhausted {
                if self.emit_events && quorum {
                    self.events.push(ServeEvent::EarlyStop {
                        request: self.requests[ridx].id,
                        at: now,
                    });
                }
                self.finalize(ridx, now)?;
            }
        }
        Ok(())
    }

    /// Adaptive spread prune-to-k, at a request's first scored round
    /// (`spread_checked` guards exactly-once). Finite rewards only — an
    /// all-NaN, unscored or sub-2-sample round records a static fallback
    /// and changes nothing, so a NaN can never drive a decision. When the
    /// finite rewards concentrate within `spread_tol`, the branches
    /// agree: keep the top `prune_keep` by (reward, then branch index),
    /// prune the rest — surplus scored running branches plus every
    /// still-queued branch — through the ordinary pruning path, and lower
    /// the quorum to what the survivors can still deliver. Unscored
    /// (NaN) *running* branches are left alone: nothing is known about
    /// them.
    fn adaptive_spread_check(&mut self, ridx: usize, now: f64) -> Result<()> {
        let acfg = self.cfg.adaptive.unwrap();
        let rid = self.requests[ridx].id;
        self.requests[ridx].spread_checked = true;
        let mut scored: Vec<(usize, f32)> = {
            let req = &self.requests[ridx];
            req.running
                .iter()
                .map(|&b| (b, req.branches[b].reward))
                .filter(|(_, r)| !r.is_nan())
                .collect()
        };
        if !scored.is_empty() {
            let mean = scored.iter().map(|&(_, r)| r as f64).sum::<f64>()
                / scored.len() as f64;
            self.requests[ridx].first_round_reward = Some(mean as f32);
        }
        if scored.len() < 2 {
            self.adaptive_stats.static_fallbacks += 1;
            self.adaptive_stats.decisions.push(AdaptiveDecision {
                request: rid,
                kind: AdaptiveDecisionKind::StaticFallback,
            });
            return Ok(());
        }
        let max = scored.iter().map(|&(_, r)| r).fold(f32::MIN, f32::max);
        let min = scored.iter().map(|&(_, r)| r).fold(f32::MAX, f32::min);
        if max - min > acfg.spread_tol {
            return Ok(()); // genuine disagreement: explore as configured
        }
        scored.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
        });
        let keep = acfg.prune_keep.max(1).min(scored.len());
        let mut victims: Vec<usize> =
            scored[keep..].iter().map(|&(b, _)| b).collect();
        for (b, br) in self.requests[ridx].branches.iter().enumerate() {
            if br.status == BranchStatus::Queued {
                victims.push(b);
            }
        }
        victims.sort_unstable();
        let pruned = victims.len();
        for bidx in victims {
            self.terminate_branch(ridx, bidx, BranchStatus::Pruned, now)?;
            self.requests[ridx].meta.num_pruned += 1;
            if self.emit_events {
                self.events.push(ServeEvent::BranchPruned {
                    request: rid,
                    branch: bidx,
                    at: now,
                });
            }
        }
        if pruned == 0 {
            return Ok(()); // agreement, but nothing surplus to prune
        }
        // The quorum cannot exceed what can still answer: survivors
        // (scored keeps + unscored running) plus answers already in.
        let achievable = self.requests[ridx].meta.num_completed
            + self.requests[ridx].running.len();
        let req = &mut self.requests[ridx];
        req.m_req = req.m_req.min(achievable.max(1));
        self.adaptive_stats.spread_pruned_branches += pruned;
        self.adaptive_stats.decisions.push(AdaptiveDecision {
            request: rid,
            kind: AdaptiveDecisionKind::SpreadPrune { pruned },
        });
        Ok(())
    }

    /// Remove a completed/capped branch from the batch and record its
    /// response. (Status and the running index were already updated at
    /// classification time.)
    fn harvest(&mut self, ridx: usize, bidx: usize, now: f64) -> Result<()> {
        let (answer, reward, length) = {
            let b = &self.requests[ridx].branches[bidx];
            (tok::extract_answer(&b.generated), b.reward, b.generated.len())
        };
        // Free the slot and the kv reservation immediately.
        let b = &mut self.requests[ridx].branches[bidx];
        let slot = b.slot.take();
        let kvb = b.kv.take();
        if let Some(slot) = slot {
            self.slots[slot] = None;
            self.free_slots.push(Reverse(slot));
            self.engine.release(slot);
        }
        if let Some(kvb) = kvb {
            self.kv.release_branch(kvb)?;
            self.kv_index.remove(&kvb);
        }
        let meta = &mut self.requests[ridx].meta;
        meta.num_harvested += 1;
        if answer.is_some() {
            // Only answer-bearing responses count toward the early-stop
            // quorum; the response is still recorded below either way so
            // the final vote sees everything harvested.
            meta.num_completed += 1;
        }
        self.requests[ridx].completed.push(CompletedResponse {
            answer,
            reward,
            length,
            at: now,
        });
        if self.cfg.adaptive.is_some() {
            // Feed the serve-wide completion-length distribution behind
            // the over-thinking-tail rule.
            self.adaptive_lengths.push(length as f64);
        }
        Ok(())
    }

    fn terminate_branch(
        &mut self,
        ridx: usize,
        bidx: usize,
        status: BranchStatus,
        now: f64,
    ) -> Result<()> {
        let req = &mut self.requests[ridx];
        debug_assert!(!req.branches[bidx].is_terminal());
        if req.branches[bidx].status == BranchStatus::Running {
            let gen_len = req.branches[bidx].generated.len();
            if let Some(p) = req.running.iter().position(|&x| x == bidx) {
                req.running.remove(p);
            }
            self.running_tokens -= gen_len;
        }
        let b = &mut req.branches[bidx];
        b.status = status;
        b.finished_at = Some(now);
        let slot = b.slot.take();
        let kvb = b.kv.take();
        if let Some(slot) = slot {
            // The branch may die mid-prefill (request finalization /
            // preemption): abandon its stream — the engine drops the
            // partial slot state on release, and the kv prefix release
            // below (last sibling) frees the partial pages and cancels
            // the outstanding pledge.
            if let Some(cur) = self.prefilling[slot].take() {
                debug_assert_eq!((cur.ridx, cur.bidx), (ridx, bidx));
                let remaining =
                    self.requests[ridx].prompt.len() - cur.cursor;
                self.queued_prefill_tokens -= remaining;
                self.prefill_queue.retain(|&s| s != slot);
                self.requests[ridx].stream_slot = None;
            }
            self.slots[slot] = None;
            self.free_slots.push(Reverse(slot));
            self.engine.release(slot);
        }
        if let Some(kvb) = kvb {
            self.kv.release_branch(kvb)?;
            self.kv_index.remove(&kvb);
        }
        Ok(())
    }

    /// Early stopping: emit the final answer and release every remaining
    /// resource of the request.
    fn finalize(&mut self, ridx: usize, now: f64) -> Result<()> {
        let answer = match self.cfg.policy {
            Policy::Vanilla => {
                self.requests[ridx].completed.first().and_then(|c| c.answer)
            }
            Policy::SelfConsistency { .. } => {
                let answers: Vec<Option<u8>> = self.requests[ridx]
                    .completed
                    .iter()
                    .map(|c| c.answer)
                    .collect();
                sampler::majority_vote(&answers)
            }
            Policy::Sart { .. } | Policy::SartNoPrune { .. } => {
                let pairs: Vec<(Option<u8>, f32)> = self.requests[ridx]
                    .completed
                    .iter()
                    .map(|c| (c.answer, c.reward))
                    .collect();
                sampler::best_reward_vote(&pairs)
            }
        };
        // Terminate all remaining branches (early stopping, line 39).
        // One pass over the request's N branches, once per request.
        for bidx in 0..self.requests[ridx].branches.len() {
            if !self.requests[ridx].branches[bidx].is_terminal() {
                self.terminate_branch(ridx, bidx, BranchStatus::Stopped, now)?;
            }
        }
        let req = &mut self.requests[ridx];
        debug_assert!(req.running.is_empty());
        req.final_answer = answer;
        req.finished_at = Some(now);
        self.finished_count += 1;
        if self.cfg.adaptive.is_some() {
            // Per-dataset difficulty aggregates behind the easy fast
            // path: mean first-round reward (when the first scored round
            // produced one) and harvested completion lengths.
            let req = &self.requests[ridx];
            let ds = self.dataset_stats.entry(req.dataset.clone()).or_default();
            ds.finished += 1;
            if let Some(r) = req.first_round_reward {
                ds.reward_sum += r as f64;
                ds.reward_n += 1;
            }
            for c in &req.completed {
                ds.len_sum += c.length as f64;
                ds.len_n += 1;
            }
        }
        if self.emit_events {
            self.events.push(ServeEvent::Finalized {
                request: self.requests[ridx].id,
                answer,
                votes: self.requests[ridx].completed.len(),
                at: now,
            });
        }
        Ok(())
    }

    /// Audit mode: recompute every incremental structure with the
    /// straightforward full scans and fail on any divergence.
    fn audit_check(&self) -> Result<()> {
        let free_scan = self.slots.iter().filter(|s| s.is_none()).count();
        if free_scan != self.free_slots.len() {
            bail!(
                "audit: freelist size {} != scanned free slots {free_scan}",
                self.free_slots.len()
            );
        }
        if let Some(&Reverse(top)) = self.free_slots.peek() {
            let first = self.slots.iter().position(|s| s.is_none());
            if first != Some(top) {
                bail!("audit: freelist min {top} != first free slot {first:?}");
            }
        }
        let tokens_scan: usize = self
            .requests
            .iter()
            .filter(|r| !r.is_finished())
            .map(|r| r.running_tokens())
            .sum();
        if tokens_scan != self.running_tokens {
            bail!(
                "audit: running_tokens {} != scanned {tokens_scan}",
                self.running_tokens
            );
        }
        for (i, r) in self.requests.iter().enumerate() {
            let scan: Vec<usize> = r
                .branches
                .iter()
                .enumerate()
                .filter(|(_, b)| b.status == BranchStatus::Running)
                .map(|(j, _)| j)
                .collect();
            if scan != r.running {
                bail!(
                    "audit: request {i} running index {:?} != scanned {scan:?}",
                    r.running
                );
            }
            let mut expected_prompt = r.header.clone();
            expected_prompt.extend(r.question.prompt_tokens());
            if r.prompt != expected_prompt {
                bail!("audit: request {i} cached prompt drifted");
            }
            if r.cached_prompt_tokens > r.prompt.len() {
                bail!(
                    "audit: request {i} claims {} cached tokens of a {}-token \
                     prompt",
                    r.cached_prompt_tokens,
                    r.prompt.len()
                );
            }
            // Meta counters vs branch/response scans (threshold & quorum
            // bookkeeping).
            let pruned = r
                .branches
                .iter()
                .filter(|b| b.status == BranchStatus::Pruned)
                .count();
            if pruned != r.meta.num_pruned {
                bail!(
                    "audit: request {i} num_pruned {} != scanned {pruned}",
                    r.meta.num_pruned
                );
            }
            let harvested = r
                .branches
                .iter()
                .filter(|b| {
                    matches!(
                        b.status,
                        BranchStatus::Completed | BranchStatus::Capped
                    )
                })
                .count();
            if harvested != r.meta.num_harvested {
                bail!(
                    "audit: request {i} num_harvested {} != scanned \
                     {harvested}",
                    r.meta.num_harvested
                );
            }
            if harvested != r.completed.len() {
                bail!(
                    "audit: request {i} harvested {harvested} branches but \
                     recorded {} responses",
                    r.completed.len()
                );
            }
            let answered = r
                .completed
                .iter()
                .filter(|c| c.answer.is_some())
                .count();
            if answered != r.meta.num_completed {
                bail!(
                    "audit: request {i} num_completed {} != scanned answered \
                     {answered} (quorum must count only parsed answers)",
                    r.meta.num_completed
                );
            }
        }
        let finished_scan =
            self.requests.iter().filter(|r| r.is_finished()).count();
        if finished_scan != self.finished_count {
            bail!(
                "audit: finished_count {} != scanned {finished_scan}",
                self.finished_count
            );
        }
        // Prefix-cache counters vs the per-request admission records.
        let admitted = || {
            self.requests.iter().filter(|r| r.admitted_at.is_some())
        };
        let hit_scan: usize = admitted().map(|r| r.cached_prompt_tokens).sum();
        if hit_scan != self.cache_hit_tokens_total {
            bail!(
                "audit: cache_hit_tokens_total {} != scanned {hit_scan}",
                self.cache_hit_tokens_total
            );
        }
        let prompt_scan: usize = admitted().map(|r| r.prompt.len()).sum();
        if prompt_scan != self.prompt_tokens_total {
            bail!(
                "audit: prompt_tokens_total {} != scanned {prompt_scan}",
                self.prompt_tokens_total
            );
        }
        if self.cfg.kv.prefix_cache_pages == 0
            && self.cache_hit_tokens_total != 0
        {
            bail!("audit: cache hits recorded with the cache disabled");
        }
        // Gossip-staleness counters vs the per-request routing promises.
        let routed_scan =
            admitted().filter(|r| r.expected_cached_tokens > 0).count();
        if routed_scan != self.table_routed_admissions {
            bail!(
                "audit: table_routed_admissions {} != scanned {routed_scan}",
                self.table_routed_admissions
            );
        }
        let stale_scan = admitted()
            .filter(|r| {
                r.expected_cached_tokens > 0
                    && r.cached_prompt_tokens < r.expected_cached_tokens
            })
            .count();
        if stale_scan != self.stale_admissions {
            bail!(
                "audit: stale_admissions {} != scanned {stale_scan}",
                self.stale_admissions
            );
        }
        // Chunked-prefill structures vs full scans.
        if self.cfg.kv.prefill_chunk_tokens == 0
            && (self.queued_prefill_tokens != 0
                || !self.prefill_queue.is_empty()
                || self.prefilling.iter().any(|c| c.is_some())
                || !self.pending_installs.is_empty()
                || self.requests.iter().any(|r| r.stream_slot.is_some()))
        {
            bail!("audit: monolithic serve carries chunk-prefill state");
        }
        if !self.pending_installs.is_empty() {
            bail!("audit: install entries survived the round's pump");
        }
        if !self.prefill_done_buf.is_empty() {
            bail!("audit: prefill-done stamps survived the round");
        }
        let mut queued_scan = 0usize;
        let mut streaming = 0usize;
        for (s, cur) in self.prefilling.iter().enumerate() {
            let Some(cur) = cur else { continue };
            streaming += 1;
            let Some((ridx, bidx)) = self.slots[s] else {
                bail!("audit: mid-prefill slot {s} is unoccupied");
            };
            if (cur.ridx, cur.bidx) != (ridx, bidx) {
                bail!("audit: prefill cursor owner mismatch at slot {s}");
            }
            let req = &self.requests[ridx];
            if req.branches[bidx].status != BranchStatus::Running {
                bail!("audit: mid-prefill branch not Running at slot {s}");
            }
            if req.prefill_done_at.is_some() {
                bail!(
                    "audit: request {ridx} marked prefill-done while \
                     slot {s} still streams"
                );
            }
            if req.stream_slot != Some(s) {
                bail!(
                    "audit: request {ridx} stream_slot {:?} != streaming \
                     slot {s}",
                    req.stream_slot
                );
            }
            if cur.cursor < req.cached_prompt_tokens
                || cur.cursor >= req.prompt.len()
            {
                bail!(
                    "audit: prefill cursor {} out of [{}, {}) at slot {s}",
                    cur.cursor,
                    req.cached_prompt_tokens,
                    req.prompt.len()
                );
            }
            if cur.prompt[..] != req.prompt[..] {
                bail!("audit: stream prompt drifted from request {ridx}");
            }
            if !self.prefill_queue.contains(&s) {
                bail!("audit: mid-prefill slot {s} missing from the queue");
            }
            queued_scan += req.prompt.len() - cur.cursor;
        }
        if queued_scan != self.queued_prefill_tokens {
            bail!(
                "audit: queued_prefill_tokens {} != scanned {queued_scan}",
                self.queued_prefill_tokens
            );
        }
        if self.prefill_queue.len() != streaming {
            bail!(
                "audit: prefill queue holds {} slots but {streaming} are \
                 streaming",
                self.prefill_queue.len()
            );
        }
        for (i, r) in self.requests.iter().enumerate() {
            let started = r.branches.iter().any(|b| b.started_at.is_some());
            if r.prefill_done_at.is_some() && !started {
                bail!("audit: request {i} prefill-done before any start");
            }
            // stream_slot must mirror the per-slot cursor table exactly.
            if let Some(s) = r.stream_slot {
                if self.prefilling[s].as_ref().map(|c| c.ridx) != Some(i) {
                    bail!(
                        "audit: request {i} claims stream slot {s} but no \
                         matching cursor exists"
                    );
                }
            }
            // A live started request is either fully resident or has a
            // stream in flight (a finished one may have been terminated
            // mid-prefill).
            if started
                && !r.is_finished()
                && r.prefill_done_at.is_none()
                && r.stream_slot.is_none()
            {
                bail!(
                    "audit: request {i} started but neither prefill-done \
                     nor streaming"
                );
            }
        }
        // Preemption structures vs full scans.
        if !self.pending_replays.is_empty() {
            bail!("audit: replay entries survived the round's dispatch");
        }
        let mut index_scan: HashMap<crate::kvcache::BranchId, (usize, usize)> =
            HashMap::new();
        for (i, r) in self.requests.iter().enumerate() {
            for (j, b) in r.branches.iter().enumerate() {
                if let Some(kvb) = b.kv {
                    index_scan.insert(kvb, (i, j));
                }
            }
        }
        if index_scan != self.kv_index {
            bail!(
                "audit: kv index holds {} entries != scanned {}",
                self.kv_index.len(),
                index_scan.len()
            );
        }
        let preempt_scan: usize =
            self.requests.iter().map(|r| r.preemptions).sum();
        if preempt_scan != self.preemptions_total {
            bail!(
                "audit: preemptions_total {} != scanned {preempt_scan}",
                self.preemptions_total
            );
        }
        if !self.cfg.kv.preempt && self.kv.preemptable_pages() != 0 {
            bail!("audit: eviction priorities set with preemption disabled");
        }
        if !self.cfg.kv.preempt
            && !self.cfg.kv.stream_admission
            && self.preemptions_total != 0
        {
            bail!("audit: preemptions recorded with the pressure knobs off");
        }
        if !self.cfg.kv.stream_admission && self.stream_stalled {
            bail!("audit: stream stall flagged with streamed admission off");
        }
        // Adaptive-policy structures vs full scans.
        match self.cfg.adaptive {
            None => {
                for (i, r) in self.requests.iter().enumerate() {
                    if r.n_limit != self.cfg.policy.n_branches()
                        || r.m_req != self.cfg.policy.m_required()
                        || r.cap != self.cfg.max_new
                        || r.fast_path
                        || r.spread_checked
                        || r.cap_tightened
                        || r.first_round_reward.is_some()
                    {
                        bail!(
                            "audit: request {i} carries adaptive decisions \
                             with the adaptive policy off"
                        );
                    }
                }
                if !self.adaptive_stats.is_empty()
                    || !self.adaptive_lengths.is_empty()
                    || !self.dataset_stats.is_empty()
                {
                    bail!(
                        "audit: adaptive state recorded with the adaptive \
                         policy off"
                    );
                }
            }
            Some(_) => {
                let mut fast_scan = 0usize;
                for (i, r) in self.requests.iter().enumerate() {
                    if r.m_req < 1
                        || r.m_req > r.n_limit
                        || r.n_limit > self.cfg.policy.n_branches()
                    {
                        bail!(
                            "audit: request {i} violates 1 <= m_req ({}) <= \
                             n_limit ({}) <= N",
                            r.m_req,
                            r.n_limit
                        );
                    }
                    if r.cap < 1 || r.cap > self.cfg.max_new {
                        bail!(
                            "audit: request {i} cap {} outside [1, {}]",
                            r.cap,
                            self.cfg.max_new
                        );
                    }
                    if !r.branches.is_empty()
                        && r.branches.len() != r.n_limit
                    {
                        bail!(
                            "audit: request {i} holds {} branches under \
                             n_limit {}",
                            r.branches.len(),
                            r.n_limit
                        );
                    }
                    if r.fast_path {
                        fast_scan += 1;
                    }
                }
                if fast_scan != self.adaptive_stats.fast_path_requests {
                    bail!(
                        "audit: fast_path_requests {} != scanned {fast_scan}",
                        self.adaptive_stats.fast_path_requests
                    );
                }
                let (mut fp, mut spb, mut ct, mut sf) = (0, 0, 0, 0);
                for d in &self.adaptive_stats.decisions {
                    match d.kind {
                        AdaptiveDecisionKind::FastPath { .. } => fp += 1,
                        AdaptiveDecisionKind::SpreadPrune { pruned } => {
                            spb += pruned
                        }
                        AdaptiveDecisionKind::CapTighten { .. } => ct += 1,
                        AdaptiveDecisionKind::StaticFallback => sf += 1,
                    }
                }
                let s = &self.adaptive_stats;
                if fp != s.fast_path_requests
                    || spb != s.spread_pruned_branches
                    || ct != s.cap_tightened_requests
                    || sf != s.static_fallbacks
                {
                    bail!(
                        "audit: adaptive decision log ({fp}/{spb}/{ct}/{sf}) \
                         != counters ({}/{}/{}/{})",
                        s.fast_path_requests,
                        s.spread_pruned_branches,
                        s.cap_tightened_requests,
                        s.static_fallbacks
                    );
                }
                let len_scan: usize =
                    self.requests.iter().map(|r| r.completed.len()).sum();
                if len_scan != self.adaptive_lengths.len() {
                    bail!(
                        "audit: adaptive length samples {} != harvested \
                         responses {len_scan}",
                        self.adaptive_lengths.len()
                    );
                }
                let ds_finished: usize =
                    self.dataset_stats.values().map(|d| d.finished).sum();
                if ds_finished != self.finished_count {
                    bail!(
                        "audit: dataset-stat finishes {ds_finished} != \
                         finished_count {}",
                        self.finished_count
                    );
                }
            }
        }
        self.kv.check_invariants()
    }
}
