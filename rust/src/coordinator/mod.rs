//! The paper's L3 contribution: SART's scheduling workflow.
//!
//! [`types`] defines the request/branch state machines and Algorithm 1's
//! per-request metadata; [`scheduler`] implements the continuous-batching
//! loop with redundant sampling, early stopping and two-phase dynamic
//! pruning. Baseline policies (Vanilla, Self-Consistency) run through the
//! same loop as degenerate configurations for a fair comparison; Rebase
//! has its own tree scheduler in `crate::baselines`.

pub mod adaptive;
pub mod scheduler;
pub mod types;

pub use adaptive::{
    AdaptiveConfig, AdaptiveDecision, AdaptiveDecisionKind, AdaptiveStats,
};
pub use scheduler::{
    ClockHandle, DrainItem, KvConfig, LoadSnapshot, SchedConfig, Scheduler,
    ServeResult, StepOutcome,
};
pub use types::{
    Branch, BranchStatus, CompletedResponse, Policy, PrunePhase, RequestMeta,
    RequestOutcome, RequestState, ServeEvent,
};
