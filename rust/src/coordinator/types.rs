//! Request/branch state machines and SART metadata (Algorithm 1's `meta`).

use crate::kvcache;
use crate::tokenizer::Token;
use crate::workload::Question;

/// One observable scheduling decision, emitted by the event-emitting
/// core (`Scheduler::step` with events enabled) as it happens — the
/// stream the wall-clock front end forwards to live sessions, and the
/// unit the byte-identity property tests cross-check against the final
/// [`RequestOutcome`]s. `request` is the external request id
/// (`Request::id`), `branch` the per-request branch index, and every
/// `at` is in the serve's own timebase (virtual seconds under a
/// `SimClock`, wall seconds under a `RealClock`).
#[derive(Debug, Clone, PartialEq)]
pub enum ServeEvent {
    /// The request left the FCFS queue and acquired its KV reservation.
    Admitted { request: usize, at: f64 },
    /// Tokens one branch decoded this round, in generation order.
    BranchTokens { request: usize, branch: usize, tokens: Vec<Token> },
    /// SART pruned the branch (two-phase dynamic pruning).
    BranchPruned { request: usize, branch: usize, at: f64 },
    /// Memory pressure swapped the branch out: its pages are released,
    /// its generated tokens are kept, and it re-queues to resume by
    /// recomputation when pages free up. Only emitted with preemption
    /// enabled (`--kv-preempt`).
    BranchPreempted { request: usize, branch: usize, at: f64 },
    /// The branch hit the generation cap without an EOS.
    BranchCapped { request: usize, branch: usize, at: f64 },
    /// The early-stop quorum landed (M answered completions) — emitted
    /// just before `Finalized` when the quorum, not branch exhaustion,
    /// ended the request.
    EarlyStop { request: usize, at: f64 },
    /// The voted answer is final; `votes` counts the harvested
    /// completions that took part in the vote.
    Finalized { request: usize, answer: Option<u8>, votes: usize, at: f64 },
}

impl ServeEvent {
    /// External id of the request this event belongs to (session
    /// routing key of the live front end).
    pub fn request(&self) -> usize {
        match *self {
            ServeEvent::Admitted { request, .. }
            | ServeEvent::BranchTokens { request, .. }
            | ServeEvent::BranchPruned { request, .. }
            | ServeEvent::BranchPreempted { request, .. }
            | ServeEvent::BranchCapped { request, .. }
            | ServeEvent::EarlyStop { request, .. }
            | ServeEvent::Finalized { request, .. } => request,
        }
    }
}

/// Scheduling policy — which method serves the request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// No branch sampling (N = 1).
    Vanilla,
    /// Sample N branches, wait for all N, majority vote. Completed
    /// branches release their resources immediately (fair-comparison
    /// variant the paper uses).
    SelfConsistency { n: usize },
    /// Redundant sampling with early stopping + two-phase dynamic pruning
    /// (the paper's system). `m` completions finalize; pruning thresholds
    /// per Algorithm 1.
    Sart { n: usize, m: usize, alpha: f32, beta: usize },
    /// Ablation: redundant sampling with early stopping only (Fig. 6's
    /// "SART (w/o Pruning)").
    SartNoPrune { n: usize, m: usize },
}

impl Policy {
    pub fn n_branches(&self) -> usize {
        match *self {
            Policy::Vanilla => 1,
            Policy::SelfConsistency { n } => n,
            Policy::Sart { n, .. } => n,
            Policy::SartNoPrune { n, .. } => n,
        }
    }

    /// Completions required to finalize.
    pub fn m_required(&self) -> usize {
        match *self {
            Policy::Vanilla => 1,
            Policy::SelfConsistency { n } => n,
            Policy::Sart { m, .. } => m,
            Policy::SartNoPrune { m, .. } => m,
        }
    }

    pub fn prunes(&self) -> bool {
        matches!(self, Policy::Sart { .. })
    }

    /// Does this policy need PRM rewards? (SART needs them for pruning and
    /// final selection; Self-Consistency and Vanilla do not.)
    pub fn needs_prm(&self) -> bool {
        matches!(self, Policy::Sart { .. } | Policy::SartNoPrune { .. })
    }

    pub fn label(&self) -> String {
        match *self {
            Policy::Vanilla => "vanilla".into(),
            Policy::SelfConsistency { n } => format!("self-consistency(N={n})"),
            Policy::Sart { n, m, .. } => format!("sart(N={n},M={m})"),
            Policy::SartNoPrune { n, m } => {
                format!("sart-noprune(N={n},M={m})")
            }
        }
    }
}

/// Two-phase pruning state (Algorithm 1 lines 16, 24-26).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrunePhase {
    /// Exploration: low threshold alpha, at most beta prunes.
    Explore,
    /// Exploitation: threshold alpha' = reward of first completed branch,
    /// prune cap lifted to N-1.
    Exploit,
}

/// Per-request scheduling metadata (Algorithm 1's `meta[i]`).
#[derive(Debug, Clone)]
pub struct RequestMeta {
    pub phase: PrunePhase,
    pub threshold: f32,
    pub max_num_pruned: usize,
    /// Harvested branches whose answer parses — the early-stopping quorum
    /// counts only these, so M junk (capped, answerless) responses can
    /// never finalize a request.
    pub num_completed: usize,
    /// All harvested branches (EOS *or* cap), answered or not. Bounds the
    /// exhaustion check: `num_harvested + num_pruned == N` means no branch
    /// is left that could still produce an answer.
    pub num_harvested: usize,
    pub num_pruned: usize,
}

/// Lifecycle of one branch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchStatus {
    /// Waiting in the branch queue for a slot.
    Queued,
    /// Decoding in an engine slot.
    Running,
    /// Emitted EOS (a usable response).
    Completed,
    /// Pruned by the two-phase policy (resources released).
    Pruned,
    /// Terminated by request finalization (early stopping).
    Stopped,
    /// Hit the generation cap without EOS (counts as completed-invalid).
    Capped,
}

/// One reasoning branch.
#[derive(Debug)]
pub struct Branch {
    pub status: BranchStatus,
    pub slot: Option<crate::engine::SlotId>,
    pub kv: Option<kvcache::BranchId>,
    pub seed: u64,
    pub generated: Vec<Token>,
    pub reward: f32,
    pub started_at: Option<f64>,
    pub finished_at: Option<f64>,
}

impl Branch {
    pub fn new(seed: u64) -> Branch {
        Branch {
            status: BranchStatus::Queued,
            slot: None,
            kv: None,
            seed,
            generated: Vec::new(),
            reward: f32::NAN,
            started_at: None,
            finished_at: None,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self.status,
            BranchStatus::Completed
                | BranchStatus::Pruned
                | BranchStatus::Stopped
                | BranchStatus::Capped
        )
    }
}

/// A usable (completed or capped) response collected for final selection.
#[derive(Debug, Clone)]
pub struct CompletedResponse {
    pub answer: Option<u8>,
    pub reward: f32,
    pub length: usize,
    pub at: f64,
}

/// One in-flight request.
#[derive(Debug)]
pub struct RequestState {
    pub id: usize,
    pub question: Question,
    /// Serving prompt (`header` ⊕ question prompt), derived exactly once
    /// at arrival — the scheduler touches it on every admission check,
    /// branch start and PRM query, so it must not be re-tokenized on the
    /// hot path.
    pub prompt: Vec<Token>,
    /// Shared few-shot header this request arrived with (empty for plain
    /// traces; audit mode recomputes `prompt` from it).
    pub header: Vec<Token>,
    pub dataset: String,
    pub arrival: f64,
    pub admitted_at: Option<f64>,
    /// When this request's prompt KV became fully resident — stamped
    /// *after* the round's prefill dispatch cost is charged, in both
    /// modes, so monolithic and streamed prefill latencies compare
    /// symmetrically: the first branch's monolithic prefill, or the
    /// completing chunk of its stream.
    pub prefill_done_at: Option<f64>,
    /// Slot currently streaming this request's prefix in (chunked mode;
    /// `None` once committed, abandoned, or for monolithic serves).
    /// Siblings cannot fork while this is set.
    pub stream_slot: Option<crate::engine::SlotId>,
    pub finished_at: Option<f64>,
    pub meta: RequestMeta,
    pub branches: Vec<Branch>,
    /// Indices of branches currently in `BranchStatus::Running`, kept in
    /// ascending order (so per-round processing visits branches in the
    /// same order a full scan would). Maintained by the scheduler.
    pub running: Vec<usize>,
    pub completed: Vec<CompletedResponse>,
    /// Round number this request last received emissions in — the
    /// scheduler's O(1) involved-set dedup (replaces a `contains` scan).
    pub round_stamp: u64,
    pub prefix: Option<kvcache::PrefixId>,
    /// Prompt tokens the cross-request prefix cache covered at admission
    /// (0 before admission, on cold prompts, or with the cache disabled).
    pub cached_prompt_tokens: usize,
    /// Prompt tokens the cluster's routing layer promised were cached on
    /// this replica when it chose it (a gossip digest-table match; 0 for
    /// non-table routes). Compared against `cached_prompt_tokens` at
    /// admission to count stale routing decisions on the replica itself,
    /// which is what drives the adaptive gossip period.
    pub expected_cached_tokens: usize,
    pub final_answer: Option<u8>,
    /// Branch swap-outs this request absorbed under memory pressure
    /// (each costs a recompute-on-resume; 0 with preemption off).
    pub preemptions: usize,
    /// Effective branch count for this request. Equals
    /// `policy.n_branches()` unless the adaptive layer routed the request
    /// to the fast path at arrival (then 1). Admission, the exploit-phase
    /// prune cap and the exhaustion check all read this, never the global.
    pub n_limit: usize,
    /// Effective early-stop quorum. Equals `policy.m_required()` unless
    /// adapted (fast path ⇒ 1; spread prune may lower it to what the
    /// surviving branches can still deliver). Always `1 ≤ m_req ≤ n_limit`.
    pub m_req: usize,
    /// Effective per-branch generation cap. Equals `SchedConfig::max_new`
    /// unless the adaptive layer tightened it (over-thinking tail, fast
    /// path). Always `1 ≤ cap ≤ max_new`.
    pub cap: usize,
    /// Routed to the 1-branch no-think fast path at arrival.
    pub fast_path: bool,
    /// The adaptive spread rule already evaluated this request's first
    /// scored round (it fires at most once, whatever the outcome).
    pub spread_checked: bool,
    /// The adaptive layer already tightened `cap` (at most once).
    pub cap_tightened: bool,
    /// Mean finite PRM reward of the first scored round — the easy-prompt
    /// signal fed into per-dataset stats at finalization. `None` until
    /// scored, or when the first round had no finite reward.
    pub first_round_reward: Option<f32>,
}

impl RequestState {
    pub fn running_branches(&self) -> usize {
        self.branches
            .iter()
            .filter(|b| b.status == BranchStatus::Running)
            .count()
    }

    pub fn queued_branches(&self) -> usize {
        self.branches
            .iter()
            .filter(|b| b.status == BranchStatus::Queued)
            .count()
    }

    pub fn running_tokens(&self) -> usize {
        self.branches
            .iter()
            .filter(|b| b.status == BranchStatus::Running)
            .map(|b| b.generated.len())
            .sum()
    }

    pub fn is_finished(&self) -> bool {
        self.finished_at.is_some()
    }
}

/// Final per-request record handed to metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestOutcome {
    pub id: usize,
    pub dataset: String,
    pub arrival: f64,
    pub admitted_at: f64,
    /// When the prompt KV became fully resident (= `admitted_at` plus any
    /// slot wait and prefill streaming). Splits time-to-first-token into
    /// queueing (`queue_latency`) and prefill streaming
    /// (`prefill_latency`).
    pub prefill_done_at: f64,
    pub finished_at: f64,
    pub answer: Option<u8>,
    pub truth: u8,
    pub branches_started: usize,
    pub branches_pruned: usize,
    pub branches_completed: usize,
    pub tokens_generated: usize,
    pub response_lengths: Vec<usize>,
    /// Prompt tokens the serving replica's radix cache covered at
    /// admission (0 for cold prompts or with the cache disabled). The
    /// cluster's gossip layer compares this against the digest-table
    /// match that routed the request to count stale hits.
    pub cached_prompt_tokens: usize,
    /// How many times a replica failure forced this request to be
    /// re-dispatched (and re-prefilled) on a surviving replica. 0 on the
    /// single-engine path and in fault-free cluster serves; the added
    /// latency shows up in the ordinary latency fields, measured from the
    /// original arrival.
    pub redispatches: usize,
    /// Branch swap-outs under memory pressure: a running branch released
    /// its pages to a higher-priority admission and later resumed by
    /// recomputing through the prefix cache. 0 with `--kv-preempt` off;
    /// the recompute latency lands in the ordinary latency fields.
    pub preemptions: usize,
}

impl RequestOutcome {
    pub fn correct(&self) -> bool {
        self.answer == Some(self.truth)
    }

    pub fn e2e_latency(&self) -> f64 {
        self.finished_at - self.arrival
    }

    pub fn queue_latency(&self) -> f64 {
        self.admitted_at - self.arrival
    }

    /// Admission → prompt KV fully resident: slot wait plus prefill
    /// streaming. Together with `queue_latency` this splits the
    /// time-to-first-token; chunked prefill trades a longer
    /// `prefill_latency` for its own request against decode stalls for
    /// everyone else's.
    pub fn prefill_latency(&self) -> f64 {
        self.prefill_done_at - self.admitted_at
    }

    /// Arrival → prompt KV fully resident (a time-to-first-token proxy:
    /// the first decode step follows within one round).
    pub fn ttft(&self) -> f64 {
        self.prefill_done_at - self.arrival
    }

    pub fn inference_latency(&self) -> f64 {
        self.finished_at - self.admitted_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_shapes() {
        assert_eq!(Policy::Vanilla.n_branches(), 1);
        assert_eq!(Policy::Vanilla.m_required(), 1);
        let sc = Policy::SelfConsistency { n: 8 };
        assert_eq!(sc.n_branches(), 8);
        assert_eq!(sc.m_required(), 8);
        assert!(!sc.prunes() && !sc.needs_prm());
        let sart = Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 };
        assert_eq!(sart.m_required(), 4);
        assert!(sart.prunes() && sart.needs_prm());
        let np = Policy::SartNoPrune { n: 8, m: 4 };
        assert!(!np.prunes() && np.needs_prm());
    }

    #[test]
    fn branch_lifecycle() {
        let mut b = Branch::new(1);
        assert_eq!(b.status, BranchStatus::Queued);
        assert!(!b.is_terminal());
        b.status = BranchStatus::Running;
        assert!(!b.is_terminal());
        for s in [
            BranchStatus::Completed,
            BranchStatus::Pruned,
            BranchStatus::Stopped,
            BranchStatus::Capped,
        ] {
            b.status = s;
            assert!(b.is_terminal());
        }
    }

    #[test]
    fn outcome_latencies() {
        let o = RequestOutcome {
            id: 0,
            dataset: "d".into(),
            arrival: 1.0,
            admitted_at: 3.0,
            prefill_done_at: 4.0,
            finished_at: 10.0,
            answer: Some(4),
            truth: 4,
            branches_started: 8,
            branches_pruned: 2,
            branches_completed: 4,
            tokens_generated: 100,
            response_lengths: vec![10, 20],
            cached_prompt_tokens: 0,
            redispatches: 0,
            preemptions: 0,
        };
        assert!(o.correct());
        assert_eq!(o.e2e_latency(), 9.0);
        assert_eq!(o.queue_latency(), 2.0);
        assert_eq!(o.prefill_latency(), 1.0);
        assert_eq!(o.ttft(), 3.0);
        assert_eq!(o.inference_latency(), 7.0);
    }
}
