//! Workload generation: questions, datasets, arrival processes, traces.
//!
//! Rust mirror of `python/compile/data.py` (SynthHop: multi-hop pointer
//! chasing over an in-context digit map). The *question* generator
//! produces the serving requests (with ground-truth answers so accuracy is
//! measurable); the *trajectory* sampler reproduces the corpus generative
//! process and powers the simulation engine's scripted branches (the HLO
//! engine generates tokens from the trained model instead).

use crate::tokenizer as tok;
use crate::tokenizer::Token;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

pub const NUM_KEYS: usize = 10;

/// Difficulty profile of a dataset (mirror of `data.TaskSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub name: String,
    pub min_hops: u32,
    pub max_hops: u32,
    pub p_err: f64,
    pub p_rethink: f64,
    pub p_continue: f64,
}

impl TaskSpec {
    pub fn synth_gaokao() -> TaskSpec {
        TaskSpec {
            name: "synth-gaokao".into(),
            min_hops: 3,
            max_hops: 5,
            p_err: 0.08,
            p_rethink: 0.35,
            p_continue: 0.55,
        }
    }

    pub fn synth_gpqa() -> TaskSpec {
        TaskSpec {
            name: "synth-gpqa".into(),
            min_hops: 5,
            max_hops: 8,
            p_err: 0.13,
            p_rethink: 0.6,
            p_continue: 0.6,
        }
    }

    pub fn by_name(name: &str) -> Result<TaskSpec> {
        match name {
            "synth-gaokao" => Ok(Self::synth_gaokao()),
            "synth-gpqa" => Ok(Self::synth_gpqa()),
            _ => bail!("unknown dataset `{name}`"),
        }
    }

    /// Parse from the manifest's `datasets` section (keeps python and rust
    /// presets in lockstep; integration tests assert equality).
    pub fn from_json(j: &Json) -> Result<TaskSpec> {
        Ok(TaskSpec {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            min_hops: j.req("min_hops")?.as_usize().unwrap_or(0) as u32,
            max_hops: j.req("max_hops")?.as_usize().unwrap_or(0) as u32,
            p_err: j.req("p_err")?.as_f64().unwrap_or(0.0),
            p_rethink: j.req("p_rethink")?.as_f64().unwrap_or(0.0),
            p_continue: j.req("p_continue")?.as_f64().unwrap_or(0.0),
        })
    }
}

/// One request: a digit map, a start digit and a hop count.
#[derive(Debug, Clone, PartialEq)]
pub struct Question {
    pub mapping: [u8; NUM_KEYS], // mapping[k] = value of key k
    pub start: u8,
    pub hops: u8,
}

impl Question {
    pub fn answer(&self) -> u8 {
        let mut cur = self.start;
        for _ in 0..self.hops {
            cur = self.mapping[cur as usize];
        }
        cur
    }

    /// `<q> k v k v ... + start hops </q>` — key order must match
    /// `data.Question.tokens()` exactly (the trained model saw that order).
    pub fn tokens(&self) -> Vec<Token> {
        let mut order: Vec<usize> = (0..NUM_KEYS).collect();
        order.sort_by_key(|&k| {
            ((self.mapping[k] as usize * 7 + k * 3) % NUM_KEYS, k)
        });
        let mut out = vec![tok::Q];
        for k in order {
            out.push(tok::digit(k as u8));
            out.push(tok::digit(self.mapping[k]));
        }
        out.push(tok::PLUS);
        out.push(tok::digit(self.start));
        out.push(tok::digit(self.hops % 10));
        out.push(tok::EQ);
        out
    }

    /// Serving prompt: `<bos> <question> <think>`.
    pub fn prompt_tokens(&self) -> Vec<Token> {
        let mut out = vec![tok::BOS];
        out.extend(self.tokens());
        out.push(tok::THINK);
        out
    }

    /// Parse a question out of a serving prompt that may carry a shared
    /// few-shot header (`templated_trace`): the question proper is always
    /// the trailing `<bos> … <think>` window, so this parses the last 27
    /// tokens. Identical to [`Question::from_prompt`] on bare prompts.
    pub fn from_serving_prompt(prompt: &[Token]) -> Result<Question> {
        if prompt.len() < 27 {
            bail!("serving prompt too short: {} tokens", prompt.len());
        }
        Question::from_prompt(&prompt[prompt.len() - 27..])
    }

    /// Parse a question back out of its serving prompt — the inverse of
    /// `prompt_tokens`. Used by the simulation engine and the oracle PRM,
    /// which only ever see token streams (keeping their interfaces
    /// identical to the HLO-backed implementations).
    pub fn from_prompt(prompt: &[Token]) -> Result<Question> {
        // <bos> <q> (k v)*10 + start hops </q> <think>
        if prompt.len() != 27
            || prompt[0] != tok::BOS
            || prompt[1] != tok::Q
            || prompt[22] != tok::PLUS
            || prompt[25] != tok::EQ
            || prompt[26] != tok::THINK
        {
            bail!("malformed prompt: {:?}", prompt);
        }
        let d = |t: Token| -> Result<u8> {
            tok::digit_value(t)
                .ok_or_else(|| anyhow::anyhow!("expected digit, got {t}"))
        };
        let mut mapping = [0u8; NUM_KEYS];
        let mut seen = [false; NUM_KEYS];
        for pair in prompt[2..22].chunks(2) {
            let k = d(pair[0])? as usize;
            if seen[k] {
                bail!("duplicate key {k} in prompt");
            }
            seen[k] = true;
            mapping[k] = d(pair[1])?;
        }
        Ok(Question {
            mapping,
            start: d(prompt[23])?,
            hops: d(prompt[24])?,
        })
    }

    pub fn sample(spec: &TaskSpec, rng: &mut Rng) -> Question {
        let mut mapping = [0u8; NUM_KEYS];
        for m in mapping.iter_mut() {
            *m = rng.below(10) as u8;
        }
        Question {
            mapping,
            start: rng.below(10) as u8,
            hops: rng.int_range(spec.min_hops as i64, spec.max_hops as i64)
                as u8,
        }
    }
}

/// One scripted derivation pass (mirror of `data._derivation`).
fn derivation(q: &Question, spec: &TaskSpec, rng: &mut Rng) -> (Vec<Token>, u8) {
    let mut toks = Vec::new();
    let mut cur = q.start as i64;
    for _ in 0..q.hops {
        let mut next = q.mapping[cur as usize] as i64;
        if rng.chance(spec.p_err) {
            let delta = if rng.chance(0.5) { 1 } else { -1 };
            next = (next + delta).rem_euclid(10);
        }
        toks.extend([
            tok::STEP,
            tok::digit(cur as u8),
            tok::EQUALS,
            tok::digit(next as u8),
        ]);
        cur = next;
    }
    (toks, cur as u8)
}

/// Scripted *response* (the part generated after the prompt): mirrors
/// `data.sample_trajectory` but returns only the post-`<think>` suffix,
/// which is what the SimEngine feeds the coordinator token by token.
pub fn sample_response(
    q: &Question,
    spec: &TaskSpec,
    rng: &mut Rng,
    max_len: usize,
) -> Vec<Token> {
    let prompt_len = q.prompt_tokens().len();
    let (mut body, mut ans) = derivation(q, spec, rng);
    if rng.chance(spec.p_rethink) {
        loop {
            let (extra, ans2) = derivation(q, spec, rng);
            // +4: </think> <ans> digit <eos>.
            if prompt_len + body.len() + 1 + extra.len() + 4 > max_len {
                break;
            }
            body.push(tok::RECHECK);
            body.extend(extra);
            ans = ans2;
            if !rng.chance(spec.p_continue) {
                break;
            }
        }
    }
    body.extend([tok::ETHINK, tok::ANS, tok::digit(ans), tok::EOS]);
    body
}

/// Parse the chain state at the end of a step-boundary-aligned generated
/// prefix: (current value, steps completed in the latest derivation).
/// Returns None if the prefix is malformed or not at a boundary.
pub fn chain_state(q: &Question, generated: &[Token]) -> Option<(u8, u32)> {
    let start = generated
        .iter()
        .rposition(|&t| t == tok::RECHECK)
        .map(|i| i + 1)
        .unwrap_or(0);
    let seg = &generated[start..];
    if seg.len() % 4 != 0 {
        return None; // mid-step
    }
    let mut cur = q.start;
    let mut steps = 0u32;
    for chunk in seg.chunks(4) {
        if chunk[0] != tok::STEP || chunk[2] != tok::EQUALS {
            return None;
        }
        let c = tok::digit_value(chunk[1])?;
        let n = tok::digit_value(chunk[3])?;
        if c != cur {
            return None; // broken chain — not a valid fork point
        }
        cur = n;
        steps += 1;
    }
    Some((cur, steps))
}

/// Scripted *continuation* of a forked branch: finish the in-progress
/// derivation from the given chain state (fresh slips), then optional
/// re-think loops, then the answer tail. Mirrors the distribution
/// `sample_response` conditions on the forced prefix.
pub fn continue_response(
    q: &Question,
    spec: &TaskSpec,
    forced: &[Token],
    rng: &mut Rng,
    max_len: usize,
) -> Vec<Token> {
    let Some((mut cur, steps_done)) = chain_state(q, forced) else {
        // Defensive: if the fork point is unparsable, emit the tail.
        return vec![tok::ETHINK, tok::ANS, tok::digit(q.start), tok::EOS];
    };
    let consumed = q.prompt_tokens().len() + forced.len();
    let mut body = Vec::new();
    // Finish the current derivation.
    for _ in steps_done..q.hops as u32 {
        let mut next = q.mapping[cur as usize] as i64;
        if rng.chance(spec.p_err) {
            let delta = if rng.chance(0.5) { 1 } else { -1 };
            next = (next + delta).rem_euclid(10);
        }
        body.extend([tok::STEP, tok::digit(cur), tok::EQUALS,
                     tok::digit(next as u8)]);
        cur = next as u8;
    }
    let mut ans = cur;
    // Optional re-think loops, budget-aware.
    if rng.chance(spec.p_rethink) {
        loop {
            let (extra, ans2) = derivation(q, spec, rng);
            if consumed + body.len() + 1 + extra.len() + 4 > max_len {
                break;
            }
            body.push(tok::RECHECK);
            body.extend(extra);
            ans = ans2;
            if !rng.chance(spec.p_continue) {
                break;
            }
        }
    }
    body.extend([tok::ETHINK, tok::ANS, tok::digit(ans), tok::EOS]);
    body
}

/// A request with its arrival time (seconds since serve start).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub question: Question,
    pub arrival: f64,
    pub dataset: String,
    /// Shared few-shot header prepended to the serving prompt (empty for
    /// plain traces). Requests carrying the same header share its prompt
    /// pages through the cross-request prefix cache.
    pub header: Vec<Token>,
}

impl Request {
    /// Full serving prompt: the (possibly empty) shared header followed
    /// by the question's `<bos> … <think>` prompt.
    pub fn prompt_tokens(&self) -> Vec<Token> {
        if self.header.is_empty() {
            return self.question.prompt_tokens();
        }
        let mut out = self.header.clone();
        out.extend(self.question.prompt_tokens());
        out
    }
}

/// Generate a Poisson-arrival trace over a dataset.
pub fn poisson_trace(
    spec: &TaskSpec,
    n_requests: usize,
    rate: f64,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n_requests)
        .map(|id| {
            t += rng.exponential(rate);
            Request {
                id,
                question: Question::sample(spec, &mut rng),
                arrival: t,
                dataset: spec.name.clone(),
                header: Vec::new(),
            }
        })
        .collect()
}

/// All requests arrive at t=0 (offline / batch evaluation mode).
pub fn batch_trace(spec: &TaskSpec, n_requests: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n_requests)
        .map(|id| Request {
            id,
            question: Question::sample(spec, &mut rng),
            arrival: 0.0,
            dataset: spec.name.clone(),
            header: Vec::new(),
        })
        .collect()
}

/// A deterministic few-shot header: `shots` worked examples (question
/// tokens, the clean derivation chain, the answer). Contains no `<think>`
/// marker, so prompt parsers can always locate the real question as the
/// trailing window. Same seed → byte-identical header, which is what
/// makes it a *shared* prefix across requests.
pub fn few_shot_header(spec: &TaskSpec, seed: u64, shots: usize) -> Vec<Token> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..shots {
        let q = Question::sample(spec, &mut rng);
        out.extend(q.tokens());
        let mut cur = q.start;
        for _ in 0..q.hops {
            let next = q.mapping[cur as usize];
            out.extend([tok::STEP, tok::digit(cur), tok::EQUALS,
                        tok::digit(next)]);
            cur = next;
        }
        out.extend([tok::ANS, tok::digit(cur)]);
    }
    out
}

/// Templated prefix-heavy trace: each request carries, with probability
/// `prefix_share`, one of `n_templates` shared few-shot headers (`shots`
/// worked examples each) ahead of its own question — the workload shape
/// that makes a cross-request prefix cache pay. Header assignment draws
/// from a forked RNG stream, so with `prefix_share = 0` the generated
/// questions and arrival times are *identical* to [`poisson_trace`]
/// (`rate > 0`) / [`batch_trace`] (`rate == 0`) at the same seed.
pub fn templated_trace(
    spec: &TaskSpec,
    n_requests: usize,
    rate: f64,
    seed: u64,
    prefix_share: f64,
    n_templates: usize,
    shots: usize,
) -> Vec<Request> {
    assert!(n_templates > 0, "need at least one template");
    let headers: Vec<Vec<Token>> = (0..n_templates)
        .map(|i| {
            few_shot_header(
                spec,
                seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                shots,
            )
        })
        .collect();
    let mut rng = Rng::new(seed);
    let mut hrng = Rng::new(seed ^ 0x5EED_4EAD_E12F_1D3A);
    let mut t = 0.0;
    (0..n_requests)
        .map(|id| {
            if rate > 0.0 {
                t += rng.exponential(rate);
            }
            let question = Question::sample(spec, &mut rng);
            let header = if hrng.chance(prefix_share) {
                headers[hrng.below(n_templates)].clone()
            } else {
                Vec::new()
            };
            Request { id, question, arrival: t, dataset: spec.name.clone(),
                      header }
        })
        .collect()
}

/// Mixed easy/hard trace: each request draws its task spec at arrival —
/// `hard` with probability `hard_share`, `easy` otherwise — from a
/// *forked* decision stream, so at `hard_share = 0` the questions and
/// arrival times are identical to [`poisson_trace`] (`rate > 0`) /
/// [`batch_trace`] (`rate == 0`) over `easy` at the same seed
/// ([`Question::sample`] draws the same number of RNG values whichever
/// spec it samples from). `Request::dataset` records the chosen spec's
/// name — the key the adaptive policy's per-dataset statistics learn
/// under, and what makes same-seed traces carry identical adaptive
/// decisions.
pub fn mixed_trace(
    easy: &TaskSpec,
    hard: &TaskSpec,
    n_requests: usize,
    rate: f64,
    seed: u64,
    hard_share: f64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut drng = Rng::new(seed ^ 0x4D15_ED00_CAFE_F00D);
    let mut t = 0.0;
    (0..n_requests)
        .map(|id| {
            if rate > 0.0 {
                t += rng.exponential(rate);
            }
            let spec = if drng.chance(hard_share) { hard } else { easy };
            Request {
                id,
                question: Question::sample(spec, &mut rng),
                arrival: t,
                dataset: spec.name.clone(),
                header: Vec::new(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn spec() -> TaskSpec {
        TaskSpec::synth_gaokao()
    }

    #[test]
    fn question_answer_follows_chain() {
        let mut mapping = [0u8; NUM_KEYS];
        for (k, m) in mapping.iter_mut().enumerate() {
            *m = ((k + 1) % 10) as u8; // successor map
        }
        let q = Question { mapping, start: 3, hops: 4 };
        assert_eq!(q.answer(), 7);
    }

    #[test]
    fn prompt_shape() {
        let mut rng = Rng::new(0);
        let q = Question::sample(&spec(), &mut rng);
        let p = q.prompt_tokens();
        assert_eq!(p[0], tok::BOS);
        assert_eq!(p[1], tok::Q);
        assert_eq!(*p.last().unwrap(), tok::THINK);
        assert_eq!(p[p.len() - 2], tok::EQ);
        // <bos> <q> (k v)*10 + start hops </q> <think> = 27 tokens.
        assert_eq!(p.len(), 27);
    }

    #[test]
    fn key_order_is_deterministic() {
        let mut rng = Rng::new(4);
        let q = Question::sample(&spec(), &mut rng);
        assert_eq!(q.tokens(), q.tokens());
        // All 10 keys present exactly once.
        let toks = q.tokens();
        let mut seen = [0u8; 10];
        for pair in toks[1..21].chunks(2) {
            seen[tok::digit_value(pair[0]).unwrap() as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn scripted_response_well_formed() {
        let mut rng = Rng::new(1);
        for i in 0..200 {
            let mut r = rng.fork(i);
            let q = Question::sample(&spec(), &mut r);
            let resp = sample_response(&q, &spec(), &mut r, 256);
            assert_eq!(*resp.last().unwrap(), tok::EOS);
            assert!(resp.len() + q.prompt_tokens().len() <= 256);
            assert!(tok::extract_answer(&resp).is_some());
        }
    }

    #[test]
    fn error_free_spec_always_correct() {
        let mut rng = Rng::new(2);
        let mut s = spec();
        s.p_err = 0.0;
        for i in 0..100 {
            let mut r = rng.fork(i);
            let q = Question::sample(&s, &mut r);
            let resp = sample_response(&q, &s, &mut r, 256);
            assert_eq!(tok::extract_answer(&resp), Some(q.answer()));
        }
    }

    #[test]
    fn rethink_lengthens_responses() {
        let mut rng = Rng::new(3);
        let mut never = spec();
        never.p_rethink = 0.0;
        let mut always = spec();
        always.p_rethink = 1.0;
        always.p_continue = 0.7;
        let mean_len = |s: &TaskSpec, rng: &mut Rng| -> f64 {
            let mut total = 0usize;
            for i in 0..300 {
                let mut r = rng.fork(i);
                let q = Question::sample(s, &mut r);
                total += sample_response(&q, s, &mut r, 256).len();
            }
            total as f64 / 300.0
        };
        let short = mean_len(&never, &mut rng);
        let long = mean_len(&always, &mut rng);
        assert!(long > short * 1.5, "short={short} long={long}");
    }

    #[test]
    fn poisson_trace_monotone_arrivals() {
        let trace = poisson_trace(&spec(), 50, 4.0, 7);
        assert_eq!(trace.len(), 50);
        for w in trace.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        // Mean inter-arrival ~ 1/4 s.
        let mean = trace.last().unwrap().arrival / 50.0;
        assert!(mean > 0.1 && mean < 0.5, "mean={mean}");
    }

    #[test]
    fn trace_deterministic() {
        let a = poisson_trace(&spec(), 10, 1.0, 42);
        let b = poisson_trace(&spec(), 10, 1.0, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.question, y.question);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn serving_prompt_parse_ignores_header() {
        let mut rng = Rng::new(6);
        let q = Question::sample(&spec(), &mut rng);
        // Bare prompt parses identically through both entry points.
        let bare = q.prompt_tokens();
        assert_eq!(Question::from_serving_prompt(&bare).unwrap(), q);
        // Headered prompt parses to the same question.
        let mut with_header = few_shot_header(&spec(), 3, 2);
        with_header.extend(q.prompt_tokens());
        assert_eq!(Question::from_serving_prompt(&with_header).unwrap(), q);
        // A header never contains the <think> marker (prompt locators
        // rely on it).
        assert!(!few_shot_header(&spec(), 3, 4).contains(&tok::THINK));
        // Too-short prompts are rejected.
        assert!(Question::from_serving_prompt(&bare[..10]).is_err());
    }

    #[test]
    fn few_shot_header_deterministic_and_distinct() {
        let a = few_shot_header(&spec(), 1, 3);
        let b = few_shot_header(&spec(), 1, 3);
        let c = few_shot_header(&spec(), 2, 3);
        assert_eq!(a, b, "same seed must give the same header");
        assert_ne!(a, c, "different seeds must give distinct headers");
        assert!(a.len() >= 3 * 30, "3 shots should span 90+ tokens");
    }

    #[test]
    fn templated_trace_share_zero_matches_plain_traces() {
        let plain = poisson_trace(&spec(), 20, 2.0, 11);
        let templ = templated_trace(&spec(), 20, 2.0, 11, 0.0, 3, 3);
        for (p, t) in plain.iter().zip(&templ) {
            assert_eq!(p.question, t.question);
            assert_eq!(p.arrival, t.arrival);
            assert!(t.header.is_empty());
            assert_eq!(p.prompt_tokens(), t.prompt_tokens());
        }
        let batch = batch_trace(&spec(), 10, 12);
        let templ0 = templated_trace(&spec(), 10, 0.0, 12, 0.0, 2, 2);
        for (p, t) in batch.iter().zip(&templ0) {
            assert_eq!(p.question, t.question);
            assert_eq!(t.arrival, 0.0);
        }
    }

    #[test]
    fn templated_trace_shares_headers_across_requests() {
        let trace = templated_trace(&spec(), 64, 2.0, 7, 0.8, 2, 3);
        let with_header: Vec<&Request> =
            trace.iter().filter(|r| !r.header.is_empty()).collect();
        // ~80% should carry a header, drawn from exactly 2 templates.
        assert!(with_header.len() > 32, "only {} headered", with_header.len());
        let mut distinct: Vec<&[Token]> = Vec::new();
        for r in &with_header {
            if !distinct.iter().any(|h| *h == r.header.as_slice()) {
                distinct.push(&r.header);
            }
        }
        assert_eq!(distinct.len(), 2, "expected 2 distinct templates");
        // Headered prompts end with the question window and still parse.
        for r in &with_header {
            let p = r.prompt_tokens();
            assert_eq!(p.len(), r.header.len() + 27);
            assert_eq!(
                Question::from_serving_prompt(&p).unwrap(),
                r.question
            );
        }
        // Arrivals stay sorted.
        for w in trace.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn mixed_trace_share_zero_matches_plain_traces() {
        let easy = spec();
        let hard = TaskSpec::synth_gpqa();
        let plain = poisson_trace(&easy, 20, 2.0, 11);
        let mixed = mixed_trace(&easy, &hard, 20, 2.0, 11, 0.0);
        for (p, m) in plain.iter().zip(&mixed) {
            assert_eq!(p.question, m.question);
            assert_eq!(p.arrival, m.arrival);
            assert_eq!(m.dataset, easy.name);
        }
        let batch = batch_trace(&easy, 10, 12);
        let mixed0 = mixed_trace(&easy, &hard, 10, 0.0, 12, 0.0);
        for (p, m) in batch.iter().zip(&mixed0) {
            assert_eq!(p.question, m.question);
            assert_eq!(m.arrival, 0.0);
        }
    }

    #[test]
    fn mixed_trace_is_deterministic_and_mixes_both_specs() {
        let easy = spec();
        let hard = TaskSpec::synth_gpqa();
        let a = mixed_trace(&easy, &hard, 64, 2.0, 7, 0.5);
        let b = mixed_trace(&easy, &hard, 64, 2.0, 7, 0.5);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.question, y.question);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.dataset, y.dataset);
        }
        let n_hard = a.iter().filter(|r| r.dataset == hard.name).count();
        assert!(
            n_hard > 16 && n_hard < 48,
            "share 0.5 drew {n_hard}/64 hard requests"
        );
        // Difficulty rides on the question itself: each request's hop
        // count must come from its own spec's range.
        for r in &a {
            let (lo, hi) = if r.dataset == hard.name {
                (hard.min_hops, hard.max_hops)
            } else {
                (easy.min_hops, easy.max_hops)
            };
            let h = r.question.hops as u32;
            assert!(h >= lo && h <= hi, "{}: hops {h} outside [{lo},{hi}]",
                    r.dataset);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn taskspec_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"synth-gaokao","min_hops":3,"max_hops":5,
                "p_err":0.08,"p_rethink":0.35,"p_continue":0.55}"#,
        )
        .unwrap();
        assert_eq!(TaskSpec::from_json(&j).unwrap(), TaskSpec::synth_gaokao());
    }
}
