//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `artifacts/manifest.json` describes, per model: the architecture
//! config, the parameter layout inside `params.bin`, the packed serving
//! state layout, and which HLO files implement which entry point at which
//! batch size. Everything is validated here so a stale or inconsistent
//! artifacts directory fails at load, not mid-serve.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Architecture of one LM (mirror of `model.ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub prompt_len: usize,
}

impl ModelConfig {
    fn from_json(j: &Json) -> Result<ModelConfig> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?
                .as_usize()
                .with_context(|| format!("config `{k}` not an int"))
        };
        Ok(ModelConfig {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_head: u("d_head")?,
            d_ff: u("d_ff")?,
            vocab_size: u("vocab_size")?,
            max_seq: u("max_seq")?,
            prompt_len: u("prompt_len")?,
        })
    }

    /// Elements in the packed KV cache for `batch` slots.
    pub fn kv_elements(&self, batch: usize) -> usize {
        self.n_layers * 2 * batch * self.n_heads * self.max_seq * self.d_head
    }
}

/// One tensor inside `params.bin`.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub num_elements: usize,
}

/// Offsets (elements) of the packed serving-state segments, for one
/// (model, batch) pair. Mirror of `model.state_offsets`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StateLayout {
    pub batch: usize,
    pub chunk_t: usize,
    pub tokens_out: (usize, usize),
    pub logits: (usize, usize),
    pub lengths: (usize, usize),
    pub alive: (usize, usize),
    pub kv: (usize, usize),
    pub total: usize,
}

impl StateLayout {
    pub fn new(cfg: &ModelConfig, batch: usize, chunk_t: usize) -> StateLayout {
        let mut off = 0;
        let mut seg = |n: usize| {
            let s = (off, n);
            off += n;
            s
        };
        let tokens_out = seg(batch * chunk_t);
        let logits = seg(batch * cfg.vocab_size);
        let lengths = seg(batch);
        let alive = seg(batch);
        let kv = seg(cfg.kv_elements(batch));
        StateLayout {
            batch,
            chunk_t,
            tokens_out,
            logits,
            lengths,
            alive,
            kv,
            total: off,
        }
    }
}

/// Executable inventory for one model: entry-point -> batch -> HLO path.
#[derive(Debug, Clone, Default)]
pub struct ExecutableSet {
    pub by_batch: BTreeMap<usize, PathBuf>,
}

impl ExecutableSet {
    fn from_json(root: &Path, j: &Json) -> Result<ExecutableSet> {
        let mut by_batch = BTreeMap::new();
        for (k, v) in j.as_obj().context("executable set not an object")? {
            let b: usize = k.parse().context("batch key not an int")?;
            let rel = v.as_str().context("executable path not a string")?;
            by_batch.insert(b, root.join(rel));
        }
        Ok(ExecutableSet { by_batch })
    }

    /// Smallest compiled batch bucket that fits `n` (or the largest one).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.by_batch
            .keys()
            .copied()
            .find(|&b| b >= n)
            .or_else(|| self.by_batch.keys().copied().last())
    }

    pub fn batches(&self) -> Vec<usize> {
        self.by_batch.keys().copied().collect()
    }
}

/// Everything about one servable model.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    pub params_bin: PathBuf,
    pub params: Vec<ParamEntry>,
    pub chunk_t: usize,
    pub decode: ExecutableSet,
    pub prefill: ExecutableSet,
    pub decode_chunk: ExecutableSet,
    pub peek: ExecutableSet,
}

/// PRM artifacts (trunk config is opaque to rust; only shapes matter).
#[derive(Debug, Clone)]
pub struct PrmArtifacts {
    pub name: String,
    pub max_seq: usize,
    pub params_bin: PathBuf,
    pub params: Vec<ParamEntry>,
    /// Fixed scoring batch size.
    pub batch: usize,
    /// Keyed by SEQUENCE bucket (not batch): pick the smallest bucket
    /// that fits the longest prefix in a chunk.
    pub score: ExecutableSet,
}

/// The parsed artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
    pub prm: PrmArtifacts,
    pub datasets: BTreeMap<String, crate::workload::TaskSpec>,
}

fn parse_params(j: &Json) -> Result<Vec<ParamEntry>> {
    let mut out = Vec::new();
    let mut expected_offset = 0usize;
    for p in j.as_arr().context("params not an array")? {
        let e = ParamEntry {
            name: p.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: p
                .req("shape")?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            offset_bytes: p.req("offset_bytes")?.as_usize().unwrap_or(0),
            num_elements: p.req("num_elements")?.as_usize().unwrap_or(0),
        };
        if e.offset_bytes != expected_offset {
            bail!("param `{}` offset {} != expected {} (params.bin layout \
                   must be contiguous)", e.name, e.offset_bytes, expected_offset);
        }
        let shape_elems: usize = e.shape.iter().product();
        if shape_elems != e.num_elements {
            bail!("param `{}` shape/size mismatch", e.name);
        }
        expected_offset += e.num_elements * 4;
        out.push(e);
    }
    Ok(out)
}

impl Manifest {
    /// Load and validate `<root>/manifest.json` (+ tokenizer drift check).
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| {
                format!(
                    "cannot read {}/manifest.json — run `make artifacts` first",
                    root.display()
                )
            })?;
        let j = Json::parse(&text).context("manifest.json parse error")?;

        let tok_text = std::fs::read_to_string(root.join("tokenizer.json"))
            .context("cannot read tokenizer.json")?;
        let tok = Json::parse(&tok_text).context("tokenizer.json parse error")?;
        crate::tokenizer::verify_spec(&tok)?;

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().context("models")? {
            let config = ModelConfig::from_json(m.req("config")?)?;
            let execs = m.req("executables")?;
            let art = ModelArtifacts {
                config,
                params_bin: root
                    .join(m.req("params_bin")?.as_str().unwrap_or_default()),
                params: parse_params(m.req("params")?)?,
                chunk_t: m.req("chunk_t")?.as_usize().unwrap_or(0),
                decode: ExecutableSet::from_json(&root, execs.req("decode")?)?,
                prefill: ExecutableSet::from_json(&root, execs.req("prefill")?)?,
                decode_chunk: ExecutableSet::from_json(
                    &root,
                    execs.req("decode_chunk")?,
                )?,
                peek: ExecutableSet::from_json(&root, execs.req("peek")?)?,
            };
            if art.chunk_t == 0 {
                bail!("model `{name}`: chunk_t missing/zero");
            }
            models.insert(name.clone(), art);
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }

        let pj = j.req("prm")?;
        let prm = PrmArtifacts {
            name: pj
                .req("config")?
                .req("name")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            max_seq: pj.req("config")?.req("max_seq")?.as_usize().unwrap_or(0),
            params_bin: root
                .join(pj.req("params_bin")?.as_str().unwrap_or_default()),
            params: parse_params(pj.req("params")?)?,
            batch: pj.get("batch").and_then(|b| b.as_usize()).unwrap_or(8),
            score: ExecutableSet::from_json(
                &root,
                pj.req("executables")?.req("score")?,
            )?,
        };

        let mut datasets = BTreeMap::new();
        if let Some(ds) = j.get("datasets").and_then(|d| d.as_obj()) {
            for (k, v) in ds {
                datasets
                    .insert(k.clone(), crate::workload::TaskSpec::from_json(v)?);
            }
        }

        Ok(Manifest { root, models, prm, datasets })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models.get(name).with_context(|| {
            format!(
                "model `{name}` not in artifacts (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            d_ff: 256,
            vocab_size: 32,
            max_seq: 256,
            prompt_len: 32,
        }
    }

    #[test]
    fn state_layout_contiguous() {
        let l = StateLayout::new(&cfg(), 8, 16);
        assert_eq!(l.tokens_out, (0, 128));
        assert_eq!(l.logits.0, 128);
        assert_eq!(l.logits.1, 8 * 32);
        assert_eq!(l.lengths.1, 8);
        assert_eq!(l.alive.1, 8);
        assert_eq!(l.kv.1, 2 * 2 * 8 * 2 * 256 * 32);
        assert_eq!(l.total, l.kv.0 + l.kv.1);
    }

    #[test]
    fn kv_elements_formula() {
        assert_eq!(cfg().kv_elements(1), 2 * 2 * 1 * 2 * 256 * 32);
    }

    #[test]
    fn bucket_selection() {
        let mut s = ExecutableSet::default();
        for b in [1usize, 4, 16] {
            s.by_batch.insert(b, PathBuf::from(format!("x{b}")));
        }
        assert_eq!(s.bucket_for(1), Some(1));
        assert_eq!(s.bucket_for(3), Some(4));
        assert_eq!(s.bucket_for(5), Some(16));
        assert_eq!(s.bucket_for(99), Some(16)); // clamped to largest
    }

    #[test]
    fn params_layout_validation() {
        let good = Json::parse(
            r#"[{"name":"a","shape":[2,3],"offset_bytes":0,"num_elements":6},
                {"name":"b","shape":[4],"offset_bytes":24,"num_elements":4}]"#,
        )
        .unwrap();
        assert_eq!(parse_params(&good).unwrap().len(), 2);
        let gap = Json::parse(
            r#"[{"name":"a","shape":[2,3],"offset_bytes":8,"num_elements":6}]"#,
        )
        .unwrap();
        assert!(parse_params(&gap).is_err());
        let mismatch = Json::parse(
            r#"[{"name":"a","shape":[2,3],"offset_bytes":0,"num_elements":5}]"#,
        )
        .unwrap();
        assert!(parse_params(&mismatch).is_err());
    }
}
