//! Offline stand-in for the `xla` PJRT binding.
//!
//! The real serving path executes AOT-compiled HLO through a PJRT client
//! (see `client.rs` for the calling convention). That binding is not
//! available in the offline build registry, so this module provides the
//! same API surface with constructors that fail at runtime: everything
//! compiles, `Runtime::cpu()` returns a descriptive error, and every
//! HLO-dependent test/bench skips gracefully (they all gate on
//! `Manifest::load` / `Runtime::cpu` succeeding first). Swapping the real
//! binding back in is a one-line change in `client.rs`/`hlo.rs` (`use`
//! the external crate instead of this module).

use std::fmt;

/// Error produced by every stub entry point.
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError(format!(
            "{what}: the xla/PJRT binding is not available in this build \
             (offline registry; see EXPERIMENTS.md §Runtime)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// PJRT CPU client handle (refcounted in the real binding).
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

/// A compiled executable loaded on the client.
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

/// Host copy of a device buffer.
pub struct Literal {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(XlaError::unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable("compile"))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable("execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable("to_literal_sync"))
    }
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }
}
