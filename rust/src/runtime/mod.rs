//! PJRT runtime: artifact loading and AOT-executable execution.
//!
//! This is the bridge between the rust coordinator (L3) and the
//! AOT-compiled JAX/Pallas graphs (L2/L1): [`manifest`] parses the
//! artifacts contract, [`client`] compiles the HLO text on the PJRT CPU
//! client and executes it on device-resident buffers. Python is never
//! invoked from here — the artifacts directory is the entire interface.

pub mod client;
pub mod manifest;
pub mod xla;

pub use client::{read_f32, Executable, ModelExecutables, Runtime};
pub use manifest::{
    ExecutableSet, Manifest, ModelArtifacts, ModelConfig, ParamEntry,
    PrmArtifacts, StateLayout,
};

/// Default artifacts location (relative to the repo root); overridable via
/// `SART_ARTIFACTS` for tests and installed deployments.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("SART_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| "artifacts".into())
}
