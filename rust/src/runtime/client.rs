//! PJRT client wrapper: compile HLO-text artifacts, manage device buffers.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute_b`. All serving
//! executables are single-output (the packed state array — see
//! `python/compile/model.py` "Packed serving state"), so the
//! tuple-buffer limitation of the binding never bites.

use super::manifest::{ModelArtifacts, ParamEntry, PrmArtifacts};
// Offline stand-in with the same API as the external `xla` binding; see
// the module docs for how to swap the real crate back in.
use super::xla;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Shared PJRT CPU client (cheap to clone — refcounted C++ handle).
#[derive(Clone)]
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled single-output executable plus its uploaded weights.
///
/// Calling convention (matches `aot.py` lowering order): the flattened
/// sorted-name parameters first, then the entry-specific operands.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Uploaded once; shared across all executables of the same model.
    params: Rc<Vec<xla::PjRtBuffer>>,
    pub compile_seconds: f64,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a model's `params.bin` as device buffers (once per model).
    pub fn load_params(
        &self,
        bin_path: &Path,
        entries: &[ParamEntry],
    ) -> Result<Rc<Vec<xla::PjRtBuffer>>> {
        let bytes = std::fs::read(bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        let expected: usize =
            entries.iter().map(|e| e.num_elements * 4).sum();
        if bytes.len() != expected {
            bail!(
                "{}: size {} != manifest total {}",
                bin_path.display(),
                bytes.len(),
                expected
            );
        }
        let mut bufs = Vec::with_capacity(entries.len());
        for e in entries {
            let start = e.offset_bytes;
            let end = start + e.num_elements * 4;
            let mut host = vec![0f32; e.num_elements];
            byte_to_f32(&bytes[start..end], &mut host);
            // Scalars/1-d/N-d all upload with their manifest shape.
            let dims: Vec<usize> = if e.shape.is_empty() {
                vec![]
            } else {
                e.shape.clone()
            };
            let buf = self
                .client
                .buffer_from_host_buffer(&host, &dims, None)
                .map_err(|err| {
                    anyhow::anyhow!("uploading param `{}`: {err}", e.name)
                })?;
            bufs.push(buf);
        }
        Ok(Rc::new(bufs))
    }

    /// Compile one HLO-text artifact.
    pub fn compile(
        &self,
        hlo_path: &Path,
        params: Rc<Vec<xla::PjRtBuffer>>,
    ) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| {
            anyhow::anyhow!("parsing {}: {e}", hlo_path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", hlo_path.display()))?;
        Ok(Executable {
            exe,
            params,
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Upload an f32 host array.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32: {e}"))
    }

    /// Upload an i32 host array.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32: {e}"))
    }

    /// Upload a u32 host array (PRNG key data).
    pub fn upload_u32(&self, data: &[u32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload u32: {e}"))
    }
}

impl Executable {
    /// Execute with the model params followed by `operands`; returns the
    /// single output buffer.
    pub fn run(&self, operands: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.params.len() + operands.len());
        args.extend(self.params.iter());
        args.extend(operands.iter().copied());
        let mut outs = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        if outs.is_empty() {
            bail!("no replicas in execute output");
        }
        let mut replica0 = outs.remove(0);
        if replica0.len() != 1 {
            bail!(
                "expected single-output executable, got {} outputs \
                 (tuple roots are unsupported by the runtime — see model.py)",
                replica0.len()
            );
        }
        Ok(replica0.remove(0))
    }
}

/// Read back a whole (small) device buffer as f32 via its literal.
/// NOTE: the CPU PJRT client does not implement CopyRawToHost, so partial
/// readback of big buffers must go through a `peek` executable that
/// slices on device first.
pub fn read_f32(buf: &xla::PjRtBuffer, offset: usize, len: usize) -> Result<Vec<f32>> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("readback: {e}"))?;
    let all: Vec<f32> = lit
        .to_vec()
        .map_err(|e| anyhow::anyhow!("literal decode: {e}"))?;
    if offset + len > all.len() {
        anyhow::bail!("readback out of range: {}+{} > {}", offset, len,
                      all.len());
    }
    Ok(all[offset..offset + len].to_vec())
}

fn byte_to_f32(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len() * 4);
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
}

/// Convenience bundle: a model's compiled entry points at one batch size.
pub struct ModelExecutables {
    pub batch: usize,
    pub decode: Executable,
    pub prefill: Executable,
    pub decode_chunk: Executable,
    /// Param-free control-prefix readback (the CPU PJRT client lacks
    /// CopyRawToHost, so partial readback slices on device).
    pub peek: Executable,
}

impl Runtime {
    /// Compile a model's three entry points at (bucketed) batch size `b`.
    pub fn load_model(
        &self,
        art: &ModelArtifacts,
        batch: usize,
    ) -> Result<ModelExecutables> {
        let params = self.load_params(&art.params_bin, &art.params)?;
        let pick = |set: &super::manifest::ExecutableSet,
                    what: &str|
         -> Result<std::path::PathBuf> {
            let b = set.bucket_for(batch).with_context(|| {
                format!("no {what} executable for batch {batch}")
            })?;
            if b != batch {
                bail!(
                    "{what}: requested batch {batch} but only buckets {:?} \
                     exported — pass a compiled batch size",
                    set.batches()
                );
            }
            Ok(set.by_batch[&b].clone())
        };
        Ok(ModelExecutables {
            batch,
            decode: self.compile(&pick(&art.decode, "decode")?, params.clone())?,
            prefill: self
                .compile(&pick(&art.prefill, "prefill")?, params.clone())?,
            decode_chunk: self
                .compile(&pick(&art.decode_chunk, "decode_chunk")?,
                         params.clone())?,
            peek: self.compile(&pick(&art.peek, "peek")?,
                               Rc::new(Vec::new()))?,
        })
    }

    /// Compile the PRM scorer's sequence-bucket executables (fixed batch).
    pub fn load_prm(
        &self,
        art: &PrmArtifacts,
    ) -> Result<std::collections::BTreeMap<usize, Executable>> {
        let params = self.load_params(&art.params_bin, &art.params)?;
        let mut out = std::collections::BTreeMap::new();
        for (&seq, path) in &art.score.by_batch {
            out.insert(seq, self.compile(path, params.clone())?);
        }
        if out.is_empty() {
            bail!("no PRM executable buckets");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_conversion_roundtrip() {
        let vals = [0.0f32, 1.5, -2.25, f32::MAX];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let mut out = [0f32; 4];
        byte_to_f32(&bytes, &mut out);
        assert_eq!(out, vals);
    }
}
