//! Paged KV-cache manager with prefix sharing, refcounting and a
//! cross-request radix prefix cache.
//!
//! This is the memory-accounting substrate that turns branch
//! over-subscription into queuing delay — the second challenge the paper
//! studies. Physically the engine stores KV in fixed slots of a packed
//! device tensor; *logically* this manager accounts pages the way a
//! vLLM-style paged allocator would:
//!
//! * a request's prompt KV is a **shared prefix**: one set of pages,
//!   refcounted by its N branches (paper §4: "we share prefix KV cache
//!   across branches");
//! * each branch **reserves** its worst-case decode pages at admission
//!   (conservative Orca-style reservation — no mid-flight preemption, so
//!   a branch can always run to completion once admitted);
//! * pruning / early stopping / completion releases the branch pages
//!   immediately, and the prefix pages when the last sibling terminates —
//!   this is exactly the release path that lets SART batch more requests.
//!
//! # Cross-request radix prefix cache
//!
//! With a nonzero prefix-cache budget ([`KvCacheManager::with_prefix_cache`]),
//! prompt token sequences are additionally interned into a **page-granular
//! radix tree** (one node per full page of prompt tokens, SGLang-style):
//!
//! * a [`AdmissionMode::Monolithic`] admission walks the tree for the
//!   longest cached prefix and only charges pages for the *uncovered*
//!   suffix — two requests sharing a few-shot header pay for its pages
//!   once;
//! * every node carries a lease refcount (number of live prefixes whose
//!   interned path includes it). When the last lease drops, the node's
//!   page is **retained** instead of freed: it moves to an LRU-stamped
//!   pool bounded by the cache budget, ready to serve the next request
//!   with the same prefix;
//! * eviction only ever touches refcount-0 nodes, deepest/oldest first
//!   (junk tails age out before shared headers, whose stamps refresh on
//!   every release);
//! * [`KvCacheManager::check_invariants`] recomputes node refcounts and
//!   tree-page accounting from scratch each call, so audit-mode serves
//!   cross-check the incremental bookkeeping every round.
//!
//! A zero cache budget (the [`KvCacheManager::new`] default) disables the
//! tree entirely: admission falls back to content-blind scalar
//! accounting, byte-for-byte reproducing the pre-cache behaviour
//! (property-tested).
//!
//! # Prefix digests (cross-replica gossip)
//!
//! Every radix node additionally carries the rolling [`page_digest`] of
//! its root path — the digest of the full-page prompt prefix the node
//! represents. The manager maintains the multiset of resident digests
//! incrementally (added at intern time, retracted at eviction; no tree
//! walk at read time), and [`KvCacheManager::advertised_digests`] hands
//! the distinct digests to the cluster's gossip layer, which routes on
//! them instead of probing every replica's tree per arrival. A prompt's
//! own page-prefix digests come from [`prompt_page_digests`] with the
//! same chain, so content-equal prefixes always match. The digest set is
//! advisory: routing on a stale digest is only a placement
//! pessimization, never a correctness issue, because admission still
//! walks the real tree. `check_invariants` rebuilds the whole multiset
//! (and every per-node digest) from scratch.
//!
//! # Chunked prefill (incremental page leasing)
//!
//! A [`AdmissionMode::Chunked`] admission takes a request whose
//! uncovered prompt suffix will stream in over several scheduling rounds:
//! the suffix's pages are **pledged** (held against the budget so no later
//! admission can strand the prefill) and convert to used pages chunk by
//! chunk via [`KvCacheManager::note_prefill`]; the full pages intern into
//! the radix tree only at [`KvCacheManager::commit_prefix`], once their KV
//! actually exists. A request released mid-prefill frees its partial pages
//! and cancels the outstanding pledge without ever touching the tree.
//! [`AdmissionMode::Streamed`] relaxes the all-or-nothing pledge: only
//! the first prefill chunk's pages are pledged up front, and the pledge
//! grows chunk by chunk through [`KvCacheManager::ensure_pledged`].
//!
//! All admission goes through the one typed entry point
//! [`KvCacheManager::admit`], which answers [`AdmissionOutcome::Deferred`]
//! — side-effect free — when the budget falls short; the scheduler
//! combines this with engine-slot availability (and, under pressure, with
//! reward-driven preemption via
//! [`KvCacheManager::preemption_candidates`]).
//!
//! Storage is slab-style: prefixes and branches live in `Vec`s indexed by
//! their handle, with a free list for reuse and a per-slot generation
//! counter so stale handles (double release, use-after-release) are
//! rejected in O(1) instead of hashed lookups — the manager sits on the
//! admission/termination hot path of every scheduling round.
//!
//! [`admit`]: KvCacheManager::admit

use crate::tokenizer::Token;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Seed of the per-page rolling digest chain (FNV-1a offset basis). The
/// digest of a prompt's first full page is `page_digest(DIGEST_SEED,
/// page)`; deeper pages chain from their parent's digest.
pub const DIGEST_SEED: u64 = 0xCBF2_9CE4_8422_2325;

const DIGEST_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Rolling digest of one more page on a prefix chain: FNV-1a over the
/// page's token bytes, chained from the parent prefix's digest. The kv
/// manager stamps every radix node with the digest of its root path at
/// intern time, and the cluster's `DigestTable` hashes arriving prompts
/// with the same function — content-equal full-page prefixes collide by
/// construction, unequal ones only with ~2^-64 probability.
pub fn page_digest(parent: u64, page: &[Token]) -> u64 {
    let mut h = parent;
    for &t in page {
        for b in (t as u32).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(DIGEST_PRIME);
        }
    }
    h
}

/// Digests of every full-page prefix of `prompt`: entry `k` is the digest
/// of pages `0..=k`. Empty for prompts shorter than one page.
pub fn prompt_page_digests(prompt: &[Token], page_tokens: usize) -> Vec<u64> {
    assert!(page_tokens > 0);
    let mut out = Vec::with_capacity(prompt.len() / page_tokens);
    let mut h = DIGEST_SEED;
    for page in prompt.chunks_exact(page_tokens) {
        h = page_digest(h, page);
        out.push(h);
    }
    out
}

/// Handle for a request's shared prompt pages (generation-checked slab
/// index; stale handles are rejected by every operation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixId {
    idx: u32,
    gen: u32,
}

/// Handle for one branch's reserved decode pages (generation-checked).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BranchId {
    idx: u32,
    gen: u32,
}

/// Chunked-prefill staging state of a prefix (see
/// [`AdmissionMode::Chunked`]): the uncovered prompt
/// suffix's pages are *pledged* — held against the budget but not yet
/// materialized — at admission, convert to used pages as prefill chunks
/// land ([`KvCacheManager::note_prefill`]), and the full pages intern
/// into the radix tree only when the prefill completes
/// ([`KvCacheManager::commit_prefix`]).
#[derive(Debug)]
struct StagedPrefill {
    /// Prompt tokens covered by the radix path leased at admission.
    covered_tokens: usize,
    /// Total prompt length in tokens.
    prompt_tokens: usize,
    /// Uncovered tokens whose prefill has landed so far.
    staged_tokens: usize,
    /// Uncovered tokens whose pages are secured against the budget
    /// (pledged or already materialized). Equals the whole uncovered
    /// suffix for [`AdmissionMode::Chunked`]; starts at the first chunk
    /// and grows via [`KvCacheManager::ensure_pledged`] for
    /// [`AdmissionMode::Streamed`].
    pledged_tokens: usize,
    /// Uncovered pages not yet materialized (the remaining pledge).
    pledged_pages: usize,
}

#[derive(Debug)]
struct Prefix {
    /// Total prompt pages (shared path + private remainder; diagnostics).
    pages: usize,
    /// Pages owned privately by this prefix (the partial tail page, or
    /// the whole prompt on the scalar/cache-disabled path; during a
    /// chunked prefill, the materialized-so-far uncovered pages).
    private_pages: usize,
    refcount: usize,
    /// Deepest radix node of the interned full-page path (None on the
    /// scalar path or when the prompt is shorter than one page).
    leaf: Option<u32>,
    /// Chunked-prefill progress (None once committed / for monolithic
    /// admissions).
    staged: Option<StagedPrefill>,
}

#[derive(Debug)]
struct BranchAlloc {
    prefix: PrefixId,
    reserved_pages: usize,
    /// Tokens actually decoded so far (informational — the budget is
    /// charged at reservation time).
    grown_tokens: usize,
    /// Eviction priority fed by the scheduler (the branch's PRM reward;
    /// lower evicts first). `None` = not a preemption candidate. The
    /// reserved pages of prioritized branches sum to
    /// `KvCacheManager::preemptable_pages`.
    priority: Option<f32>,
}

/// One radix-tree node: exactly one page of prompt tokens (the edge label
/// from its parent). `refcount` counts live prefix leases through this
/// node; at 0 the page is retained (LRU-evictable) rather than freed.
#[derive(Debug)]
struct RadixNode {
    page: Vec<Token>,
    parent: Option<u32>,
    children: Vec<u32>,
    refcount: usize,
    /// LRU stamp assigned when `refcount` last dropped to 0 (valid only
    /// while retained).
    lru: u64,
    /// Rolling digest of this node's root path (see [`page_digest`]) —
    /// what the cluster's gossip layer advertises. Stamped at intern
    /// time from the parent's digest; never recomputed on the hot path.
    digest: u64,
}

/// One slab slot: the generation is bumped on removal so outstanding
/// handles to the old occupant can never alias a reused slot.
#[derive(Debug)]
struct Slot<T> {
    gen: u32,
    val: Option<T>,
}

/// Minimal slab: Vec storage + free list + live count.
#[derive(Debug)]
struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Slab<T> {
    fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free: Vec::new(), len: 0 }
    }

    fn insert(&mut self, val: T) -> (u32, u32) {
        self.len += 1;
        if let Some(idx) = self.free.pop() {
            let s = &mut self.slots[idx as usize];
            debug_assert!(s.val.is_none());
            s.val = Some(val);
            (idx, s.gen)
        } else {
            self.slots.push(Slot { gen: 0, val: Some(val) });
            ((self.slots.len() - 1) as u32, 0)
        }
    }

    fn get(&self, idx: u32, gen: u32) -> Option<&T> {
        self.slots
            .get(idx as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.val.as_ref())
    }

    fn get_mut(&mut self, idx: u32, gen: u32) -> Option<&mut T> {
        self.slots
            .get_mut(idx as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.val.as_mut())
    }

    fn remove(&mut self, idx: u32, gen: u32) -> Option<T> {
        let s = self.slots.get_mut(idx as usize)?;
        if s.gen != gen {
            return None;
        }
        let v = s.val.take()?;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(idx);
        self.len -= 1;
        Some(v)
    }

    fn iter(&self) -> impl Iterator<Item = &T> {
        self.slots.iter().filter_map(|s| s.val.as_ref())
    }
}

/// What an admitted [`AdmissionRequest`] hands back: the usual handles
/// plus how many prompt tokens the cross-request cache already covered
/// (a multiple of the page size; 0 on cold admits or with the cache
/// disabled). The engine's cost model charges only the uncovered suffix.
#[derive(Debug)]
pub struct Admission {
    pub prefix: PrefixId,
    pub branches: Vec<BranchId>,
    pub cached_tokens: usize,
}

/// How an [`AdmissionRequest`] secures pages for its prompt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionMode {
    /// The whole prompt materializes at admission: the radix-covered
    /// prefix is leased, the uncovered suffix (and private tail page) is
    /// allocated up front. With the cache disabled this is the scalar
    /// pre-cache accounting (the Rebase baseline's path).
    Monolithic,
    /// Chunked prefill: the uncovered suffix's pages are *pledged* in
    /// full at admission and convert to used pages as chunks land
    /// ([`KvCacheManager::note_prefill`]); the prompt interns only at
    /// [`KvCacheManager::commit_prefix`].
    Chunked,
    /// Stream-aware admission: admit as soon as the *first* prefill
    /// chunk (of `first_chunk_tokens`) fits, pledging only its pages.
    /// The pledge grows chunk by chunk via
    /// [`KvCacheManager::ensure_pledged`] as the stream progresses — so
    /// a tight budget admits requests the all-or-nothing pledge would
    /// defer, at the cost of streams that can stall mid-prompt (the
    /// scheduler's head-of-line rules handle that).
    Streamed { first_chunk_tokens: usize },
    /// Attach `branches` more reservations to an existing prefix (tree
    /// expansion: a Rebase fork, or re-reserving pages for a preempted
    /// branch that resumes). `prompt` is ignored.
    Grow { prefix: PrefixId },
}

/// The one typed admission entry point: what is being admitted and how
/// its pages are secured. Replaces the old eight-way
/// `admit`/`admit_tokens`/`try_*`/`can_*`/`grow` surface.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionRequest<'a> {
    pub prompt: &'a [Token],
    pub max_new: usize,
    pub branches: usize,
    pub mode: AdmissionMode,
}

impl<'a> AdmissionRequest<'a> {
    pub fn monolithic(
        prompt: &'a [Token],
        max_new: usize,
        branches: usize,
    ) -> Self {
        AdmissionRequest { prompt, max_new, branches, mode: AdmissionMode::Monolithic }
    }

    pub fn chunked(
        prompt: &'a [Token],
        max_new: usize,
        branches: usize,
    ) -> Self {
        AdmissionRequest { prompt, max_new, branches, mode: AdmissionMode::Chunked }
    }

    pub fn streamed(
        prompt: &'a [Token],
        max_new: usize,
        branches: usize,
        first_chunk_tokens: usize,
    ) -> Self {
        AdmissionRequest {
            prompt,
            max_new,
            branches,
            mode: AdmissionMode::Streamed { first_chunk_tokens },
        }
    }

    pub fn grow(prefix: PrefixId, max_new: usize, branches: usize) -> Self {
        AdmissionRequest {
            prompt: &[],
            max_new,
            branches,
            mode: AdmissionMode::Grow { prefix },
        }
    }
}

/// What [`KvCacheManager::admit`] decides. `Deferred` is side-effect
/// free: the caller may retry later (or free pages by preempting
/// low-priority branches and retry immediately).
#[derive(Debug)]
pub enum AdmissionOutcome {
    Admitted(Admission),
    /// Over budget: the admission would have to secure `need_pages`
    /// (including retained pages it would re-lease) but only
    /// `free_pages` are unheld.
    Deferred { need_pages: usize, free_pages: usize },
}

impl AdmissionOutcome {
    /// The admission, or `None` if deferred.
    pub fn admitted(self) -> Option<Admission> {
        match self {
            AdmissionOutcome::Admitted(a) => Some(a),
            AdmissionOutcome::Deferred { .. } => None,
        }
    }

    /// The admission, or an error carrying the budget shortfall —
    /// for callers that sized the budget to always fit.
    pub fn into_admission(self) -> Result<Admission> {
        match self {
            AdmissionOutcome::Admitted(a) => Ok(a),
            AdmissionOutcome::Deferred { need_pages, free_pages } => bail!(
                "kv budget exceeded: need {need_pages} pages, \
                 {free_pages} free"
            ),
        }
    }

    pub fn is_deferred(&self) -> bool {
        matches!(self, AdmissionOutcome::Deferred { .. })
    }
}

/// Version-keyed change set between two advertisements of one replica's
/// digest set: everything that entered (`adds`) and left (`retracts`)
/// since `base_version`. Applying it to a table row at `base_version`
/// yields the row at `version`; applying it to any other base is invalid
/// and the receiver must fall back to a full snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DigestDelta {
    pub base_version: u64,
    pub version: u64,
    pub adds: Vec<u64>,
    pub retracts: Vec<u64>,
}

/// One gossip advertisement taken from a replica's cache: either a full
/// digest-set snapshot (first take after construction or a cold rejoin)
/// or a [`DigestDelta`] against the previously advertised version.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Advertisement {
    Full { version: u64, digests: Vec<u64> },
    Delta(DigestDelta),
}

/// Paged KV accounting with a hard page budget.
#[derive(Debug)]
pub struct KvCacheManager {
    page_tokens: usize,
    capacity_pages: usize,
    /// Pages held by live allocations: refcount>0 tree nodes (one page
    /// each, shared across all leases), private prefix remainders and
    /// branch reservations.
    used_pages: usize,
    /// Pages promised to chunked prefills in flight but not yet
    /// materialized (Σ per-prefix `StagedPrefill::pledged_pages`). They
    /// count against the budget — an admission must never be able to
    /// strand a mid-prefill request — but are not physically resident.
    pledged_pages: usize,
    prefixes: Slab<Prefix>,
    branches: Slab<BranchAlloc>,
    /// Incrementally maintained Σ grown_tokens over live branches
    /// (Fig. 3's "running tokens"; previously recomputed by a full scan).
    live_decoded: usize,
    /// High-water mark of `used_pages`, for metrics.
    peak_pages: usize,
    /// Retention budget for refcount-0 radix pages; 0 disables the
    /// cross-request cache entirely (scalar accounting, pre-cache
    /// semantics).
    prefix_cache_pages: usize,
    /// Radix node storage (free-listed; `None` slots are reusable).
    nodes: Vec<Option<RadixNode>>,
    free_nodes: Vec<u32>,
    /// First-page nodes (the radix tree's root edge set).
    roots: Vec<u32>,
    /// Resident refcount-0 pages (≤ `prefix_cache_pages`; all evictable).
    cached_pages: usize,
    /// Multiset of resident node digests (live or retained): digest →
    /// node count. Incremented at intern time, decremented at eviction;
    /// `advertised_digests` reads the keys with no tree walk. Rebuilt
    /// from scratch by `check_invariants`.
    digest_counts: HashMap<u64, u32>,
    /// Monotone version of the advertised digest *set* (the key set of
    /// `digest_counts`); bumped once per digest entering or leaving.
    digest_version: u64,
    /// Net set transitions since the last advertisement take: `+1` the
    /// digest became resident, `-1` it left. Presence is boolean, so a
    /// round trip cancels to net 0 and the entry is dropped — values
    /// outside ±1 cannot occur. Cleared by [`Self::take_advertisement`]
    /// and [`Self::full_advertisement`].
    digest_journal: HashMap<u64, i8>,
    /// Digest-set version the last advertisement reflected (`None` until
    /// the first take — forcing that take to be a Full snapshot).
    advertised_version: Option<u64>,
    lru_clock: u64,
    /// Σ cached_tokens over all admissions (metrics).
    hit_tokens_total: usize,
    /// Pages evicted from the retained pool (metrics).
    evicted_pages_total: usize,
    /// Incrementally maintained Σ `reserved_pages` over branches with an
    /// eviction priority set — the pages reward-driven preemption could
    /// reclaim right now. Rebuilt from scratch by `check_invariants`.
    preemptable_pages: usize,
}

fn pages_for(tokens: usize, page_tokens: usize) -> usize {
    tokens.div_ceil(page_tokens)
}

impl KvCacheManager {
    /// Manager with the cross-request prefix cache disabled (pre-cache
    /// accounting, byte-for-byte).
    pub fn new(capacity_tokens: usize, page_tokens: usize) -> KvCacheManager {
        Self::with_prefix_cache(capacity_tokens, page_tokens, 0)
    }

    /// Manager with up to `prefix_cache_pages` refcount-0 prompt pages
    /// retained for cross-request reuse (0 disables the cache).
    pub fn with_prefix_cache(
        capacity_tokens: usize,
        page_tokens: usize,
        prefix_cache_pages: usize,
    ) -> KvCacheManager {
        assert!(page_tokens > 0 && capacity_tokens >= page_tokens);
        KvCacheManager {
            page_tokens,
            capacity_pages: capacity_tokens / page_tokens,
            used_pages: 0,
            pledged_pages: 0,
            prefixes: Slab::new(),
            branches: Slab::new(),
            live_decoded: 0,
            peak_pages: 0,
            prefix_cache_pages,
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: Vec::new(),
            cached_pages: 0,
            digest_counts: HashMap::new(),
            digest_version: 0,
            digest_journal: HashMap::new(),
            advertised_version: None,
            lru_clock: 0,
            hit_tokens_total: 0,
            evicted_pages_total: 0,
            preemptable_pages: 0,
        }
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn used_tokens_upper_bound(&self) -> usize {
        self.used_pages * self.page_tokens
    }

    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Pages available to live allocations. Retained (refcount-0) cache
    /// pages do not subtract: they are evicted on demand by admissions.
    /// Pages pledged to chunked prefills in flight *do*: they will
    /// materialize without a further budget check.
    pub fn free_pages(&self) -> usize {
        self.capacity_pages - self.used_pages - self.pledged_pages
    }

    /// Pages pledged to chunked prefills in flight (0 outside chunked
    /// serving).
    pub fn pledged_pages(&self) -> usize {
        self.pledged_pages
    }

    /// Retained refcount-0 prefix pages currently resident.
    /// Fraction of the page budget currently held (used + pledged) —
    /// the pressure signal `LoadSnapshot` carries to the cluster's
    /// scale/routing layer. 0.0 idle, 1.0 fully committed.
    pub fn pressure(&self) -> f64 {
        (self.used_pages + self.pledged_pages) as f64
            / self.capacity_pages as f64
    }

    pub fn cached_pages(&self) -> usize {
        self.cached_pages
    }

    /// Retention budget for refcount-0 prefix pages (0 = cache disabled).
    pub fn prefix_cache_capacity(&self) -> usize {
        self.prefix_cache_pages
    }

    /// Σ prompt tokens served from the cache across all admissions.
    pub fn cache_hit_tokens_total(&self) -> usize {
        self.hit_tokens_total
    }

    /// Pages evicted from the retained pool since construction.
    pub fn evicted_pages_total(&self) -> usize {
        self.evicted_pages_total
    }

    /// Distinct digests of every interned full-page prefix currently
    /// resident (live or retained) — what a replica advertises into the
    /// cluster's digest table. O(distinct digests), no tree walk; order
    /// is unspecified (consumers treat it as a set).
    pub fn advertised_digests(&self) -> Vec<u64> {
        self.digest_counts.keys().copied().collect()
    }

    /// Number of distinct resident prefix digests (metrics).
    pub fn advertised_digest_count(&self) -> usize {
        self.digest_counts.len()
    }

    /// Is a full-page prefix with this digest resident right now? (Tests
    /// and the gossip staleness regressions.)
    pub fn has_digest(&self, digest: u64) -> bool {
        self.digest_counts.contains_key(&digest)
    }

    /// Take the next gossip advertisement: a Full snapshot on the first
    /// take (nothing advertised yet — e.g. a freshly constructed or
    /// restarted replica), a [`DigestDelta`] against the last advertised
    /// version afterwards. Either way the journal is drained and the
    /// advertised version catches up, so consecutive takes chain.
    /// Add/retract lists are sorted for deterministic wire contents.
    pub fn take_advertisement(&mut self) -> Advertisement {
        let Some(base) = self.advertised_version else {
            let (version, digests) = self.full_advertisement();
            return Advertisement::Full { version, digests };
        };
        let mut adds = Vec::new();
        let mut retracts = Vec::new();
        for (&d, &sign) in &self.digest_journal {
            if sign > 0 {
                adds.push(d);
            } else {
                retracts.push(d);
            }
        }
        adds.sort_unstable();
        retracts.sort_unstable();
        self.digest_journal.clear();
        self.advertised_version = Some(self.digest_version);
        Advertisement::Delta(DigestDelta {
            base_version: base,
            version: self.digest_version,
            adds,
            retracts,
        })
    }

    /// Force a full snapshot advertisement (version + every resident
    /// digest), regardless of delta state — the fallback when a receiver
    /// reports a base-version mismatch. Drains the journal and advances
    /// the advertised version like [`Self::take_advertisement`].
    pub fn full_advertisement(&mut self) -> (u64, Vec<u64>) {
        self.digest_journal.clear();
        self.advertised_version = Some(self.digest_version);
        (self.digest_version, self.advertised_digests())
    }

    fn admission_pages(&self, prompt_len: usize, max_new: usize, n_branches: usize) -> usize {
        pages_for(prompt_len, self.page_tokens)
            + n_branches * pages_for(max_new, self.page_tokens)
    }

    /// Walk the radix tree for the longest interned full-page prefix of
    /// `prompt`. Returns the matched node path, root-first.
    fn walk_path(&self, prompt: &[Token]) -> Vec<u32> {
        let mut path = Vec::new();
        if self.prefix_cache_pages == 0 {
            return path;
        }
        let pt = self.page_tokens;
        let full = prompt.len() / pt;
        let mut children: &[u32] = &self.roots;
        for i in 0..full {
            let page = &prompt[i * pt..(i + 1) * pt];
            let mut found = None;
            for &c in children {
                if self.nodes[c as usize]
                    .as_ref()
                    .is_some_and(|n| n.page.as_slice() == page)
                {
                    found = Some(c);
                    break;
                }
            }
            match found {
                Some(c) => {
                    path.push(c);
                    children = &self.nodes[c as usize].as_ref().unwrap().children;
                }
                None => break,
            }
        }
        path
    }

    /// Tokens of `prompt` resident in the radix cache right now (longest
    /// interned full-page prefix, live or retained). Read-only — the
    /// cluster's prefix-affinity policy probes replicas with this.
    pub fn cached_prefix_tokens(&self, prompt: &[Token]) -> usize {
        self.walk_path(prompt).len() * self.page_tokens
    }

    /// One tree walk's worth of admission arithmetic: the matched path,
    /// the pages the admission must newly allocate, and the retained
    /// (refcount-0) pages it would re-lease. Single source of the budget
    /// formula for every token-level admission mode.
    fn admission_need_tokens(
        &self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
    ) -> (Vec<u32>, usize, usize) {
        let pt = self.page_tokens;
        let full = prompt.len() / pt;
        let tail_pages = usize::from(prompt.len() % pt > 0);
        let path = self.walk_path(prompt);
        let hit_retained = path
            .iter()
            .filter(|&&c| self.nodes[c as usize].as_ref().unwrap().refcount == 0)
            .count();
        let need = (full - path.len())
            + tail_pages
            + n_branches * pages_for(max_new, pt);
        (path, need, hit_retained)
    }

    /// Evict the least-recently-retained refcount-0 node with no
    /// children (leaves first; ancestors become evictable as their
    /// subtrees drain — refcounts are monotone down the tree, so a
    /// refcount-0 subtree always contains a childless refcount-0 node).
    ///
    /// Linear scan by design: the node slab is bounded by the live
    /// prompt pages plus the (budgeted) retained pool, both small next
    /// to a serve's page traffic; an intrusive LRU list would only pay
    /// off once retained pools reach thousands of pages.
    fn evict_lru(&mut self) -> Result<()> {
        let mut best: Option<(u64, u32)> = None;
        for (i, slot) in self.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.refcount == 0 && n.children.is_empty() {
                    let key = (n.lru, i as u32);
                    match best {
                        Some(b) if key >= b => {}
                        _ => best = Some(key),
                    }
                }
            }
        }
        let Some((_, idx)) = best else {
            bail!("prefix cache eviction found no refcount-0 leaf");
        };
        let node = self.nodes[idx as usize].take().unwrap();
        debug_assert!(node.refcount == 0 && node.children.is_empty());
        self.retract_digest(node.digest);
        match node.parent {
            Some(p) => self.nodes[p as usize]
                .as_mut()
                .unwrap()
                .children
                .retain(|&c| c != idx),
            None => self.roots.retain(|&c| c != idx),
        }
        self.free_nodes.push(idx);
        self.cached_pages -= 1;
        self.evicted_pages_total += 1;
        Ok(())
    }

    /// Evict retained pages until `fresh` new pages fit physically.
    /// No-op when the cache is disabled (cached_pages is always 0 then).
    fn make_room(&mut self, fresh: usize) -> Result<()> {
        while self.capacity_pages
            - self.used_pages
            - self.pledged_pages
            - self.cached_pages
            < fresh
        {
            self.evict_lru()?;
        }
        Ok(())
    }

    /// Record one more resident node carrying `digest`.
    fn add_digest(&mut self, digest: u64) {
        let c = self.digest_counts.entry(digest).or_insert(0);
        *c += 1;
        if *c == 1 {
            self.journal(digest, 1);
        }
    }

    /// Drop one resident node carrying `digest`; the digest leaves the
    /// advertised set when its last node goes.
    fn retract_digest(&mut self, digest: u64) {
        let remove = match self.digest_counts.get_mut(&digest) {
            Some(c) if *c > 1 => {
                *c -= 1;
                false
            }
            Some(_) => true,
            // Unknown digest: nothing to retract. `check_invariants`
            // catches the multiset drifting, so don't panic a serve here.
            None => false,
        };
        if remove {
            self.digest_counts.remove(&digest);
            self.journal(digest, -1);
        }
    }

    /// Log one digest-*set* transition (`+1` entered, `-1` left) for the
    /// delta journal. A transition opposite to a pending entry is a round
    /// trip since the last advertisement — net zero, entry dropped.
    fn journal(&mut self, digest: u64, sign: i8) {
        self.digest_version += 1;
        match self.digest_journal.remove(&digest) {
            Some(prev) => debug_assert_eq!(prev, -sign),
            None => {
                self.digest_journal.insert(digest, sign);
            }
        }
    }

    fn alloc_node(&mut self, node: RadixNode) -> u32 {
        match self.free_nodes.pop() {
            Some(idx) => {
                debug_assert!(self.nodes[idx as usize].is_none());
                self.nodes[idx as usize] = Some(node);
                idx
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as u32
            }
        }
    }

    /// Intern `prompt`'s full pages from `from_page` onward as
    /// refcount-1 radix nodes chained below `leaf`; returns the new
    /// deepest node. `charge_used` additionally charges each page to
    /// `used_pages` (admission-time interning allocates fresh pages;
    /// commit-time interning converts pages already charged while
    /// staged). One definition shared by both paths so monolithic and
    /// chunked cache semantics cannot drift.
    fn intern_pages(
        &mut self,
        prompt: &[Token],
        from_page: usize,
        mut leaf: Option<u32>,
        charge_used: bool,
    ) -> Option<u32> {
        let pt = self.page_tokens;
        let full = prompt.len() / pt;
        let mut digest = match leaf {
            Some(p) => self.nodes[p as usize].as_ref().unwrap().digest,
            None => DIGEST_SEED,
        };
        for i in from_page..full {
            let page = prompt[i * pt..(i + 1) * pt].to_vec();
            digest = page_digest(digest, &page);
            self.add_digest(digest);
            let idx = self.alloc_node(RadixNode {
                page,
                parent: leaf,
                children: Vec::new(),
                refcount: 1,
                lru: 0,
                digest,
            });
            match leaf {
                Some(p) => self.nodes[p as usize]
                    .as_mut()
                    .unwrap()
                    .children
                    .push(idx),
                None => self.roots.push(idx),
            }
            if charge_used {
                self.used_pages += 1;
            }
            leaf = Some(idx);
        }
        leaf
    }

    /// Bump the lease refcount of every node on `path` (a `walk_path`
    /// result). Retained (refcount-0) hits leave the evictable pool:
    /// cached → used. One definition shared by both admission modes so
    /// their budget accounting cannot drift.
    fn lease_path(&mut self, path: &[u32]) {
        for &c in path {
            let was_retained = {
                let node = self.nodes[c as usize].as_mut().unwrap();
                node.refcount += 1;
                node.refcount == 1
            };
            if was_retained {
                self.cached_pages -= 1;
                self.used_pages += 1;
            }
        }
    }

    /// Insert `n_branches` reservations of `branch_pages` each against
    /// `prefix`, charging `used_pages` (shared by every admission path).
    fn reserve_branches(
        &mut self,
        prefix: PrefixId,
        n_branches: usize,
        branch_pages: usize,
    ) -> Vec<BranchId> {
        let mut ids = Vec::with_capacity(n_branches);
        for _ in 0..n_branches {
            let (bidx, bgen) = self.branches.insert(BranchAlloc {
                prefix,
                reserved_pages: branch_pages,
                grown_tokens: 0,
                priority: None,
            });
            self.used_pages += branch_pages;
            ids.push(BranchId { idx: bidx, gen: bgen });
        }
        ids
    }

    /// The unified admission entry point: dispatches on
    /// [`AdmissionRequest::mode`]. Every outcome is side-effect free when
    /// `Deferred`; errors are reserved for protocol misuse (unknown
    /// prefix handles, zero-sized streamed chunks).
    pub fn admit(
        &mut self,
        req: &AdmissionRequest,
    ) -> Result<AdmissionOutcome> {
        match req.mode {
            AdmissionMode::Monolithic => {
                self.admit_monolithic(req.prompt, req.max_new, req.branches)
            }
            AdmissionMode::Chunked => {
                self.admit_staged(req.prompt, req.max_new, req.branches, None)
            }
            AdmissionMode::Streamed { first_chunk_tokens } => {
                if first_chunk_tokens == 0 {
                    bail!("streamed admission needs first_chunk_tokens >= 1");
                }
                self.admit_staged(
                    req.prompt,
                    req.max_new,
                    req.branches,
                    Some(first_chunk_tokens),
                )
            }
            AdmissionMode::Grow { prefix } => {
                self.grow_branches(prefix, req.max_new, req.branches)
            }
        }
    }

    /// Scalar admission: allocate the whole prompt privately plus one
    /// reservation per branch. Never consults the radix cache — this is
    /// the pre-cache accounting, the delegation target when the cache is
    /// disabled (and thereby the Rebase baseline's path).
    fn admit_scalar(
        &mut self,
        prompt_len: usize,
        max_new: usize,
        n_branches: usize,
    ) -> Result<AdmissionOutcome> {
        let need = self.admission_pages(prompt_len, max_new, n_branches);
        if need > self.free_pages() {
            return Ok(AdmissionOutcome::Deferred {
                need_pages: need,
                free_pages: self.free_pages(),
            });
        }
        let prefix_pages = pages_for(prompt_len, self.page_tokens);
        let branch_pages = pages_for(max_new, self.page_tokens);
        self.make_room(prefix_pages + n_branches * branch_pages)?;
        let (pidx, pgen) = self.prefixes.insert(Prefix {
            pages: prefix_pages,
            private_pages: prefix_pages,
            refcount: n_branches,
            leaf: None,
            staged: None,
        });
        let prefix = PrefixId { idx: pidx, gen: pgen };
        self.used_pages += prefix_pages;
        let branch_ids = self.reserve_branches(prefix, n_branches, branch_pages);
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok(AdmissionOutcome::Admitted(Admission {
            prefix,
            branches: branch_ids,
            cached_tokens: 0,
        }))
    }

    /// Monolithic token-level admission: intern the prompt's full pages
    /// into the radix tree, lease the longest cached prefix for free, and
    /// only charge pages for the uncovered suffix (plus the private tail
    /// page and the per-branch reservations). One tree walk shared
    /// between the budget check and the admission — the scheduler's
    /// head-of-line gate sits on this path. With the cache disabled this
    /// delegates to the scalar accounting, byte-identical to it.
    fn admit_monolithic(
        &mut self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
    ) -> Result<AdmissionOutcome> {
        if self.prefix_cache_pages == 0 {
            return self.admit_scalar(prompt.len(), max_new, n_branches);
        }
        let (path, need, hit_retained) =
            self.admission_need_tokens(prompt, max_new, n_branches);
        if need + hit_retained > self.free_pages() {
            return Ok(AdmissionOutcome::Deferred {
                need_pages: need + hit_retained,
                free_pages: self.free_pages(),
            });
        }
        let pt = self.page_tokens;
        let tail_pages = usize::from(prompt.len() % pt > 0);
        let branch_pages = pages_for(max_new, pt);

        // 1. Lease the already-interned path. Bumping refcounts first
        //    protects the hit nodes from the eviction pass below; nodes
        //    leaving the retained pool move from cached to used.
        self.lease_path(&path);

        // 2. Make physical room for the genuinely new pages.
        self.make_room(need)?;

        // 3. Intern the uncovered full pages (one node per page).
        let leaf =
            self.intern_pages(prompt, path.len(), path.last().copied(), true);

        // 4. Private tail page, prefix record, branch reservations.
        self.used_pages += tail_pages;
        let (pidx, pgen) = self.prefixes.insert(Prefix {
            pages: pages_for(prompt.len(), pt),
            private_pages: tail_pages,
            refcount: n_branches,
            leaf,
            staged: None,
        });
        let prefix = PrefixId { idx: pidx, gen: pgen };
        let branch_ids = self.reserve_branches(prefix, n_branches, branch_pages);
        self.peak_pages = self.peak_pages.max(self.used_pages);
        let cached_tokens = path.len() * pt;
        self.hit_tokens_total += cached_tokens;
        Ok(AdmissionOutcome::Admitted(Admission {
            prefix,
            branches: branch_ids,
            cached_tokens,
        }))
    }

    /// Staged (chunked or streamed) admission: lease the radix-covered
    /// prefix and the per-branch reservations exactly like the monolithic
    /// path, but *pledge* the uncovered prompt suffix's pages instead of
    /// materializing them — they convert to used pages as prefill chunks
    /// land ([`KvCacheManager::note_prefill`]), and the full pages intern
    /// into the radix tree only when the prefill completes
    /// ([`KvCacheManager::commit_prefix`]). Interning on completion means
    /// a second identical prompt admitted while the first still streams
    /// sees no hit (its pages are not computed yet) — the monolithic path
    /// could intern optimistically at admission, this one cannot.
    ///
    /// `first_chunk` selects the pledge discipline. `None` (chunked): the
    /// whole uncovered suffix is pledged up front, so the admission can
    /// never be stranded mid-prefill by a later admission. `Some(c)`
    /// (streamed): only the pages spanned by the first `c` uncovered
    /// tokens are pledged, and the pledge grows per chunk via
    /// [`KvCacheManager::ensure_pledged`] — tighter budgets admit more,
    /// but a stream may stall mid-prompt waiting for pages. A streamed
    /// request whose *total* footprint exceeds the whole budget is
    /// deferred outright (it could never complete), keeping the stall
    /// transient by construction.
    ///
    /// Works with the cache disabled too (no path, no interning — the
    /// whole prompt streams and stays private).
    fn admit_staged(
        &mut self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
        first_chunk: Option<usize>,
    ) -> Result<AdmissionOutcome> {
        let (path, full_need, hit_retained) =
            self.admission_need_tokens(prompt, max_new, n_branches);
        let pt = self.page_tokens;
        let covered_pages = path.len();
        let covered_tokens = covered_pages * pt;
        let uncovered_tokens = prompt.len() - covered_tokens;
        let branch_pages = pages_for(max_new, pt);
        // Pledge discipline: whole suffix (chunked) vs first chunk
        // (streamed), measured in uncovered tokens whose pages must be
        // secured now.
        let pledged_tokens = match first_chunk {
            None => uncovered_tokens,
            Some(c) => uncovered_tokens.min(c),
        };
        let secured_pages =
            pages_for(covered_tokens + pledged_tokens, pt) - covered_pages;
        let need = secured_pages + n_branches * branch_pages;
        if first_chunk.is_some() && full_need > self.capacity_pages {
            // The stream could admit on its first chunk but never finish:
            // defer permanently rather than deadlock mid-prompt.
            return Ok(AdmissionOutcome::Deferred {
                need_pages: full_need,
                free_pages: self.free_pages(),
            });
        }
        if need + hit_retained > self.free_pages() {
            return Ok(AdmissionOutcome::Deferred {
                need_pages: need + hit_retained,
                free_pages: self.free_pages(),
            });
        }

        // 1. Lease the already-interned path (protects the hit nodes from
        //    the eviction pass below; retained hits move cached → used).
        self.lease_path(&path);

        // 2. Make physical room for everything this admission secures now
        //    (branch reservations immediately, pledged pages as chunks
        //    land).
        self.make_room(need)?;

        // 3. Prefix record: nothing is interned or materialized for the
        //    uncovered suffix yet — it all arrives via note_prefill.
        let staged = if covered_tokens < prompt.len() {
            Some(StagedPrefill {
                covered_tokens,
                prompt_tokens: prompt.len(),
                staged_tokens: 0,
                pledged_tokens,
                pledged_pages: secured_pages,
            })
        } else {
            None // fully covered: nothing to stream
        };
        let (pidx, pgen) = self.prefixes.insert(Prefix {
            pages: pages_for(prompt.len(), pt),
            private_pages: 0,
            refcount: n_branches,
            leaf: path.last().copied(),
            staged,
        });
        self.pledged_pages += secured_pages;
        let prefix = PrefixId { idx: pidx, gen: pgen };
        let branch_ids = self.reserve_branches(prefix, n_branches, branch_pages);
        self.peak_pages = self.peak_pages.max(self.used_pages);
        self.hit_tokens_total += covered_tokens;
        Ok(AdmissionOutcome::Admitted(Admission {
            prefix,
            branches: branch_ids,
            cached_tokens: covered_tokens,
        }))
    }

    /// Grow a streamed pledge: secure the pages spanned by the next
    /// `more_tokens` of the uncovered suffix (beyond what is already
    /// staged). Returns `Ok(false)` — with no side effects — when the
    /// budget cannot cover them yet; the stream stalls and retries after
    /// decode frees pages (or preemption reclaims them). A no-op
    /// `Ok(true)` when the pledge already covers the span (always the
    /// case for chunked admissions, whose pledge is the whole suffix).
    pub fn ensure_pledged(
        &mut self,
        prefix: PrefixId,
        more_tokens: usize,
    ) -> Result<bool> {
        let pt = self.page_tokens;
        let free = self.free_pages();
        let Some(p) = self.prefixes.get_mut(prefix.idx, prefix.gen) else {
            bail!("ensure_pledged on unknown prefix {prefix:?}");
        };
        let Some(st) = p.staged.as_mut() else {
            bail!("ensure_pledged on a prefix with no prefill in flight");
        };
        let uncovered = st.prompt_tokens - st.covered_tokens;
        let target = uncovered.min(st.staged_tokens + more_tokens);
        if target <= st.pledged_tokens {
            return Ok(true);
        }
        let covered_pages = st.covered_tokens / pt;
        let secured_now =
            pages_for(st.covered_tokens + st.pledged_tokens, pt) - covered_pages;
        let secured_target =
            pages_for(st.covered_tokens + target, pt) - covered_pages;
        let delta = secured_target - secured_now;
        if delta > free {
            return Ok(false);
        }
        st.pledged_tokens = target;
        st.pledged_pages += delta;
        self.pledged_pages += delta;
        self.make_room(0)?; // evict retained pages the pledge now displaces
        Ok(true)
    }

    /// Record `new_tokens` of chunked-prefill progress on `prefix`: pages
    /// fully spanned by the progress cursor convert from pledged to used
    /// (leased incrementally, per chunk). Errors on unknown prefixes, on
    /// prefixes with no prefill in flight, and on overrunning the
    /// uncovered suffix.
    pub fn note_prefill(
        &mut self,
        prefix: PrefixId,
        new_tokens: usize,
    ) -> Result<()> {
        let pt = self.page_tokens;
        let Some(p) = self.prefixes.get_mut(prefix.idx, prefix.gen) else {
            bail!("note_prefill on unknown prefix {prefix:?}");
        };
        let Some(st) = p.staged.as_mut() else {
            bail!("note_prefill on a prefix with no chunked prefill in flight");
        };
        let uncovered = st.prompt_tokens - st.covered_tokens;
        if st.staged_tokens + new_tokens > uncovered {
            bail!(
                "prefill progress overruns the uncovered suffix: \
                 {} + {new_tokens} > {uncovered}",
                st.staged_tokens
            );
        }
        if st.staged_tokens + new_tokens > st.pledged_tokens {
            bail!(
                "prefill progress overruns the streamed pledge: \
                 {} + {new_tokens} > {} pledged (grow the pledge via \
                 ensure_pledged first)",
                st.staged_tokens,
                st.pledged_tokens
            );
        }
        st.staged_tokens += new_tokens;
        let covered_pages = st.covered_tokens / pt;
        let materialized =
            pages_for(st.covered_tokens + st.staged_tokens, pt) - covered_pages;
        let delta = materialized - p.private_pages;
        p.private_pages = materialized;
        debug_assert!(st.pledged_pages >= delta);
        st.pledged_pages -= delta;
        debug_assert!(self.pledged_pages >= delta);
        self.pledged_pages -= delta;
        self.used_pages += delta;
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok(())
    }

    /// Complete a chunked prefill: intern the now-computed uncovered full
    /// pages into the radix tree (cache enabled) or leave them private
    /// (cache disabled). Requires every uncovered token to have been
    /// reported via [`KvCacheManager::note_prefill`] first. `prompt` must
    /// be the admission-time prompt — the manager does not retain token
    /// content for staged prefixes.
    ///
    /// Two identical prompts streamed concurrently each intern their own
    /// nodes (neither can lease pages the other has not finished
    /// computing); `walk_path` matches the first sibling, the duplicate
    /// ages out of the retained pool like any cold tail.
    pub fn commit_prefix(
        &mut self,
        prefix: PrefixId,
        prompt: &[Token],
    ) -> Result<()> {
        let pt = self.page_tokens;
        let covered_pages = {
            let Some(p) = self.prefixes.get(prefix.idx, prefix.gen) else {
                bail!("commit_prefix on unknown prefix {prefix:?}");
            };
            let Some(st) = p.staged.as_ref() else {
                bail!("commit_prefix on a prefix with no prefill in flight");
            };
            if st.prompt_tokens != prompt.len() {
                bail!(
                    "commit_prefix prompt length {} != admitted {}",
                    prompt.len(),
                    st.prompt_tokens
                );
            }
            if st.covered_tokens + st.staged_tokens != st.prompt_tokens {
                bail!(
                    "commit_prefix before prefill completed: {} of {} \
                     uncovered tokens staged",
                    st.staged_tokens,
                    st.prompt_tokens - st.covered_tokens
                );
            }
            debug_assert_eq!(st.pledged_pages, 0);
            st.covered_tokens / pt
        };
        if self.prefix_cache_pages == 0 {
            // No tree: the streamed pages simply stay private, matching
            // the scalar accounting.
            let p = self.prefixes.get_mut(prefix.idx, prefix.gen).unwrap();
            p.staged = None;
            return Ok(());
        }
        let tail_pages = usize::from(prompt.len() % pt > 0);
        let admitted_leaf =
            self.prefixes.get(prefix.idx, prefix.gen).unwrap().leaf;
        // The interned pages move from private to tree accounting; the
        // page totals (and used_pages) are unchanged, so intern_pages
        // must not charge them again.
        let leaf =
            self.intern_pages(prompt, covered_pages, admitted_leaf, false);
        let p = self.prefixes.get_mut(prefix.idx, prefix.gen).unwrap();
        p.leaf = leaf;
        p.private_pages = tail_pages;
        p.staged = None;
        Ok(())
    }

    /// Attach `n_more` branches to an existing shared prefix (tree
    /// expansion: a Rebase fork — or a preempted branch resuming — reuses
    /// the prompt pages and reserves fresh decode pages).
    fn grow_branches(
        &mut self,
        prefix: PrefixId,
        max_new: usize,
        n_more: usize,
    ) -> Result<AdmissionOutcome> {
        if self.prefixes.get(prefix.idx, prefix.gen).is_none() {
            bail!("grow on unknown prefix {prefix:?}");
        }
        let branch_pages = pages_for(max_new, self.page_tokens);
        let need = n_more * branch_pages;
        if need > self.free_pages() {
            return Ok(AdmissionOutcome::Deferred {
                need_pages: need,
                free_pages: self.free_pages(),
            });
        }
        self.make_room(need)?;
        let out = self.reserve_branches(prefix, n_more, branch_pages);
        self.prefixes
            .get_mut(prefix.idx, prefix.gen)
            .unwrap()
            .refcount += n_more;
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok(AdmissionOutcome::Admitted(Admission {
            prefix,
            branches: out,
            cached_tokens: 0,
        }))
    }

    /// Feed a branch's PRM reward in as its eviction priority: under
    /// pressure the scheduler preempts the lowest-priority branches first
    /// — exactly the ones SART's pruning phase was about to kill. NaN is
    /// rejected (it would poison the candidate ordering).
    pub fn set_branch_priority(
        &mut self,
        branch: BranchId,
        priority: f32,
    ) -> Result<()> {
        if priority.is_nan() {
            bail!("branch eviction priority must not be NaN");
        }
        let Some(b) = self.branches.get_mut(branch.idx, branch.gen) else {
            bail!("set_branch_priority on unknown branch {branch:?}");
        };
        if b.priority.is_none() {
            self.preemptable_pages += b.reserved_pages;
        }
        b.priority = Some(priority);
        Ok(())
    }

    /// Pages currently reclaimable by reward-driven preemption (Σ
    /// reserved pages over prioritized branches). O(1): maintained
    /// incrementally, rebuilt by `check_invariants`.
    pub fn preemptable_pages(&self) -> usize {
        self.preemptable_pages
    }

    /// The lowest-priority branches whose combined reservations cover
    /// `need_pages` — the manager's side of reward-driven preemption.
    /// Ordered worst reward first (slab index breaks ties
    /// deterministically); returns fewer than requested when the whole
    /// prioritized pool is smaller than the need.
    pub fn preemption_candidates(&self, need_pages: usize) -> Vec<BranchId> {
        let mut ranked: Vec<(f32, u32, u32, usize)> = Vec::new();
        for (idx, slot) in self.branches.slots.iter().enumerate() {
            if let Some(b) = &slot.val {
                if let Some(pri) = b.priority {
                    ranked.push((pri, idx as u32, slot.gen, b.reserved_pages));
                }
            }
        }
        ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut out = Vec::new();
        let mut freed = 0usize;
        for (_, idx, gen, reserved) in ranked {
            if freed >= need_pages {
                break;
            }
            out.push(BranchId { idx, gen });
            freed += reserved;
        }
        out
    }

    /// Record decode progress (informational; reservation already charged).
    pub fn note_decode(&mut self, branch: BranchId, new_tokens: usize) -> Result<()> {
        match self.branches.get_mut(branch.idx, branch.gen) {
            Some(b) => {
                b.grown_tokens += new_tokens;
                self.live_decoded += new_tokens;
                Ok(())
            }
            None => bail!("note_decode on unknown branch {branch:?}"),
        }
    }

    /// Tokens actually decoded by live branches (Fig. 3's "running
    /// tokens"). O(1): maintained incrementally by `note_decode` /
    /// `release_branch` and cross-checked by `check_invariants`.
    pub fn live_decoded_tokens(&self) -> usize {
        self.live_decoded
    }

    /// Drop one lease along `leaf`→root. Nodes reaching refcount 0 move
    /// to the retained pool (deepest stamped oldest, so request-unique
    /// tails evict before shared headers), then the pool is trimmed to
    /// the cache budget.
    fn release_lease(&mut self, leaf: u32) -> Result<()> {
        let mut cur = Some(leaf);
        while let Some(idx) = cur {
            let (parent, now_zero) = {
                let Some(node) =
                    self.nodes.get_mut(idx as usize).and_then(|s| s.as_mut())
                else {
                    bail!("lease release hit dead radix node {idx}");
                };
                if node.refcount == 0 {
                    bail!("radix lease refcount underflow at node {idx}");
                }
                node.refcount -= 1;
                (node.parent, node.refcount == 0)
            };
            if now_zero {
                self.lru_clock += 1;
                let stamp = self.lru_clock;
                self.nodes[idx as usize].as_mut().unwrap().lru = stamp;
                debug_assert!(self.used_pages >= 1);
                self.used_pages -= 1;
                self.cached_pages += 1;
            }
            cur = parent;
        }
        while self.cached_pages > self.prefix_cache_pages {
            self.evict_lru()?;
        }
        Ok(())
    }

    /// Release a branch (pruned / early-stopped / completed). Frees its
    /// reservation immediately; releases the prefix when the last sibling
    /// terminates — private pages are freed, interned pages drop their
    /// lease and are retained for cross-request reuse. Double release is
    /// an error (caught by the slab generation check, even after the slot
    /// has been reused).
    pub fn release_branch(&mut self, branch: BranchId) -> Result<()> {
        let Some(b) = self.branches.remove(branch.idx, branch.gen) else {
            bail!("double release of branch {branch:?}");
        };
        debug_assert!(self.used_pages >= b.reserved_pages);
        self.used_pages -= b.reserved_pages;
        debug_assert!(self.live_decoded >= b.grown_tokens);
        self.live_decoded -= b.grown_tokens;
        if b.priority.is_some() {
            debug_assert!(self.preemptable_pages >= b.reserved_pages);
            self.preemptable_pages -= b.reserved_pages;
        }
        let prefix = self
            .prefixes
            .get_mut(b.prefix.idx, b.prefix.gen)
            .expect("branch with dangling prefix");
        prefix.refcount -= 1;
        if prefix.refcount == 0 {
            let p = self.prefixes.remove(b.prefix.idx, b.prefix.gen).unwrap();
            debug_assert!(self.used_pages >= p.private_pages);
            self.used_pages -= p.private_pages;
            if let Some(st) = p.staged {
                // Released mid-prefill: the partial pages materialized so
                // far were just freed with `private_pages`; cancel the
                // outstanding pledge. Nothing was interned, so the radix
                // tree never sees the half-computed suffix.
                debug_assert!(self.pledged_pages >= st.pledged_pages);
                self.pledged_pages -= st.pledged_pages;
            }
            if let Some(leaf) = p.leaf {
                self.release_lease(leaf)?;
            }
        }
        Ok(())
    }

    /// Number of live branches (for invariant checks).
    pub fn live_branches(&self) -> usize {
        self.branches.len
    }

    pub fn live_prefixes(&self) -> usize {
        self.prefixes.len
    }

    /// Internal invariant: used_pages equals the sum of all live
    /// allocations, the incremental counters match a from-scratch
    /// recomputation, and the radix tree's refcounts / page accounting
    /// rebuild exactly from the live prefix set. Exposed for property
    /// tests and audit-mode serves.
    pub fn check_invariants(&self) -> Result<()> {
        // Rebuild per-node lease counts from the live prefixes.
        let mut expected = vec![0usize; self.nodes.len()];
        let mut pledged_scan = 0usize;
        for p in self.prefixes.iter() {
            let mut cur = p.leaf;
            let mut steps = 0usize;
            while let Some(idx) = cur {
                let Some(node) =
                    self.nodes.get(idx as usize).and_then(|s| s.as_ref())
                else {
                    bail!("prefix leaf chain hits dead radix node {idx}");
                };
                expected[idx as usize] += 1;
                cur = node.parent;
                steps += 1;
                if steps > self.nodes.len() {
                    bail!("parent cycle in radix tree");
                }
            }
            // Total prompt pages split exactly into interned path +
            // private remainder + outstanding pledge + (streamed-only)
            // not-yet-pledged remainder.
            let pledged = p.staged.as_ref().map_or(0, |st| st.pledged_pages);
            let unpledged = p.staged.as_ref().map_or(0, |st| {
                pages_for(st.prompt_tokens, self.page_tokens)
                    - pages_for(
                        st.covered_tokens + st.pledged_tokens,
                        self.page_tokens,
                    )
            });
            pledged_scan += pledged;
            if p.pages != p.private_pages + steps + pledged + unpledged {
                bail!(
                    "prefix page split drift: {} != {} private + {steps} \
                     interned + {pledged} pledged + {unpledged} unpledged",
                    p.pages,
                    p.private_pages
                );
            }
            if let Some(st) = &p.staged {
                // Mid-prefill bookkeeping must be self-consistent: the
                // leased path is exactly the covered prefix, progress
                // stays within the pledged span (itself within the
                // uncovered suffix), the private pages are exactly the
                // ones the cursor has spanned, and the grown pledge
                // rebuilds from the pledged-token cursor.
                if st.covered_tokens != steps * self.page_tokens {
                    bail!(
                        "staged prefix covered_tokens {} != {} path pages",
                        st.covered_tokens,
                        steps
                    );
                }
                if st.covered_tokens + st.staged_tokens > st.prompt_tokens {
                    bail!(
                        "staged prefix progress overran its prompt: \
                         {} + {} > {}",
                        st.covered_tokens,
                        st.staged_tokens,
                        st.prompt_tokens
                    );
                }
                if st.staged_tokens > st.pledged_tokens
                    || st.covered_tokens + st.pledged_tokens
                        > st.prompt_tokens
                {
                    bail!(
                        "staged prefix pledge cursor out of bounds: \
                         {} staged / {} pledged / {} uncovered",
                        st.staged_tokens,
                        st.pledged_tokens,
                        st.prompt_tokens - st.covered_tokens
                    );
                }
                let materialized = pages_for(
                    st.covered_tokens + st.staged_tokens,
                    self.page_tokens,
                ) - steps;
                if materialized != p.private_pages {
                    bail!(
                        "staged prefix materialized {materialized} pages \
                         but holds {} private",
                        p.private_pages
                    );
                }
                let secured = pages_for(
                    st.covered_tokens + st.pledged_tokens,
                    self.page_tokens,
                ) - steps;
                if st.pledged_pages != secured - materialized {
                    bail!(
                        "grown pledge drift: {} pledged pages != {} \
                         secured - {materialized} materialized",
                        st.pledged_pages,
                        secured
                    );
                }
            }
        }
        if pledged_scan != self.pledged_pages {
            bail!(
                "pledged_pages drift: counter {} != recomputed {pledged_scan}",
                self.pledged_pages
            );
        }
        let mut live_tree_pages = 0usize;
        let mut retained_pages = 0usize;
        let mut linked_children = 0usize;
        for (i, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.refcount != expected[i] {
                bail!(
                    "radix refcount drift at node {i}: {} != recomputed {}",
                    n.refcount,
                    expected[i]
                );
            }
            if n.page.len() != self.page_tokens {
                bail!("radix node {i} is not page-sized");
            }
            if n.refcount > 0 {
                live_tree_pages += 1;
            } else {
                retained_pages += 1;
            }
            linked_children += n.children.len();
            for &c in &n.children {
                let Some(ch) =
                    self.nodes.get(c as usize).and_then(|s| s.as_ref())
                else {
                    bail!("radix node {i} has dangling child {c}");
                };
                if ch.parent != Some(i as u32) {
                    bail!("radix parent pointer mismatch at child {c}");
                }
            }
        }
        for &r in &self.roots {
            let Some(n) = self.nodes.get(r as usize).and_then(|s| s.as_ref())
            else {
                bail!("dangling radix root {r}");
            };
            if n.parent.is_some() {
                bail!("radix root {r} has a parent");
            }
        }
        let total_nodes =
            self.nodes.iter().filter(|s| s.is_some()).count();
        if linked_children + self.roots.len() != total_nodes {
            bail!(
                "radix link count drift: {} children + {} roots != {} nodes",
                linked_children,
                self.roots.len(),
                total_nodes
            );
        }
        // Digest chains and the advertised multiset rebuild exactly: walk
        // the forest root-down recomputing every node's rolling digest.
        let mut digest_scan: HashMap<u64, u32> = HashMap::new();
        let mut stack: Vec<(u32, u64)> =
            self.roots.iter().map(|&r| (r, DIGEST_SEED)).collect();
        let mut visited = 0usize;
        while let Some((idx, parent_digest)) = stack.pop() {
            let Some(n) = self.nodes.get(idx as usize).and_then(|s| s.as_ref())
            else {
                bail!("digest walk hit dead radix node {idx}");
            };
            let expect = page_digest(parent_digest, &n.page);
            if n.digest != expect {
                bail!(
                    "radix digest drift at node {idx}: {:#018x} != \
                     recomputed {expect:#018x}",
                    n.digest
                );
            }
            *digest_scan.entry(expect).or_insert(0) += 1;
            visited += 1;
            if visited > total_nodes {
                bail!("child cycle in radix tree");
            }
            for &c in &n.children {
                stack.push((c, expect));
            }
        }
        if visited != total_nodes {
            bail!(
                "digest walk covered {visited} of {total_nodes} radix nodes"
            );
        }
        if digest_scan != self.digest_counts {
            // Name one differing entry so the drift is debuggable; the
            // key sets may well have equal sizes.
            let culprit = self
                .digest_counts
                .iter()
                .find(|(d, c)| digest_scan.get(*d) != Some(*c))
                .map(|(d, c)| (*d, *c, digest_scan.get(d).copied()))
                .or_else(|| {
                    digest_scan
                        .iter()
                        .find(|(d, _)| !self.digest_counts.contains_key(*d))
                        .map(|(d, c)| (*d, 0, Some(*c)))
                });
            let (d, tracked, scanned) = culprit.unwrap_or((0, 0, None));
            bail!(
                "advertised digest multiset drift: digest {d:#018x} tracked \
                 {tracked} times vs recomputed {scanned:?} ({} tracked / {} \
                 recomputed distinct digests)",
                self.digest_counts.len(),
                digest_scan.len()
            );
        }
        // The delta journal must describe real set transitions: a pending
        // add names a digest that is resident, a pending retract one that
        // is not, and net values outside ±1 are impossible (presence is
        // boolean; round trips cancel).
        for (&d, &sign) in &self.digest_journal {
            if sign != 1 && sign != -1 {
                bail!("digest journal entry {d:#018x} has net {sign}");
            }
            let present = self.digest_counts.contains_key(&d);
            if sign == 1 && !present {
                bail!(
                    "digest journal advertises {d:#018x} as added but it \
                     is not resident"
                );
            }
            if sign == -1 && present {
                bail!(
                    "digest journal advertises {d:#018x} as retracted but \
                     it is still resident"
                );
            }
        }
        if retained_pages != self.cached_pages {
            bail!(
                "cached_pages drift: counter {} != recomputed {retained_pages}",
                self.cached_pages
            );
        }
        if self.cached_pages > self.prefix_cache_pages {
            bail!(
                "retained pages over cache budget: {} > {}",
                self.cached_pages,
                self.prefix_cache_pages
            );
        }
        let computed: usize = live_tree_pages
            + self.prefixes.iter().map(|p| p.private_pages).sum::<usize>()
            + self.branches.iter().map(|b| b.reserved_pages).sum::<usize>();
        if computed != self.used_pages {
            bail!("accounting drift: computed {computed} != used {}", self.used_pages);
        }
        if self.used_pages + self.pledged_pages + self.cached_pages
            > self.capacity_pages
        {
            bail!(
                "over budget: {} used + {} pledged + {} cached > {}",
                self.used_pages,
                self.pledged_pages,
                self.cached_pages,
                self.capacity_pages
            );
        }
        let decoded: usize = self.branches.iter().map(|b| b.grown_tokens).sum();
        if decoded != self.live_decoded {
            bail!(
                "live_decoded drift: recomputed {decoded} != counter {}",
                self.live_decoded
            );
        }
        let preemptable: usize = self
            .branches
            .iter()
            .filter(|b| b.priority.is_some())
            .map(|b| b.reserved_pages)
            .sum();
        if preemptable != self.preemptable_pages {
            bail!(
                "preemptable_pages drift: recomputed {preemptable} != \
                 counter {}",
                self.preemptable_pages
            );
        }
        if self.branches.iter().any(|b| b.priority.is_some_and(f32::is_nan)) {
            bail!("NaN branch eviction priority");
        }
        for b in self.branches.iter() {
            if self.prefixes.get(b.prefix.idx, b.prefix.gen).is_none() {
                bail!("branch references dead prefix");
            }
        }
        let refsum: usize = self.prefixes.iter().map(|p| p.refcount).sum();
        if refsum != self.branches.len {
            bail!("refcount drift: {} != {}", refsum, self.branches.len);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A page-aligned synthetic prompt: `base..base+len` as tokens.
    fn prompt(base: i32, len: usize) -> Vec<Token> {
        (base..base + len as i32).collect()
    }

    /// Scalar-style admission by prompt length: token content is
    /// irrelevant on the cache-disabled path, so a synthetic prompt
    /// stands in for it.
    fn admit_len(
        kv: &mut KvCacheManager,
        len: usize,
        max_new: usize,
        n: usize,
    ) -> Result<(PrefixId, Vec<BranchId>)> {
        let p = prompt(-20_000, len);
        let a = kv
            .admit(&AdmissionRequest::monolithic(&p, max_new, n))?
            .into_admission()?;
        Ok((a.prefix, a.branches))
    }

    /// Monolithic admission that errors when deferred.
    fn admit_tokens(
        kv: &mut KvCacheManager,
        p: &[Token],
        max_new: usize,
        n: usize,
    ) -> Result<Admission> {
        kv.admit(&AdmissionRequest::monolithic(p, max_new, n))?
            .into_admission()
    }

    /// Chunked admission: `None` when deferred.
    fn admit_chunked(
        kv: &mut KvCacheManager,
        p: &[Token],
        max_new: usize,
        n: usize,
    ) -> Option<Admission> {
        kv.admit(&AdmissionRequest::chunked(p, max_new, n))
            .unwrap()
            .admitted()
    }

    #[test]
    fn admit_and_release_roundtrip() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, branches) = admit_len(&mut kv, 30, 100, 4).unwrap();
        // prefix: ceil(30/16)=2, branch: ceil(100/16)=7 → 2 + 28 = 30.
        assert_eq!(kv.used_pages(), 30);
        kv.check_invariants().unwrap();
        for b in &branches[..3] {
            kv.release_branch(*b).unwrap();
        }
        // prefix still held by last branch.
        assert_eq!(kv.used_pages(), 2 + 7);
        assert_eq!(kv.live_prefixes(), 1);
        kv.release_branch(branches[3]).unwrap();
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.live_prefixes(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control_blocks() {
        let mut kv = KvCacheManager::new(160, 16); // 10 pages
        let (_, _b) = admit_len(&mut kv, 16, 32, 4).unwrap(); // 1 + 4*2 = 9
        // Needs 3 more pages with only 1 free: deferred, and the outcome
        // reports the exact shortfall.
        let p = prompt(0, 16);
        match kv.admit(&AdmissionRequest::monolithic(&p, 32, 1)).unwrap() {
            AdmissionOutcome::Deferred { need_pages, free_pages } => {
                assert_eq!((need_pages, free_pages), (3, 1));
            }
            AdmissionOutcome::Admitted(_) => panic!("over-budget admit"),
        }
        assert!(admit_len(&mut kv, 16, 32, 1).is_err());
        assert_eq!(kv.used_pages(), 9); // deferred admit has no side effects
        kv.check_invariants().unwrap();
    }

    #[test]
    fn double_release_rejected() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, branches) = admit_len(&mut kv, 10, 10, 1).unwrap();
        kv.release_branch(branches[0]).unwrap();
        assert!(kv.release_branch(branches[0]).is_err());
    }

    #[test]
    fn stale_handles_rejected_after_slot_reuse() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (p1, b1) = admit_len(&mut kv, 16, 16, 1).unwrap();
        kv.release_branch(b1[0]).unwrap();
        // The next admit reuses the freed slab slots with a bumped
        // generation; the stale handles must still be rejected.
        let (p2, b2) = admit_len(&mut kv, 16, 16, 1).unwrap();
        assert!(kv.note_decode(b1[0], 4).is_err());
        assert!(kv.release_branch(b1[0]).is_err());
        assert!(kv.admit(&AdmissionRequest::grow(p1, 16, 1)).is_err());
        assert_ne!(p1, p2);
        assert_ne!(b1[0], b2[0]);
        kv.note_decode(b2[0], 4).unwrap();
        kv.release_branch(b2[0]).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn live_decoded_tokens_tracks_growth() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (_, bs) = admit_len(&mut kv, 27, 64, 2).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 0);
        kv.note_decode(bs[0], 10).unwrap();
        kv.note_decode(bs[1], 5).unwrap();
        kv.note_decode(bs[0], 3).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 18);
        kv.check_invariants().unwrap();
        kv.release_branch(bs[0]).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 5);
        kv.release_branch(bs[1]).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefix_sharing_saves_pages() {
        let mut shared = KvCacheManager::new(10_000, 16);
        admit_len(&mut shared, 64, 64, 8).unwrap(); // 4 + 8*4 = 36
        let mut unshared = KvCacheManager::new(10_000, 16);
        for _ in 0..8 {
            admit_len(&mut unshared, 64, 64, 1).unwrap(); // 8 * (4+4) = 64
        }
        assert!(shared.used_pages() < unshared.used_pages());
        assert_eq!(shared.used_pages(), 36);
        assert_eq!(unshared.used_pages(), 64);
    }

    #[test]
    fn peak_tracking() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, b) = admit_len(&mut kv, 16, 16, 2).unwrap();
        let peak = kv.used_pages();
        for bid in b {
            kv.release_branch(bid).unwrap();
        }
        assert_eq!(kv.peak_pages(), peak);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn page_rounding() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }

    // -----------------------------------------------------------------
    // Cross-request radix prefix cache.
    // -----------------------------------------------------------------

    #[test]
    fn disabled_cache_matches_scalar_admit_exactly() {
        // admit_tokens with a zero cache budget must mirror the scalar
        // path page for page (the pre-cache accounting).
        let mut scalar = KvCacheManager::new(4096, 16);
        let mut tokens = KvCacheManager::new(4096, 16);
        let p = prompt(100, 30);
        let (_, bs1) = admit_len(&mut scalar, p.len(), 100, 4).unwrap();
        let adm = admit_tokens(&mut tokens, &p, 100, 4).unwrap();
        assert_eq!(adm.cached_tokens, 0);
        assert_eq!(scalar.used_pages(), tokens.used_pages());
        assert_eq!(tokens.cached_pages(), 0);
        // Second identical prompt: still no sharing with the cache off.
        let before = tokens.used_pages();
        let adm2 = admit_tokens(&mut tokens, &p, 100, 4).unwrap();
        assert_eq!(adm2.cached_tokens, 0);
        assert_eq!(tokens.used_pages(), 2 * before);
        for b in bs1 {
            scalar.release_branch(b).unwrap();
        }
        for b in adm.branches.into_iter().chain(adm2.branches) {
            tokens.release_branch(b).unwrap();
        }
        assert_eq!(tokens.used_pages(), 0);
        assert_eq!(tokens.cached_pages(), 0);
        tokens.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_identical_prompts_share_interned_pages() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 48); // 3 full pages
        let a = admit_tokens(&mut kv, &p, 32, 2).unwrap();
        assert_eq!(a.cached_tokens, 0); // cold
        // 3 tree pages + 2 branches × 2 pages.
        assert_eq!(kv.used_pages(), 3 + 4);
        let b = admit_tokens(&mut kv, &p, 32, 2).unwrap();
        assert_eq!(b.cached_tokens, 48); // full-page hit while live
        // Only the new branch reservations are charged.
        assert_eq!(kv.used_pages(), 3 + 4 + 4);
        kv.check_invariants().unwrap();
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        // Interned pages are retained, not freed.
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.cached_pages(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retained_prefix_serves_later_request() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 40); // 2 full pages + 8-token tail
        let a = admit_tokens(&mut kv, &p, 32, 1).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(kv.used_pages(), 2 + 1 + 2); // tree + tail + branch
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.cached_pages(), 2);
        assert_eq!(kv.cached_prefix_tokens(&p), 32);
        // Re-admit: the 2 full pages come from the cache.
        let b = admit_tokens(&mut kv, &p, 32, 1).unwrap();
        assert_eq!(b.cached_tokens, 32);
        assert_eq!(kv.used_pages(), 2 + 1 + 2);
        assert_eq!(kv.cached_pages(), 0);
        assert_eq!(kv.cache_hit_tokens_total(), 32);
        kv.check_invariants().unwrap();
        for br in b.branches {
            kv.release_branch(br).unwrap();
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn shared_header_divergent_tails_split_in_tree() {
        // Two prompts sharing 2 pages then diverging: the second admit
        // hits exactly the shared pages.
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let mut p1 = prompt(0, 32);
        p1.extend(prompt(500, 16));
        let mut p2 = prompt(0, 32);
        p2.extend(prompt(900, 16));
        let a = admit_tokens(&mut kv, &p1, 16, 1).unwrap();
        let b = admit_tokens(&mut kv, &p2, 16, 1).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(b.cached_tokens, 32);
        // 2 shared + 2 divergent tree pages + 2 branch pages.
        assert_eq!(kv.used_pages(), 2 + 1 + 1 + 1 + 1);
        kv.check_invariants().unwrap();
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        assert_eq!(kv.cached_pages(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn cache_budget_trims_lru_leaves_first() {
        // Budget of 2 retained pages; a released 4-page prefix keeps only
        // its 2 shallowest pages (deepest stamped oldest → evicted first).
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 2);
        let p = prompt(0, 64);
        let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 2);
        assert_eq!(kv.evicted_pages_total(), 2);
        // The survivors are the root-most pages: a 2-page prefix of the
        // same prompt still hits, the full prompt only partially.
        assert_eq!(kv.cached_prefix_tokens(&p), 32);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_never_touches_live_prefixes() {
        // A live request's interned pages must survive arbitrary cache
        // pressure; only refcount-0 pages are evictable.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 24, 16, 4);
        let live_prompt = prompt(0, 48); // 3 tree pages
        let live = admit_tokens(&mut kv, &live_prompt, 16, 1).unwrap(); // +1 branch page
        // Fill and churn the retained pool with released one-page prompts.
        for i in 0..6 {
            let p = prompt(1000 + 100 * i, 16);
            let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
            for b in a.branches {
                kv.release_branch(b).unwrap();
            }
            kv.check_invariants().unwrap();
        }
        assert!(kv.evicted_pages_total() > 0, "churn must evict");
        assert_eq!(
            kv.cached_prefix_tokens(&live_prompt),
            48,
            "live prefix evicted from the radix tree"
        );
        // Oldest retained one-pagers were evicted, newest survive.
        assert_eq!(kv.cached_prefix_tokens(&prompt(1000, 16)), 0);
        assert_eq!(kv.cached_prefix_tokens(&prompt(1500, 16)), 16);
        for b in live.branches {
            kv.release_branch(b).unwrap();
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_evicts_retained_pages_on_demand() {
        // 8-page budget total. A retained 3-page prefix must be evicted
        // to make room for a fresh admission that needs the space.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 8, 16, 8);
        let a = admit_tokens(&mut kv, &prompt(0, 48), 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 3);
        // New prompt: 4 tree pages + 2 branch pages = 6 fresh; physical
        // free is 8 - 3 retained, so one retained page must go.
        let b = admit_tokens(&mut kv, &prompt(2000, 64), 32, 1).unwrap();
        assert_eq!(b.cached_tokens, 0);
        assert_eq!(kv.used_pages(), 6);
        assert!(kv.used_pages() + kv.cached_pages() <= kv.capacity_pages());
        assert!(kv.evicted_pages_total() >= 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retained_hit_counts_against_admission_headroom() {
        // 6-page budget. Retained 2-page prefix; re-admitting it with a
        // branch load that fits only if the retained pages were free must
        // be rejected: the hit pages stop being evictable.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 6, 16, 6);
        let p = prompt(0, 32);
        let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 2);
        // Re-lease 2 retained + 5 branch pages > 6 total: must refuse.
        assert!(kv
            .admit(&AdmissionRequest::monolithic(&p, 16 * 5, 1))
            .unwrap()
            .is_deferred());
        assert!(admit_tokens(&mut kv, &p, 16 * 5, 1).is_err());
        // 2 retained + 4 branch pages == 6: fits exactly.
        let b = admit_tokens(&mut kv, &p, 16 * 4, 1).unwrap();
        assert_eq!(b.cached_tokens, 32);
        assert_eq!(kv.used_pages(), 6);
        kv.check_invariants().unwrap();
    }

    // -----------------------------------------------------------------
    // Chunked prefill: incremental leasing, commit-time interning.
    // -----------------------------------------------------------------

    #[test]
    fn chunked_admission_leases_pages_incrementally() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 48); // 3 full pages, no tail
        let adm = admit_chunked(&mut kv, &p, 32, 2).unwrap();
        assert_eq!(adm.cached_tokens, 0);
        // Only the 2×2 branch reservations are materialized; the prompt's
        // 3 pages are pledged.
        assert_eq!(kv.used_pages(), 4);
        assert_eq!(kv.pledged_pages(), 3);
        kv.check_invariants().unwrap();
        // Chunks land: pages convert pledge → used as the cursor spans
        // them (the page materializes at its first token).
        kv.note_prefill(adm.prefix, 16).unwrap();
        assert_eq!((kv.used_pages(), kv.pledged_pages()), (5, 2));
        kv.note_prefill(adm.prefix, 8).unwrap();
        assert_eq!((kv.used_pages(), kv.pledged_pages()), (6, 1));
        kv.note_prefill(adm.prefix, 8).unwrap(); // page boundary exactly
        assert_eq!((kv.used_pages(), kv.pledged_pages()), (6, 1));
        kv.check_invariants().unwrap();
        // Nothing is interned before commit: a probe sees no hit.
        assert_eq!(kv.cached_prefix_tokens(&p), 0);
        // Commit requires the full suffix.
        assert!(kv.commit_prefix(adm.prefix, &p).is_err());
        kv.note_prefill(adm.prefix, 16).unwrap();
        assert_eq!((kv.used_pages(), kv.pledged_pages()), (7, 0));
        kv.commit_prefix(adm.prefix, &p).unwrap();
        kv.check_invariants().unwrap();
        // Interned now: resident for probes, retained after release.
        assert_eq!(kv.cached_prefix_tokens(&p), 48);
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.cached_pages(), 3);
        kv.check_invariants().unwrap();
        // A later admission re-leases the committed pages like any hit.
        let warm = admit_tokens(&mut kv, &p, 32, 1).unwrap();
        assert_eq!(warm.cached_tokens, 48);
    }

    #[test]
    fn mid_prefill_release_frees_partial_pages_and_pledge() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 50); // 3 full pages + 2-token tail
        let adm = admit_chunked(&mut kv, &p, 16, 2).unwrap();
        assert_eq!(kv.pledged_pages(), 4);
        kv.note_prefill(adm.prefix, 20).unwrap(); // 2 pages materialized
        assert_eq!(kv.used_pages(), 2 + 2 * 1);
        assert_eq!(kv.pledged_pages(), 2);
        kv.check_invariants().unwrap();
        // Request finishes / is preempted mid-prefill: every partial page
        // and the outstanding pledge must go, and the half-computed
        // suffix must never reach the radix tree.
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.pledged_pages(), 0);
        assert_eq!(kv.cached_pages(), 0);
        assert_eq!(kv.cached_prefix_tokens(&p), 0);
        kv.check_invariants().unwrap();
        assert!(kv.note_prefill(adm.prefix, 1).is_err(), "stale prefix");
    }

    #[test]
    fn chunked_admission_pledge_counts_against_budget() {
        // 8 pages total. Chunked admit pledges 3 prompt pages + uses 2
        // branch pages → 3 free. A 4-page admission must be refused even
        // though only 2 pages are physically used.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 8, 16, 8);
        let p = prompt(0, 48);
        let adm = admit_chunked(&mut kv, &p, 32, 1).unwrap();
        assert_eq!(kv.used_pages(), 2);
        assert_eq!(kv.free_pages(), 3);
        assert!(admit_chunked(&mut kv, &prompt(500, 32), 32, 1).is_none());
        assert!(kv
            .admit(&AdmissionRequest::monolithic(&prompt(500, 32), 32, 1))
            .unwrap()
            .is_deferred());
        // 3 pages fits exactly (1 prompt page + 2 branch pages).
        assert!(admit_chunked(&mut kv, &prompt(500, 16), 32, 1).is_some());
        kv.check_invariants().unwrap();
        kv.note_prefill(adm.prefix, 48).unwrap();
        kv.commit_prefix(adm.prefix, &p).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn fully_covered_chunked_admission_streams_nothing() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 32); // page-aligned: fully internable
        let cold = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        for b in cold.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 2);
        // Chunked re-admission of the retained prompt: zero uncovered
        // tokens, so there is no staging state at all.
        let warm = admit_chunked(&mut kv, &p, 16, 1).unwrap();
        assert_eq!(warm.cached_tokens, 32);
        assert_eq!(kv.pledged_pages(), 0);
        assert!(kv.note_prefill(warm.prefix, 1).is_err(), "nothing to stream");
        assert!(kv.commit_prefix(warm.prefix, &p).is_err());
        kv.check_invariants().unwrap();
        for b in warm.branches {
            kv.release_branch(b).unwrap();
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn chunked_admission_cache_disabled_matches_scalar_totals() {
        // With the cache off, a streamed admission must end at exactly the
        // scalar accounting once complete (all prompt pages private, no
        // tree), and drain back to zero.
        let mut scalar = KvCacheManager::new(4096, 16);
        let mut chunked = KvCacheManager::new(4096, 16);
        let p = prompt(0, 40); // 2 full pages + tail
        let (_, bs) = admit_len(&mut scalar, p.len(), 64, 3).unwrap();
        let adm = admit_chunked(&mut chunked, &p, 64, 3).unwrap();
        assert_eq!(adm.cached_tokens, 0);
        assert_eq!(
            chunked.used_pages() + chunked.pledged_pages(),
            scalar.used_pages()
        );
        chunked.note_prefill(adm.prefix, 25).unwrap();
        chunked.check_invariants().unwrap();
        chunked.note_prefill(adm.prefix, 15).unwrap();
        chunked.commit_prefix(adm.prefix, &p).unwrap();
        assert_eq!(chunked.used_pages(), scalar.used_pages());
        assert_eq!(chunked.pledged_pages(), 0);
        assert_eq!(chunked.cached_pages(), 0);
        chunked.check_invariants().unwrap();
        for b in bs {
            scalar.release_branch(b).unwrap();
        }
        for b in adm.branches {
            chunked.release_branch(b).unwrap();
        }
        assert_eq!(chunked.used_pages(), 0);
        chunked.check_invariants().unwrap();
    }

    // -----------------------------------------------------------------
    // Prefix digests (cross-replica gossip).
    // -----------------------------------------------------------------

    #[test]
    fn prompt_page_digests_chain_per_page() {
        let p = prompt(0, 40); // 2 full pages + 8-token tail
        let ds = prompt_page_digests(&p, 16);
        assert_eq!(ds.len(), 2, "only full pages digest");
        assert_eq!(ds[0], page_digest(DIGEST_SEED, &p[..16]));
        assert_eq!(ds[1], page_digest(ds[0], &p[16..32]));
        // Content-sensitive: a one-token change flips every digest from
        // that page on.
        let mut q = p.clone();
        q[20] += 1;
        let dq = prompt_page_digests(&q, 16);
        assert_eq!(dq[0], ds[0]);
        assert_ne!(dq[1], ds[1]);
        // Sub-page prompts advertise nothing.
        assert!(prompt_page_digests(&p[..10], 16).is_empty());
    }

    #[test]
    fn digest_set_tracks_intern_and_release() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 48); // 3 full pages
        let ds = prompt_page_digests(&p, 16);
        assert_eq!(kv.advertised_digest_count(), 0);
        let a = admit_tokens(&mut kv, &p, 32, 1).unwrap();
        assert!(ds.iter().all(|d| kv.has_digest(*d)));
        assert_eq!(kv.advertised_digest_count(), 3);
        kv.check_invariants().unwrap();
        // Release retains the pages: digests stay advertised.
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.advertised_digest_count(), 3);
        assert_eq!(kv.advertised_digests().len(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn digest_retracts_on_eviction() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 2);
        let p = prompt(0, 64); // 4 pages; retention budget 2
        let ds = prompt_page_digests(&p, 16);
        let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        assert_eq!(kv.advertised_digest_count(), 4);
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        // Pool trimmed to 2: the deepest digests retract with their
        // nodes, the shallowest survive.
        assert!(kv.has_digest(ds[0]) && kv.has_digest(ds[1]));
        assert!(!kv.has_digest(ds[2]) && !kv.has_digest(ds[3]));
        assert_eq!(kv.advertised_digest_count(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn digest_interns_only_at_chunked_commit_never_mid_prefill() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 48);
        let ds = prompt_page_digests(&p, 16);
        let adm = admit_chunked(&mut kv, &p, 16, 1).unwrap();
        kv.note_prefill(adm.prefix, 32).unwrap();
        assert_eq!(kv.advertised_digest_count(), 0, "digest before commit");
        kv.check_invariants().unwrap();
        kv.note_prefill(adm.prefix, 16).unwrap();
        kv.commit_prefix(adm.prefix, &p).unwrap();
        assert!(ds.iter().all(|d| kv.has_digest(*d)));
        assert_eq!(kv.advertised_digest_count(), 3);
        kv.check_invariants().unwrap();
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.advertised_digest_count(), 3, "retained digests stay");

        // Mid-prefill release: the half-streamed suffix never digests.
        let q = prompt(9000, 48);
        let adm2 = admit_chunked(&mut kv, &q, 16, 1).unwrap();
        kv.note_prefill(adm2.prefix, 20).unwrap();
        for b in adm2.branches {
            kv.release_branch(b).unwrap();
        }
        assert!(prompt_page_digests(&q, 16)
            .iter()
            .all(|d| !kv.has_digest(*d)));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_interned_prompts_count_digests_per_node() {
        // Two identical prompts streamed concurrently each intern their
        // own nodes (commit-time interning cannot share half-computed
        // pages); the digest multiset holds both copies, and the digest
        // stays advertised until the *last* copy is evicted.
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 1);
        let p = prompt(0, 16); // one page
        let d = prompt_page_digests(&p, 16)[0];
        let a = admit_chunked(&mut kv, &p, 16, 1).unwrap();
        let b = admit_chunked(&mut kv, &p, 16, 1).unwrap();
        kv.note_prefill(a.prefix, 16).unwrap();
        kv.commit_prefix(a.prefix, &p).unwrap();
        kv.note_prefill(b.prefix, 16).unwrap();
        kv.commit_prefix(b.prefix, &p).unwrap();
        assert_eq!(kv.advertised_digest_count(), 1, "one distinct digest");
        kv.check_invariants().unwrap();
        // Release both: budget 1 retains one copy, evicts the duplicate —
        // the digest must survive for the remaining node.
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        assert!(kv.has_digest(d));
        assert_eq!(kv.cached_pages(), 1);
        assert_eq!(kv.advertised_digest_count(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn advertisement_deltas_chain_from_full_snapshot() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 2);
        // The first take is always a Full snapshot — even of nothing.
        let Advertisement::Full { version: v0, digests } =
            kv.take_advertisement()
        else {
            panic!("first take must be Full");
        };
        assert_eq!(v0, 0);
        assert!(digests.is_empty());

        let p = prompt(0, 48); // 3 pages
        let ds = prompt_page_digests(&p, 16);
        let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        let Advertisement::Delta(d1) = kv.take_advertisement() else {
            panic!("second take must be a delta");
        };
        assert_eq!(d1.base_version, v0);
        assert_eq!(d1.version, 3, "one version bump per set transition");
        let mut expect = ds.clone();
        expect.sort_unstable();
        assert_eq!(d1.adds, expect);
        assert!(d1.retracts.is_empty());

        // Release trims the pool to the 2-page budget: the deepest
        // digest retracts, and the next delta chains off d1.
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        let Advertisement::Delta(d2) = kv.take_advertisement() else {
            panic!("third take must chain as a delta");
        };
        assert_eq!(d2.base_version, d1.version);
        assert!(d2.adds.is_empty());
        assert_eq!(d2.retracts, vec![ds[2]]);
        kv.check_invariants().unwrap();

        // A forced full snapshot re-bases delta state as well.
        let (v, full) = kv.full_advertisement();
        assert_eq!(v, d2.version);
        assert_eq!(full.len(), 2);
        let Advertisement::Delta(d3) = kv.take_advertisement() else {
            panic!("takes after a forced full still chain");
        };
        assert_eq!(d3.base_version, v);
        assert!(d3.adds.is_empty() && d3.retracts.is_empty());
    }

    #[test]
    fn advertisement_round_trips_cancel() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 1);
        kv.take_advertisement(); // arm delta mode
        let p = prompt(0, 32); // 2 pages against a 1-page budget
        let ds = prompt_page_digests(&p, 16);
        let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        // ds[1] interned then evicted inside one advert window: net
        // zero, so it appears in neither list — but both transitions
        // still advanced the version.
        let Advertisement::Delta(d) = kv.take_advertisement() else {
            panic!("delta expected");
        };
        assert_eq!(d.adds, vec![ds[0]]);
        assert!(d.retracts.is_empty());
        assert_eq!(d.version, 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn sub_page_prompts_stay_private() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 10); // below one page: nothing to intern
        let a = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(kv.used_pages(), 1 + 1);
        let b = admit_tokens(&mut kv, &p, 16, 1).unwrap();
        assert_eq!(b.cached_tokens, 0, "partial pages are never shared");
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        assert_eq!(kv.cached_pages(), 0);
        kv.check_invariants().unwrap();
    }

    // -----------------------------------------------------------------
    // Streamed admission: first-chunk pledges that grow with the stream.
    // -----------------------------------------------------------------

    #[test]
    fn streamed_admission_pledges_only_the_first_chunk() {
        let mut kv = KvCacheManager::with_prefix_cache(16 * 8, 16, 8);
        let p = prompt(0, 64); // 4 prompt pages, cold
        // Chunked would pledge 4 prompt pages up front; streamed with a
        // 16-token first chunk secures 1 prompt page + 1 branch page.
        let adm = kv
            .admit(&AdmissionRequest::streamed(&p, 16, 1, 16))
            .unwrap()
            .into_admission()
            .unwrap();
        assert_eq!(kv.used_pages(), 1); // branch reservation
        assert_eq!(kv.pledged_pages(), 1); // first chunk's page
        kv.check_invariants().unwrap();
        // The stream may not outrun its pledge...
        assert!(kv.note_prefill(adm.prefix, 32).is_err());
        kv.note_prefill(adm.prefix, 16).unwrap();
        assert_eq!((kv.used_pages(), kv.pledged_pages()), (2, 0));
        // ...and growing the pledge secures the next chunk's pages.
        assert!(kv.ensure_pledged(adm.prefix, 32).unwrap());
        assert_eq!(kv.pledged_pages(), 2);
        kv.note_prefill(adm.prefix, 32).unwrap();
        assert!(kv.ensure_pledged(adm.prefix, 16).unwrap());
        kv.note_prefill(adm.prefix, 16).unwrap();
        assert_eq!(kv.pledged_pages(), 0);
        kv.commit_prefix(adm.prefix, &p).unwrap();
        kv.check_invariants().unwrap();
        assert_eq!(kv.cached_prefix_tokens(&p), 64);
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn streamed_pledge_growth_stalls_without_free_pages() {
        // 6 pages. Stream a 4-page prompt (plus 1 branch page) next to a
        // 3-page resident: the first chunk fits, but the pledge cannot
        // grow past the budget until the resident releases.
        let mut kv = KvCacheManager::new(16 * 6, 16);
        let resident =
            admit_tokens(&mut kv, &prompt(1000, 32), 16, 1).unwrap();
        let p = prompt(0, 64);
        // Chunked (whole-suffix pledge) would need 5 of the 3 free pages.
        assert!(admit_chunked(&mut kv, &p, 16, 1).is_none());
        // Streamed needs 2 now: admitted.
        let adm = kv
            .admit(&AdmissionRequest::streamed(&p, 16, 1, 16))
            .unwrap()
            .into_admission()
            .unwrap();
        kv.note_prefill(adm.prefix, 16).unwrap();
        assert!(kv.ensure_pledged(adm.prefix, 16).unwrap());
        kv.note_prefill(adm.prefix, 16).unwrap();
        // All 6 pages spoken for (3 resident + 2 materialized + 1
        // branch): the next grow stalls, with no side effects.
        assert!(!kv.ensure_pledged(adm.prefix, 16).unwrap());
        kv.check_invariants().unwrap();
        // Freeing the resident unblocks the stream.
        for b in resident.branches {
            kv.release_branch(b).unwrap();
        }
        assert!(kv.ensure_pledged(adm.prefix, 32).unwrap());
        kv.note_prefill(adm.prefix, 32).unwrap();
        kv.commit_prefix(adm.prefix, &p).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn oversized_stream_is_deferred_not_deadlocked() {
        // An empty 4-page manager could admit the first chunk of a
        // 6-page prompt, but the stream could never finish: defer it
        // outright, reporting the full footprint as the need.
        let mut kv = KvCacheManager::new(16 * 4, 16);
        let p = prompt(0, 96);
        match kv.admit(&AdmissionRequest::streamed(&p, 16, 1, 16)).unwrap() {
            AdmissionOutcome::Deferred { need_pages, free_pages } => {
                assert_eq!((need_pages, free_pages), (7, 4));
            }
            AdmissionOutcome::Admitted(_) => panic!("stream cannot finish"),
        }
        assert_eq!(kv.used_pages(), 0);
        // A zero-length first chunk is a caller bug, not a deferral.
        assert!(kv.admit(&AdmissionRequest::streamed(&p, 16, 1, 0)).is_err());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn pressure_counts_used_and_pledged_pages() {
        let mut kv = KvCacheManager::new(16 * 10, 16);
        assert_eq!(kv.pressure(), 0.0);
        let adm = admit_chunked(&mut kv, &prompt(0, 48), 32, 1).unwrap();
        // 3 pledged prompt pages + 2 branch pages of 10.
        assert!((kv.pressure() - 0.5).abs() < 1e-12);
        kv.note_prefill(adm.prefix, 48).unwrap();
        assert!((kv.pressure() - 0.5).abs() < 1e-12);
        for b in adm.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.pressure(), 0.0);
    }

    // -----------------------------------------------------------------
    // Reward-driven eviction priority.
    // -----------------------------------------------------------------

    #[test]
    fn preemption_candidates_rank_lowest_reward_first() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (_, bs) = admit_len(&mut kv, 16, 64, 3).unwrap(); // 4 pages each
        assert_eq!(kv.preemptable_pages(), 0);
        assert!(kv.preemption_candidates(1).is_empty());
        kv.set_branch_priority(bs[0], 0.9).unwrap();
        kv.set_branch_priority(bs[1], 0.2).unwrap();
        kv.set_branch_priority(bs[2], 0.5).unwrap();
        assert_eq!(kv.preemptable_pages(), 12);
        assert!(kv.set_branch_priority(bs[0], f32::NAN).is_err());
        // One page of need: the single worst branch covers it.
        assert_eq!(kv.preemption_candidates(1), vec![bs[1]]);
        // Five pages: the worst two, in reward order.
        assert_eq!(kv.preemption_candidates(5), vec![bs[1], bs[2]]);
        // More than the pool holds: every candidate, still ranked.
        assert_eq!(kv.preemption_candidates(100), vec![bs[1], bs[2], bs[0]]);
        kv.check_invariants().unwrap();
        // Re-prioritizing reranks without double-counting the pool...
        kv.set_branch_priority(bs[1], 0.95).unwrap();
        assert_eq!(kv.preemption_candidates(1), vec![bs[2]]);
        assert_eq!(kv.preemptable_pages(), 12);
        // ...and releasing a prioritized branch shrinks it.
        kv.release_branch(bs[1]).unwrap();
        assert_eq!(kv.preemptable_pages(), 8);
        kv.check_invariants().unwrap();
        assert!(kv.set_branch_priority(bs[1], 0.1).is_err(), "stale handle");
    }

    #[test]
    fn invariants_rebuild_pledge_and_priority_structures() {
        // The audit recomputes the grown-pledge split and the
        // preemptable-page pool from the slabs: drift seeded into any of
        // the incremental counters must be caught.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 8, 16, 8);
        let p = prompt(0, 64);
        let adm = kv
            .admit(&AdmissionRequest::streamed(&p, 16, 1, 16))
            .unwrap()
            .into_admission()
            .unwrap();
        kv.set_branch_priority(adm.branches[0], 0.3).unwrap();
        kv.check_invariants().unwrap();

        kv.preemptable_pages += 1;
        assert!(kv.check_invariants().is_err(), "preemptable pool drift");
        kv.preemptable_pages -= 1;

        kv.pledged_pages += 1;
        assert!(kv.check_invariants().is_err(), "global pledge drift");
        kv.pledged_pages -= 1;

        // Pledge cursor drift inside the staged record: the per-prefix
        // secured/materialized split no longer matches the cursor.
        let pid = adm.prefix;
        kv.prefixes
            .get_mut(pid.idx, pid.gen)
            .unwrap()
            .staged
            .as_mut()
            .unwrap()
            .pledged_tokens += 16;
        assert!(kv.check_invariants().is_err(), "pledge cursor drift");
        kv.prefixes
            .get_mut(pid.idx, pid.gen)
            .unwrap()
            .staged
            .as_mut()
            .unwrap()
            .pledged_tokens -= 16;
        kv.check_invariants().unwrap();
    }
}
