//! Paged KV-cache manager with prefix sharing and refcounting.
//!
//! This is the memory-accounting substrate that turns branch
//! over-subscription into queuing delay — the second challenge the paper
//! studies. Physically the engine stores KV in fixed slots of a packed
//! device tensor; *logically* this manager accounts pages the way a
//! vLLM-style paged allocator would:
//!
//! * a request's prompt KV is a **shared prefix**: one set of pages,
//!   refcounted by its N branches (paper §4: "we share prefix KV cache
//!   across branches");
//! * each branch **reserves** its worst-case decode pages at admission
//!   (conservative Orca-style reservation — no mid-flight preemption, so
//!   a branch can always run to completion once admitted);
//! * pruning / early stopping / completion releases the branch pages
//!   immediately, and the prefix pages when the last sibling terminates —
//!   this is exactly the release path that lets SART batch more requests.
//!
//! Admission control asks `can_admit`; the scheduler combines this with
//! engine-slot availability.
//!
//! Storage is slab-style: prefixes and branches live in `Vec`s indexed by
//! their handle, with a free list for reuse and a per-slot generation
//! counter so stale handles (double release, use-after-release) are
//! rejected in O(1) instead of hashed lookups — the manager sits on the
//! admission/termination hot path of every scheduling round.

use anyhow::{bail, Result};

/// Handle for a request's shared prompt pages (generation-checked slab
/// index; stale handles are rejected by every operation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixId {
    idx: u32,
    gen: u32,
}

/// Handle for one branch's reserved decode pages (generation-checked).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BranchId {
    idx: u32,
    gen: u32,
}

#[derive(Debug)]
struct Prefix {
    pages: usize,
    refcount: usize,
}

#[derive(Debug)]
struct BranchAlloc {
    prefix: PrefixId,
    reserved_pages: usize,
    /// Tokens actually decoded so far (informational — the budget is
    /// charged at reservation time).
    grown_tokens: usize,
}

/// One slab slot: the generation is bumped on removal so outstanding
/// handles to the old occupant can never alias a reused slot.
#[derive(Debug)]
struct Slot<T> {
    gen: u32,
    val: Option<T>,
}

/// Minimal slab: Vec storage + free list + live count.
#[derive(Debug)]
struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Slab<T> {
    fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free: Vec::new(), len: 0 }
    }

    fn insert(&mut self, val: T) -> (u32, u32) {
        self.len += 1;
        if let Some(idx) = self.free.pop() {
            let s = &mut self.slots[idx as usize];
            debug_assert!(s.val.is_none());
            s.val = Some(val);
            (idx, s.gen)
        } else {
            self.slots.push(Slot { gen: 0, val: Some(val) });
            ((self.slots.len() - 1) as u32, 0)
        }
    }

    fn get(&self, idx: u32, gen: u32) -> Option<&T> {
        self.slots
            .get(idx as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.val.as_ref())
    }

    fn get_mut(&mut self, idx: u32, gen: u32) -> Option<&mut T> {
        self.slots
            .get_mut(idx as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.val.as_mut())
    }

    fn remove(&mut self, idx: u32, gen: u32) -> Option<T> {
        let s = self.slots.get_mut(idx as usize)?;
        if s.gen != gen {
            return None;
        }
        let v = s.val.take()?;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(idx);
        self.len -= 1;
        Some(v)
    }

    fn iter(&self) -> impl Iterator<Item = &T> {
        self.slots.iter().filter_map(|s| s.val.as_ref())
    }
}

/// Paged KV accounting with a hard page budget.
#[derive(Debug)]
pub struct KvCacheManager {
    page_tokens: usize,
    capacity_pages: usize,
    used_pages: usize,
    prefixes: Slab<Prefix>,
    branches: Slab<BranchAlloc>,
    /// Incrementally maintained Σ grown_tokens over live branches
    /// (Fig. 3's "running tokens"; previously recomputed by a full scan).
    live_decoded: usize,
    /// High-water mark, for metrics.
    peak_pages: usize,
}

fn pages_for(tokens: usize, page_tokens: usize) -> usize {
    tokens.div_ceil(page_tokens)
}

impl KvCacheManager {
    pub fn new(capacity_tokens: usize, page_tokens: usize) -> KvCacheManager {
        assert!(page_tokens > 0 && capacity_tokens >= page_tokens);
        KvCacheManager {
            page_tokens,
            capacity_pages: capacity_tokens / page_tokens,
            used_pages: 0,
            prefixes: Slab::new(),
            branches: Slab::new(),
            live_decoded: 0,
            peak_pages: 0,
        }
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn used_tokens_upper_bound(&self) -> usize {
        self.used_pages * self.page_tokens
    }

    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    pub fn free_pages(&self) -> usize {
        self.capacity_pages - self.used_pages
    }

    fn admission_pages(&self, prompt_len: usize, max_new: usize, n_branches: usize) -> usize {
        pages_for(prompt_len, self.page_tokens)
            + n_branches * pages_for(max_new, self.page_tokens)
    }

    /// Would admitting a request with `n_branches` branches fit the budget?
    pub fn can_admit(&self, prompt_len: usize, max_new: usize, n_branches: usize) -> bool {
        self.admission_pages(prompt_len, max_new, n_branches) <= self.free_pages()
    }

    /// Can `n_more` additional branches be attached to an existing prefix?
    pub fn can_grow(&self, max_new: usize, n_more: usize) -> bool {
        n_more * pages_for(max_new, self.page_tokens) <= self.free_pages()
    }

    /// Admit a request: allocate the shared prefix plus one reservation per
    /// branch. Fails (without side effects) if over budget.
    pub fn admit(
        &mut self,
        prompt_len: usize,
        max_new: usize,
        n_branches: usize,
    ) -> Result<(PrefixId, Vec<BranchId>)> {
        if !self.can_admit(prompt_len, max_new, n_branches) {
            bail!(
                "kv budget exceeded: need {} pages, {} free",
                self.admission_pages(prompt_len, max_new, n_branches),
                self.free_pages()
            );
        }
        let prefix_pages = pages_for(prompt_len, self.page_tokens);
        let branch_pages = pages_for(max_new, self.page_tokens);
        let (pidx, pgen) = self
            .prefixes
            .insert(Prefix { pages: prefix_pages, refcount: n_branches });
        let prefix = PrefixId { idx: pidx, gen: pgen };
        self.used_pages += prefix_pages;
        let mut branch_ids = Vec::with_capacity(n_branches);
        for _ in 0..n_branches {
            let (bidx, bgen) = self.branches.insert(BranchAlloc {
                prefix,
                reserved_pages: branch_pages,
                grown_tokens: 0,
            });
            self.used_pages += branch_pages;
            branch_ids.push(BranchId { idx: bidx, gen: bgen });
        }
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok((prefix, branch_ids))
    }

    /// Attach `n_more` branches to an existing shared prefix (Rebase tree
    /// expansion: a fork reuses the prompt pages and reserves fresh decode
    /// pages). Fails without side effects if over budget.
    pub fn grow(
        &mut self,
        prefix: PrefixId,
        max_new: usize,
        n_more: usize,
    ) -> Result<Vec<BranchId>> {
        if self.prefixes.get(prefix.idx, prefix.gen).is_none() {
            bail!("grow on unknown prefix {prefix:?}");
        }
        if !self.can_grow(max_new, n_more) {
            bail!(
                "kv budget exceeded on grow: need {} pages, {} free",
                n_more * pages_for(max_new, self.page_tokens),
                self.free_pages()
            );
        }
        let branch_pages = pages_for(max_new, self.page_tokens);
        let mut out = Vec::with_capacity(n_more);
        for _ in 0..n_more {
            let (bidx, bgen) = self.branches.insert(BranchAlloc {
                prefix,
                reserved_pages: branch_pages,
                grown_tokens: 0,
            });
            self.used_pages += branch_pages;
            out.push(BranchId { idx: bidx, gen: bgen });
        }
        self.prefixes
            .get_mut(prefix.idx, prefix.gen)
            .unwrap()
            .refcount += n_more;
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok(out)
    }

    /// Record decode progress (informational; reservation already charged).
    pub fn note_decode(&mut self, branch: BranchId, new_tokens: usize) -> Result<()> {
        match self.branches.get_mut(branch.idx, branch.gen) {
            Some(b) => {
                b.grown_tokens += new_tokens;
                self.live_decoded += new_tokens;
                Ok(())
            }
            None => bail!("note_decode on unknown branch {branch:?}"),
        }
    }

    /// Tokens actually decoded by live branches (Fig. 3's "running
    /// tokens"). O(1): maintained incrementally by `note_decode` /
    /// `release_branch` and cross-checked by `check_invariants`.
    pub fn live_decoded_tokens(&self) -> usize {
        self.live_decoded
    }

    /// Release a branch (pruned / early-stopped / completed). Frees its
    /// reservation immediately; frees the prefix when the last sibling
    /// terminates. Double release is an error (caught by the slab
    /// generation check, even after the slot has been reused).
    pub fn release_branch(&mut self, branch: BranchId) -> Result<()> {
        let Some(b) = self.branches.remove(branch.idx, branch.gen) else {
            bail!("double release of branch {branch:?}");
        };
        debug_assert!(self.used_pages >= b.reserved_pages);
        self.used_pages -= b.reserved_pages;
        debug_assert!(self.live_decoded >= b.grown_tokens);
        self.live_decoded -= b.grown_tokens;
        let prefix = self
            .prefixes
            .get_mut(b.prefix.idx, b.prefix.gen)
            .expect("branch with dangling prefix");
        prefix.refcount -= 1;
        if prefix.refcount == 0 {
            let p = self.prefixes.remove(b.prefix.idx, b.prefix.gen).unwrap();
            debug_assert!(self.used_pages >= p.pages);
            self.used_pages -= p.pages;
        }
        Ok(())
    }

    /// Number of live branches (for invariant checks).
    pub fn live_branches(&self) -> usize {
        self.branches.len
    }

    pub fn live_prefixes(&self) -> usize {
        self.prefixes.len
    }

    /// Internal invariant: used_pages equals the sum of all live
    /// allocations, and the incremental counters match a from-scratch
    /// recomputation. Exposed for property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let computed: usize = self.prefixes.iter().map(|p| p.pages).sum::<usize>()
            + self.branches.iter().map(|b| b.reserved_pages).sum::<usize>();
        if computed != self.used_pages {
            bail!("accounting drift: computed {computed} != used {}", self.used_pages);
        }
        if self.used_pages > self.capacity_pages {
            bail!("over budget: {} > {}", self.used_pages, self.capacity_pages);
        }
        let decoded: usize = self.branches.iter().map(|b| b.grown_tokens).sum();
        if decoded != self.live_decoded {
            bail!(
                "live_decoded drift: recomputed {decoded} != counter {}",
                self.live_decoded
            );
        }
        for b in self.branches.iter() {
            if self.prefixes.get(b.prefix.idx, b.prefix.gen).is_none() {
                bail!("branch references dead prefix");
            }
        }
        let refsum: usize = self.prefixes.iter().map(|p| p.refcount).sum();
        if refsum != self.branches.len {
            bail!("refcount drift: {} != {}", refsum, self.branches.len);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, branches) = kv.admit(30, 100, 4).unwrap();
        // prefix: ceil(30/16)=2, branch: ceil(100/16)=7 → 2 + 28 = 30.
        assert_eq!(kv.used_pages(), 30);
        kv.check_invariants().unwrap();
        for b in &branches[..3] {
            kv.release_branch(*b).unwrap();
        }
        // prefix still held by last branch.
        assert_eq!(kv.used_pages(), 2 + 7);
        assert_eq!(kv.live_prefixes(), 1);
        kv.release_branch(branches[3]).unwrap();
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.live_prefixes(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control_blocks() {
        let mut kv = KvCacheManager::new(160, 16); // 10 pages
        assert!(kv.can_admit(16, 32, 4)); // 1 + 4*2 = 9
        let (_, _b) = kv.admit(16, 32, 4).unwrap();
        assert!(!kv.can_admit(16, 32, 1)); // needs 3 more, only 1 free
        assert!(kv.admit(16, 32, 1).is_err());
        assert_eq!(kv.used_pages(), 9); // failed admit has no side effects
        kv.check_invariants().unwrap();
    }

    #[test]
    fn double_release_rejected() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, branches) = kv.admit(10, 10, 1).unwrap();
        kv.release_branch(branches[0]).unwrap();
        assert!(kv.release_branch(branches[0]).is_err());
    }

    #[test]
    fn stale_handles_rejected_after_slot_reuse() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (p1, b1) = kv.admit(16, 16, 1).unwrap();
        kv.release_branch(b1[0]).unwrap();
        // The next admit reuses the freed slab slots with a bumped
        // generation; the stale handles must still be rejected.
        let (p2, b2) = kv.admit(16, 16, 1).unwrap();
        assert!(kv.note_decode(b1[0], 4).is_err());
        assert!(kv.release_branch(b1[0]).is_err());
        assert!(kv.grow(p1, 16, 1).is_err());
        assert_ne!(p1, p2);
        assert_ne!(b1[0], b2[0]);
        kv.note_decode(b2[0], 4).unwrap();
        kv.release_branch(b2[0]).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn live_decoded_tokens_tracks_growth() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (_, bs) = kv.admit(27, 64, 2).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 0);
        kv.note_decode(bs[0], 10).unwrap();
        kv.note_decode(bs[1], 5).unwrap();
        kv.note_decode(bs[0], 3).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 18);
        kv.check_invariants().unwrap();
        kv.release_branch(bs[0]).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 5);
        kv.release_branch(bs[1]).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefix_sharing_saves_pages() {
        let mut shared = KvCacheManager::new(10_000, 16);
        shared.admit(64, 64, 8).unwrap(); // 4 + 8*4 = 36
        let mut unshared = KvCacheManager::new(10_000, 16);
        for _ in 0..8 {
            unshared.admit(64, 64, 1).unwrap(); // 8 * (4+4) = 64
        }
        assert!(shared.used_pages() < unshared.used_pages());
        assert_eq!(shared.used_pages(), 36);
        assert_eq!(unshared.used_pages(), 64);
    }

    #[test]
    fn peak_tracking() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, b) = kv.admit(16, 16, 2).unwrap();
        let peak = kv.used_pages();
        for bid in b {
            kv.release_branch(bid).unwrap();
        }
        assert_eq!(kv.peak_pages(), peak);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn page_rounding() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }
}
