//! Paged KV-cache manager with prefix sharing, refcounting and a
//! cross-request radix prefix cache.
//!
//! This is the memory-accounting substrate that turns branch
//! over-subscription into queuing delay — the second challenge the paper
//! studies. Physically the engine stores KV in fixed slots of a packed
//! device tensor; *logically* this manager accounts pages the way a
//! vLLM-style paged allocator would:
//!
//! * a request's prompt KV is a **shared prefix**: one set of pages,
//!   refcounted by its N branches (paper §4: "we share prefix KV cache
//!   across branches");
//! * each branch **reserves** its worst-case decode pages at admission
//!   (conservative Orca-style reservation — no mid-flight preemption, so
//!   a branch can always run to completion once admitted);
//! * pruning / early stopping / completion releases the branch pages
//!   immediately, and the prefix pages when the last sibling terminates —
//!   this is exactly the release path that lets SART batch more requests.
//!
//! # Cross-request radix prefix cache
//!
//! With a nonzero prefix-cache budget ([`KvCacheManager::with_prefix_cache`]),
//! prompt token sequences are additionally interned into a **page-granular
//! radix tree** (one node per full page of prompt tokens, SGLang-style):
//!
//! * [`KvCacheManager::admit_tokens`] walks the tree for the longest
//!   cached prefix and only charges pages for the *uncovered* suffix —
//!   two requests sharing a few-shot header pay for its pages once;
//! * every node carries a lease refcount (number of live prefixes whose
//!   interned path includes it). When the last lease drops, the node's
//!   page is **retained** instead of freed: it moves to an LRU-stamped
//!   pool bounded by the cache budget, ready to serve the next request
//!   with the same prefix;
//! * eviction only ever touches refcount-0 nodes, deepest/oldest first
//!   (junk tails age out before shared headers, whose stamps refresh on
//!   every release);
//! * [`KvCacheManager::check_invariants`] recomputes node refcounts and
//!   tree-page accounting from scratch each call, so audit-mode serves
//!   cross-check the incremental bookkeeping every round.
//!
//! A zero cache budget (the [`KvCacheManager::new`] default) disables the
//! tree entirely: `admit_tokens` delegates to the scalar [`admit`] path,
//! byte-for-byte reproducing the pre-cache accounting (property-tested).
//!
//! Admission control asks `can_admit`/`can_admit_tokens`; the scheduler
//! combines this with engine-slot availability.
//!
//! Storage is slab-style: prefixes and branches live in `Vec`s indexed by
//! their handle, with a free list for reuse and a per-slot generation
//! counter so stale handles (double release, use-after-release) are
//! rejected in O(1) instead of hashed lookups — the manager sits on the
//! admission/termination hot path of every scheduling round.
//!
//! [`admit`]: KvCacheManager::admit

use crate::tokenizer::Token;
use anyhow::{bail, Result};

/// Handle for a request's shared prompt pages (generation-checked slab
/// index; stale handles are rejected by every operation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixId {
    idx: u32,
    gen: u32,
}

/// Handle for one branch's reserved decode pages (generation-checked).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BranchId {
    idx: u32,
    gen: u32,
}

#[derive(Debug)]
struct Prefix {
    /// Total prompt pages (shared path + private remainder; diagnostics).
    pages: usize,
    /// Pages owned privately by this prefix (the partial tail page, or
    /// the whole prompt on the scalar/cache-disabled path).
    private_pages: usize,
    refcount: usize,
    /// Deepest radix node of the interned full-page path (None on the
    /// scalar path or when the prompt is shorter than one page).
    leaf: Option<u32>,
}

#[derive(Debug)]
struct BranchAlloc {
    prefix: PrefixId,
    reserved_pages: usize,
    /// Tokens actually decoded so far (informational — the budget is
    /// charged at reservation time).
    grown_tokens: usize,
}

/// One radix-tree node: exactly one page of prompt tokens (the edge label
/// from its parent). `refcount` counts live prefix leases through this
/// node; at 0 the page is retained (LRU-evictable) rather than freed.
#[derive(Debug)]
struct RadixNode {
    page: Vec<Token>,
    parent: Option<u32>,
    children: Vec<u32>,
    refcount: usize,
    /// LRU stamp assigned when `refcount` last dropped to 0 (valid only
    /// while retained).
    lru: u64,
}

/// One slab slot: the generation is bumped on removal so outstanding
/// handles to the old occupant can never alias a reused slot.
#[derive(Debug)]
struct Slot<T> {
    gen: u32,
    val: Option<T>,
}

/// Minimal slab: Vec storage + free list + live count.
#[derive(Debug)]
struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Slab<T> {
    fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free: Vec::new(), len: 0 }
    }

    fn insert(&mut self, val: T) -> (u32, u32) {
        self.len += 1;
        if let Some(idx) = self.free.pop() {
            let s = &mut self.slots[idx as usize];
            debug_assert!(s.val.is_none());
            s.val = Some(val);
            (idx, s.gen)
        } else {
            self.slots.push(Slot { gen: 0, val: Some(val) });
            ((self.slots.len() - 1) as u32, 0)
        }
    }

    fn get(&self, idx: u32, gen: u32) -> Option<&T> {
        self.slots
            .get(idx as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.val.as_ref())
    }

    fn get_mut(&mut self, idx: u32, gen: u32) -> Option<&mut T> {
        self.slots
            .get_mut(idx as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.val.as_mut())
    }

    fn remove(&mut self, idx: u32, gen: u32) -> Option<T> {
        let s = self.slots.get_mut(idx as usize)?;
        if s.gen != gen {
            return None;
        }
        let v = s.val.take()?;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(idx);
        self.len -= 1;
        Some(v)
    }

    fn iter(&self) -> impl Iterator<Item = &T> {
        self.slots.iter().filter_map(|s| s.val.as_ref())
    }
}

/// What [`KvCacheManager::admit_tokens`] hands back: the usual handles
/// plus how many prompt tokens the cross-request cache already covered
/// (a multiple of the page size; 0 on cold admits or with the cache
/// disabled). The engine's cost model charges only the uncovered suffix.
#[derive(Debug)]
pub struct Admission {
    pub prefix: PrefixId,
    pub branches: Vec<BranchId>,
    pub cached_tokens: usize,
}

/// Paged KV accounting with a hard page budget.
#[derive(Debug)]
pub struct KvCacheManager {
    page_tokens: usize,
    capacity_pages: usize,
    /// Pages held by live allocations: refcount>0 tree nodes (one page
    /// each, shared across all leases), private prefix remainders and
    /// branch reservations.
    used_pages: usize,
    prefixes: Slab<Prefix>,
    branches: Slab<BranchAlloc>,
    /// Incrementally maintained Σ grown_tokens over live branches
    /// (Fig. 3's "running tokens"; previously recomputed by a full scan).
    live_decoded: usize,
    /// High-water mark of `used_pages`, for metrics.
    peak_pages: usize,
    /// Retention budget for refcount-0 radix pages; 0 disables the
    /// cross-request cache entirely (scalar accounting, pre-cache
    /// semantics).
    prefix_cache_pages: usize,
    /// Radix node storage (free-listed; `None` slots are reusable).
    nodes: Vec<Option<RadixNode>>,
    free_nodes: Vec<u32>,
    /// First-page nodes (the radix tree's root edge set).
    roots: Vec<u32>,
    /// Resident refcount-0 pages (≤ `prefix_cache_pages`; all evictable).
    cached_pages: usize,
    lru_clock: u64,
    /// Σ cached_tokens over all `admit_tokens` calls (metrics).
    hit_tokens_total: usize,
    /// Pages evicted from the retained pool (metrics).
    evicted_pages_total: usize,
}

fn pages_for(tokens: usize, page_tokens: usize) -> usize {
    tokens.div_ceil(page_tokens)
}

impl KvCacheManager {
    /// Manager with the cross-request prefix cache disabled (pre-cache
    /// accounting, byte-for-byte).
    pub fn new(capacity_tokens: usize, page_tokens: usize) -> KvCacheManager {
        Self::with_prefix_cache(capacity_tokens, page_tokens, 0)
    }

    /// Manager with up to `prefix_cache_pages` refcount-0 prompt pages
    /// retained for cross-request reuse (0 disables the cache).
    pub fn with_prefix_cache(
        capacity_tokens: usize,
        page_tokens: usize,
        prefix_cache_pages: usize,
    ) -> KvCacheManager {
        assert!(page_tokens > 0 && capacity_tokens >= page_tokens);
        KvCacheManager {
            page_tokens,
            capacity_pages: capacity_tokens / page_tokens,
            used_pages: 0,
            prefixes: Slab::new(),
            branches: Slab::new(),
            live_decoded: 0,
            peak_pages: 0,
            prefix_cache_pages,
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: Vec::new(),
            cached_pages: 0,
            lru_clock: 0,
            hit_tokens_total: 0,
            evicted_pages_total: 0,
        }
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn used_tokens_upper_bound(&self) -> usize {
        self.used_pages * self.page_tokens
    }

    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Pages available to live allocations. Retained (refcount-0) cache
    /// pages do not subtract: they are evicted on demand by admissions.
    pub fn free_pages(&self) -> usize {
        self.capacity_pages - self.used_pages
    }

    /// Retained refcount-0 prefix pages currently resident.
    pub fn cached_pages(&self) -> usize {
        self.cached_pages
    }

    /// Retention budget for refcount-0 prefix pages (0 = cache disabled).
    pub fn prefix_cache_capacity(&self) -> usize {
        self.prefix_cache_pages
    }

    /// Σ prompt tokens served from the cache across all admissions.
    pub fn cache_hit_tokens_total(&self) -> usize {
        self.hit_tokens_total
    }

    /// Pages evicted from the retained pool since construction.
    pub fn evicted_pages_total(&self) -> usize {
        self.evicted_pages_total
    }

    fn admission_pages(&self, prompt_len: usize, max_new: usize, n_branches: usize) -> usize {
        pages_for(prompt_len, self.page_tokens)
            + n_branches * pages_for(max_new, self.page_tokens)
    }

    /// Would admitting a request with `n_branches` branches fit the
    /// budget? Scalar form: ignores the prefix cache (a cache hit can
    /// only need fewer pages, so `true` here is conservative-safe).
    pub fn can_admit(&self, prompt_len: usize, max_new: usize, n_branches: usize) -> bool {
        self.admission_pages(prompt_len, max_new, n_branches) <= self.free_pages()
    }

    /// Can `n_more` additional branches be attached to an existing prefix?
    pub fn can_grow(&self, max_new: usize, n_more: usize) -> bool {
        n_more * pages_for(max_new, self.page_tokens) <= self.free_pages()
    }

    /// Walk the radix tree for the longest interned full-page prefix of
    /// `prompt`. Returns the matched node path, root-first.
    fn walk_path(&self, prompt: &[Token]) -> Vec<u32> {
        let mut path = Vec::new();
        if self.prefix_cache_pages == 0 {
            return path;
        }
        let pt = self.page_tokens;
        let full = prompt.len() / pt;
        let mut children: &[u32] = &self.roots;
        for i in 0..full {
            let page = &prompt[i * pt..(i + 1) * pt];
            let mut found = None;
            for &c in children {
                if self.nodes[c as usize]
                    .as_ref()
                    .is_some_and(|n| n.page.as_slice() == page)
                {
                    found = Some(c);
                    break;
                }
            }
            match found {
                Some(c) => {
                    path.push(c);
                    children = &self.nodes[c as usize].as_ref().unwrap().children;
                }
                None => break,
            }
        }
        path
    }

    /// Tokens of `prompt` resident in the radix cache right now (longest
    /// interned full-page prefix, live or retained). Read-only — the
    /// cluster's prefix-affinity policy probes replicas with this.
    pub fn cached_prefix_tokens(&self, prompt: &[Token]) -> usize {
        self.walk_path(prompt).len() * self.page_tokens
    }

    /// One tree walk's worth of admission arithmetic: the matched path,
    /// the pages the admission must newly allocate, and the retained
    /// (refcount-0) pages it would re-lease. Single source of the budget
    /// formula for `can_admit_tokens` and `try_admit_tokens`.
    fn admission_need_tokens(
        &self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
    ) -> (Vec<u32>, usize, usize) {
        let pt = self.page_tokens;
        let full = prompt.len() / pt;
        let tail_pages = usize::from(prompt.len() % pt > 0);
        let path = self.walk_path(prompt);
        let hit_retained = path
            .iter()
            .filter(|&&c| self.nodes[c as usize].as_ref().unwrap().refcount == 0)
            .count();
        let need = (full - path.len())
            + tail_pages
            + n_branches * pages_for(max_new, pt);
        (path, need, hit_retained)
    }

    /// Token-level admission check: charges only the prompt suffix not
    /// covered by the radix cache. Retained pages the admission would
    /// re-lease stop being evictable, so they count against the headroom.
    /// (Callers that will admit on success should prefer
    /// [`KvCacheManager::try_admit_tokens`], which shares one tree walk
    /// between the check and the admission.)
    pub fn can_admit_tokens(
        &self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
    ) -> bool {
        if self.prefix_cache_pages == 0 {
            return self.can_admit(prompt.len(), max_new, n_branches);
        }
        let (_, need, hit_retained) =
            self.admission_need_tokens(prompt, max_new, n_branches);
        need + hit_retained <= self.free_pages()
    }

    /// Evict the least-recently-retained refcount-0 node with no
    /// children (leaves first; ancestors become evictable as their
    /// subtrees drain — refcounts are monotone down the tree, so a
    /// refcount-0 subtree always contains a childless refcount-0 node).
    ///
    /// Linear scan by design: the node slab is bounded by the live
    /// prompt pages plus the (budgeted) retained pool, both small next
    /// to a serve's page traffic; an intrusive LRU list would only pay
    /// off once retained pools reach thousands of pages.
    fn evict_lru(&mut self) -> Result<()> {
        let mut best: Option<(u64, u32)> = None;
        for (i, slot) in self.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.refcount == 0 && n.children.is_empty() {
                    let key = (n.lru, i as u32);
                    match best {
                        Some(b) if key >= b => {}
                        _ => best = Some(key),
                    }
                }
            }
        }
        let Some((_, idx)) = best else {
            bail!("prefix cache eviction found no refcount-0 leaf");
        };
        let node = self.nodes[idx as usize].take().unwrap();
        debug_assert!(node.refcount == 0 && node.children.is_empty());
        match node.parent {
            Some(p) => self.nodes[p as usize]
                .as_mut()
                .unwrap()
                .children
                .retain(|&c| c != idx),
            None => self.roots.retain(|&c| c != idx),
        }
        self.free_nodes.push(idx);
        self.cached_pages -= 1;
        self.evicted_pages_total += 1;
        Ok(())
    }

    /// Evict retained pages until `fresh` new pages fit physically.
    /// No-op when the cache is disabled (cached_pages is always 0 then).
    fn make_room(&mut self, fresh: usize) -> Result<()> {
        while self.capacity_pages - self.used_pages - self.cached_pages < fresh
        {
            self.evict_lru()?;
        }
        Ok(())
    }

    fn alloc_node(&mut self, node: RadixNode) -> u32 {
        match self.free_nodes.pop() {
            Some(idx) => {
                debug_assert!(self.nodes[idx as usize].is_none());
                self.nodes[idx as usize] = Some(node);
                idx
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as u32
            }
        }
    }

    /// Admit a request (scalar form): allocate the whole prompt privately
    /// plus one reservation per branch. Never consults the radix cache —
    /// this is the pre-cache accounting, kept for the Rebase baseline and
    /// as the delegation target when the cache is disabled. Fails
    /// (without side effects) if over budget.
    pub fn admit(
        &mut self,
        prompt_len: usize,
        max_new: usize,
        n_branches: usize,
    ) -> Result<(PrefixId, Vec<BranchId>)> {
        if !self.can_admit(prompt_len, max_new, n_branches) {
            bail!(
                "kv budget exceeded: need {} pages, {} free",
                self.admission_pages(prompt_len, max_new, n_branches),
                self.free_pages()
            );
        }
        let prefix_pages = pages_for(prompt_len, self.page_tokens);
        let branch_pages = pages_for(max_new, self.page_tokens);
        self.make_room(prefix_pages + n_branches * branch_pages)?;
        let (pidx, pgen) = self.prefixes.insert(Prefix {
            pages: prefix_pages,
            private_pages: prefix_pages,
            refcount: n_branches,
            leaf: None,
        });
        let prefix = PrefixId { idx: pidx, gen: pgen };
        self.used_pages += prefix_pages;
        let mut branch_ids = Vec::with_capacity(n_branches);
        for _ in 0..n_branches {
            let (bidx, bgen) = self.branches.insert(BranchAlloc {
                prefix,
                reserved_pages: branch_pages,
                grown_tokens: 0,
            });
            self.used_pages += branch_pages;
            branch_ids.push(BranchId { idx: bidx, gen: bgen });
        }
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok((prefix, branch_ids))
    }

    /// Admit a request by prompt *tokens*: intern the prompt's full pages
    /// into the radix tree, lease the longest cached prefix for free, and
    /// only charge pages for the uncovered suffix (plus the private tail
    /// page and the per-branch reservations). With the cache disabled
    /// this delegates to the scalar [`KvCacheManager::admit`] and is
    /// byte-identical to it. Fails without side effects if over budget.
    pub fn admit_tokens(
        &mut self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
    ) -> Result<Admission> {
        match self.try_admit_tokens(prompt, max_new, n_branches)? {
            Some(admission) => Ok(admission),
            None => bail!(
                "kv budget exceeded admitting a {}-token prompt with \
                 {n_branches} branches ({} pages free)",
                prompt.len(),
                self.free_pages()
            ),
        }
    }

    /// [`KvCacheManager::admit_tokens`] with "over budget" as a
    /// side-effect-free `Ok(None)` instead of an error, and one tree walk
    /// shared between the budget check and the admission — the
    /// scheduler's head-of-line gate calls this directly on the hot path.
    pub fn try_admit_tokens(
        &mut self,
        prompt: &[Token],
        max_new: usize,
        n_branches: usize,
    ) -> Result<Option<Admission>> {
        if self.prefix_cache_pages == 0 {
            if !self.can_admit(prompt.len(), max_new, n_branches) {
                return Ok(None);
            }
            let (prefix, branches) =
                self.admit(prompt.len(), max_new, n_branches)?;
            return Ok(Some(Admission { prefix, branches, cached_tokens: 0 }));
        }
        let (path, need, hit_retained) =
            self.admission_need_tokens(prompt, max_new, n_branches);
        if need + hit_retained > self.free_pages() {
            return Ok(None);
        }
        let pt = self.page_tokens;
        let full = prompt.len() / pt;
        let tail_pages = usize::from(prompt.len() % pt > 0);
        let branch_pages = pages_for(max_new, pt);

        // 1. Lease the already-interned path. Bumping refcounts first
        //    protects the hit nodes from the eviction pass below; nodes
        //    leaving the retained pool move from cached to used.
        for &c in &path {
            let was_retained = {
                let node = self.nodes[c as usize].as_mut().unwrap();
                node.refcount += 1;
                node.refcount == 1
            };
            if was_retained {
                self.cached_pages -= 1;
                self.used_pages += 1;
            }
        }

        // 2. Make physical room for the genuinely new pages.
        self.make_room(need)?;

        // 3. Intern the uncovered full pages (one node per page).
        let mut leaf = path.last().copied();
        for i in path.len()..full {
            let page = prompt[i * pt..(i + 1) * pt].to_vec();
            let idx = self.alloc_node(RadixNode {
                page,
                parent: leaf,
                children: Vec::new(),
                refcount: 1,
                lru: 0,
            });
            match leaf {
                Some(p) => {
                    self.nodes[p as usize].as_mut().unwrap().children.push(idx)
                }
                None => self.roots.push(idx),
            }
            self.used_pages += 1;
            leaf = Some(idx);
        }

        // 4. Private tail page, prefix record, branch reservations.
        self.used_pages += tail_pages;
        let (pidx, pgen) = self.prefixes.insert(Prefix {
            pages: pages_for(prompt.len(), pt),
            private_pages: tail_pages,
            refcount: n_branches,
            leaf,
        });
        let prefix = PrefixId { idx: pidx, gen: pgen };
        let mut branch_ids = Vec::with_capacity(n_branches);
        for _ in 0..n_branches {
            let (bidx, bgen) = self.branches.insert(BranchAlloc {
                prefix,
                reserved_pages: branch_pages,
                grown_tokens: 0,
            });
            self.used_pages += branch_pages;
            branch_ids.push(BranchId { idx: bidx, gen: bgen });
        }
        self.peak_pages = self.peak_pages.max(self.used_pages);
        let cached_tokens = path.len() * pt;
        self.hit_tokens_total += cached_tokens;
        Ok(Some(Admission { prefix, branches: branch_ids, cached_tokens }))
    }

    /// Attach `n_more` branches to an existing shared prefix (Rebase tree
    /// expansion: a fork reuses the prompt pages and reserves fresh decode
    /// pages). Fails without side effects if over budget.
    pub fn grow(
        &mut self,
        prefix: PrefixId,
        max_new: usize,
        n_more: usize,
    ) -> Result<Vec<BranchId>> {
        if self.prefixes.get(prefix.idx, prefix.gen).is_none() {
            bail!("grow on unknown prefix {prefix:?}");
        }
        if !self.can_grow(max_new, n_more) {
            bail!(
                "kv budget exceeded on grow: need {} pages, {} free",
                n_more * pages_for(max_new, self.page_tokens),
                self.free_pages()
            );
        }
        let branch_pages = pages_for(max_new, self.page_tokens);
        self.make_room(n_more * branch_pages)?;
        let mut out = Vec::with_capacity(n_more);
        for _ in 0..n_more {
            let (bidx, bgen) = self.branches.insert(BranchAlloc {
                prefix,
                reserved_pages: branch_pages,
                grown_tokens: 0,
            });
            self.used_pages += branch_pages;
            out.push(BranchId { idx: bidx, gen: bgen });
        }
        self.prefixes
            .get_mut(prefix.idx, prefix.gen)
            .unwrap()
            .refcount += n_more;
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok(out)
    }

    /// Record decode progress (informational; reservation already charged).
    pub fn note_decode(&mut self, branch: BranchId, new_tokens: usize) -> Result<()> {
        match self.branches.get_mut(branch.idx, branch.gen) {
            Some(b) => {
                b.grown_tokens += new_tokens;
                self.live_decoded += new_tokens;
                Ok(())
            }
            None => bail!("note_decode on unknown branch {branch:?}"),
        }
    }

    /// Tokens actually decoded by live branches (Fig. 3's "running
    /// tokens"). O(1): maintained incrementally by `note_decode` /
    /// `release_branch` and cross-checked by `check_invariants`.
    pub fn live_decoded_tokens(&self) -> usize {
        self.live_decoded
    }

    /// Drop one lease along `leaf`→root. Nodes reaching refcount 0 move
    /// to the retained pool (deepest stamped oldest, so request-unique
    /// tails evict before shared headers), then the pool is trimmed to
    /// the cache budget.
    fn release_lease(&mut self, leaf: u32) -> Result<()> {
        let mut cur = Some(leaf);
        while let Some(idx) = cur {
            let (parent, now_zero) = {
                let Some(node) =
                    self.nodes.get_mut(idx as usize).and_then(|s| s.as_mut())
                else {
                    bail!("lease release hit dead radix node {idx}");
                };
                if node.refcount == 0 {
                    bail!("radix lease refcount underflow at node {idx}");
                }
                node.refcount -= 1;
                (node.parent, node.refcount == 0)
            };
            if now_zero {
                self.lru_clock += 1;
                let stamp = self.lru_clock;
                self.nodes[idx as usize].as_mut().unwrap().lru = stamp;
                debug_assert!(self.used_pages >= 1);
                self.used_pages -= 1;
                self.cached_pages += 1;
            }
            cur = parent;
        }
        while self.cached_pages > self.prefix_cache_pages {
            self.evict_lru()?;
        }
        Ok(())
    }

    /// Release a branch (pruned / early-stopped / completed). Frees its
    /// reservation immediately; releases the prefix when the last sibling
    /// terminates — private pages are freed, interned pages drop their
    /// lease and are retained for cross-request reuse. Double release is
    /// an error (caught by the slab generation check, even after the slot
    /// has been reused).
    pub fn release_branch(&mut self, branch: BranchId) -> Result<()> {
        let Some(b) = self.branches.remove(branch.idx, branch.gen) else {
            bail!("double release of branch {branch:?}");
        };
        debug_assert!(self.used_pages >= b.reserved_pages);
        self.used_pages -= b.reserved_pages;
        debug_assert!(self.live_decoded >= b.grown_tokens);
        self.live_decoded -= b.grown_tokens;
        let prefix = self
            .prefixes
            .get_mut(b.prefix.idx, b.prefix.gen)
            .expect("branch with dangling prefix");
        prefix.refcount -= 1;
        if prefix.refcount == 0 {
            let p = self.prefixes.remove(b.prefix.idx, b.prefix.gen).unwrap();
            debug_assert!(self.used_pages >= p.private_pages);
            self.used_pages -= p.private_pages;
            if let Some(leaf) = p.leaf {
                self.release_lease(leaf)?;
            }
        }
        Ok(())
    }

    /// Number of live branches (for invariant checks).
    pub fn live_branches(&self) -> usize {
        self.branches.len
    }

    pub fn live_prefixes(&self) -> usize {
        self.prefixes.len
    }

    /// Internal invariant: used_pages equals the sum of all live
    /// allocations, the incremental counters match a from-scratch
    /// recomputation, and the radix tree's refcounts / page accounting
    /// rebuild exactly from the live prefix set. Exposed for property
    /// tests and audit-mode serves.
    pub fn check_invariants(&self) -> Result<()> {
        // Rebuild per-node lease counts from the live prefixes.
        let mut expected = vec![0usize; self.nodes.len()];
        for p in self.prefixes.iter() {
            let mut cur = p.leaf;
            let mut steps = 0usize;
            while let Some(idx) = cur {
                let Some(node) =
                    self.nodes.get(idx as usize).and_then(|s| s.as_ref())
                else {
                    bail!("prefix leaf chain hits dead radix node {idx}");
                };
                expected[idx as usize] += 1;
                cur = node.parent;
                steps += 1;
                if steps > self.nodes.len() {
                    bail!("parent cycle in radix tree");
                }
            }
            // Total prompt pages split exactly into interned path +
            // private remainder.
            if p.pages != p.private_pages + steps {
                bail!(
                    "prefix page split drift: {} != {} private + {steps} \
                     interned",
                    p.pages,
                    p.private_pages
                );
            }
        }
        let mut live_tree_pages = 0usize;
        let mut retained_pages = 0usize;
        let mut linked_children = 0usize;
        for (i, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.refcount != expected[i] {
                bail!(
                    "radix refcount drift at node {i}: {} != recomputed {}",
                    n.refcount,
                    expected[i]
                );
            }
            if n.page.len() != self.page_tokens {
                bail!("radix node {i} is not page-sized");
            }
            if n.refcount > 0 {
                live_tree_pages += 1;
            } else {
                retained_pages += 1;
            }
            linked_children += n.children.len();
            for &c in &n.children {
                let Some(ch) =
                    self.nodes.get(c as usize).and_then(|s| s.as_ref())
                else {
                    bail!("radix node {i} has dangling child {c}");
                };
                if ch.parent != Some(i as u32) {
                    bail!("radix parent pointer mismatch at child {c}");
                }
            }
        }
        for &r in &self.roots {
            let Some(n) = self.nodes.get(r as usize).and_then(|s| s.as_ref())
            else {
                bail!("dangling radix root {r}");
            };
            if n.parent.is_some() {
                bail!("radix root {r} has a parent");
            }
        }
        let total_nodes =
            self.nodes.iter().filter(|s| s.is_some()).count();
        if linked_children + self.roots.len() != total_nodes {
            bail!(
                "radix link count drift: {} children + {} roots != {} nodes",
                linked_children,
                self.roots.len(),
                total_nodes
            );
        }
        if retained_pages != self.cached_pages {
            bail!(
                "cached_pages drift: counter {} != recomputed {retained_pages}",
                self.cached_pages
            );
        }
        if self.cached_pages > self.prefix_cache_pages {
            bail!(
                "retained pages over cache budget: {} > {}",
                self.cached_pages,
                self.prefix_cache_pages
            );
        }
        let computed: usize = live_tree_pages
            + self.prefixes.iter().map(|p| p.private_pages).sum::<usize>()
            + self.branches.iter().map(|b| b.reserved_pages).sum::<usize>();
        if computed != self.used_pages {
            bail!("accounting drift: computed {computed} != used {}", self.used_pages);
        }
        if self.used_pages + self.cached_pages > self.capacity_pages {
            bail!(
                "over budget: {} used + {} cached > {}",
                self.used_pages,
                self.cached_pages,
                self.capacity_pages
            );
        }
        let decoded: usize = self.branches.iter().map(|b| b.grown_tokens).sum();
        if decoded != self.live_decoded {
            bail!(
                "live_decoded drift: recomputed {decoded} != counter {}",
                self.live_decoded
            );
        }
        for b in self.branches.iter() {
            if self.prefixes.get(b.prefix.idx, b.prefix.gen).is_none() {
                bail!("branch references dead prefix");
            }
        }
        let refsum: usize = self.prefixes.iter().map(|p| p.refcount).sum();
        if refsum != self.branches.len {
            bail!("refcount drift: {} != {}", refsum, self.branches.len);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A page-aligned synthetic prompt: `base..base+len` as tokens.
    fn prompt(base: i32, len: usize) -> Vec<Token> {
        (base..base + len as i32).collect()
    }

    #[test]
    fn admit_and_release_roundtrip() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, branches) = kv.admit(30, 100, 4).unwrap();
        // prefix: ceil(30/16)=2, branch: ceil(100/16)=7 → 2 + 28 = 30.
        assert_eq!(kv.used_pages(), 30);
        kv.check_invariants().unwrap();
        for b in &branches[..3] {
            kv.release_branch(*b).unwrap();
        }
        // prefix still held by last branch.
        assert_eq!(kv.used_pages(), 2 + 7);
        assert_eq!(kv.live_prefixes(), 1);
        kv.release_branch(branches[3]).unwrap();
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.live_prefixes(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control_blocks() {
        let mut kv = KvCacheManager::new(160, 16); // 10 pages
        assert!(kv.can_admit(16, 32, 4)); // 1 + 4*2 = 9
        let (_, _b) = kv.admit(16, 32, 4).unwrap();
        assert!(!kv.can_admit(16, 32, 1)); // needs 3 more, only 1 free
        assert!(kv.admit(16, 32, 1).is_err());
        assert_eq!(kv.used_pages(), 9); // failed admit has no side effects
        kv.check_invariants().unwrap();
    }

    #[test]
    fn double_release_rejected() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, branches) = kv.admit(10, 10, 1).unwrap();
        kv.release_branch(branches[0]).unwrap();
        assert!(kv.release_branch(branches[0]).is_err());
    }

    #[test]
    fn stale_handles_rejected_after_slot_reuse() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (p1, b1) = kv.admit(16, 16, 1).unwrap();
        kv.release_branch(b1[0]).unwrap();
        // The next admit reuses the freed slab slots with a bumped
        // generation; the stale handles must still be rejected.
        let (p2, b2) = kv.admit(16, 16, 1).unwrap();
        assert!(kv.note_decode(b1[0], 4).is_err());
        assert!(kv.release_branch(b1[0]).is_err());
        assert!(kv.grow(p1, 16, 1).is_err());
        assert_ne!(p1, p2);
        assert_ne!(b1[0], b2[0]);
        kv.note_decode(b2[0], 4).unwrap();
        kv.release_branch(b2[0]).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn live_decoded_tokens_tracks_growth() {
        let mut kv = KvCacheManager::new(4096, 16);
        let (_, bs) = kv.admit(27, 64, 2).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 0);
        kv.note_decode(bs[0], 10).unwrap();
        kv.note_decode(bs[1], 5).unwrap();
        kv.note_decode(bs[0], 3).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 18);
        kv.check_invariants().unwrap();
        kv.release_branch(bs[0]).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 5);
        kv.release_branch(bs[1]).unwrap();
        assert_eq!(kv.live_decoded_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefix_sharing_saves_pages() {
        let mut shared = KvCacheManager::new(10_000, 16);
        shared.admit(64, 64, 8).unwrap(); // 4 + 8*4 = 36
        let mut unshared = KvCacheManager::new(10_000, 16);
        for _ in 0..8 {
            unshared.admit(64, 64, 1).unwrap(); // 8 * (4+4) = 64
        }
        assert!(shared.used_pages() < unshared.used_pages());
        assert_eq!(shared.used_pages(), 36);
        assert_eq!(unshared.used_pages(), 64);
    }

    #[test]
    fn peak_tracking() {
        let mut kv = KvCacheManager::new(1024, 16);
        let (_, b) = kv.admit(16, 16, 2).unwrap();
        let peak = kv.used_pages();
        for bid in b {
            kv.release_branch(bid).unwrap();
        }
        assert_eq!(kv.peak_pages(), peak);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn page_rounding() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }

    // -----------------------------------------------------------------
    // Cross-request radix prefix cache.
    // -----------------------------------------------------------------

    #[test]
    fn disabled_cache_matches_scalar_admit_exactly() {
        // admit_tokens with a zero cache budget must mirror the scalar
        // path page for page (the pre-cache accounting).
        let mut scalar = KvCacheManager::new(4096, 16);
        let mut tokens = KvCacheManager::new(4096, 16);
        let p = prompt(100, 30);
        let (_, bs1) = scalar.admit(p.len(), 100, 4).unwrap();
        let adm = tokens.admit_tokens(&p, 100, 4).unwrap();
        assert_eq!(adm.cached_tokens, 0);
        assert_eq!(scalar.used_pages(), tokens.used_pages());
        assert_eq!(tokens.cached_pages(), 0);
        // Second identical prompt: still no sharing with the cache off.
        let before = tokens.used_pages();
        let adm2 = tokens.admit_tokens(&p, 100, 4).unwrap();
        assert_eq!(adm2.cached_tokens, 0);
        assert_eq!(tokens.used_pages(), 2 * before);
        for b in bs1 {
            scalar.release_branch(b).unwrap();
        }
        for b in adm.branches.into_iter().chain(adm2.branches) {
            tokens.release_branch(b).unwrap();
        }
        assert_eq!(tokens.used_pages(), 0);
        assert_eq!(tokens.cached_pages(), 0);
        tokens.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_identical_prompts_share_interned_pages() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 48); // 3 full pages
        let a = kv.admit_tokens(&p, 32, 2).unwrap();
        assert_eq!(a.cached_tokens, 0); // cold
        // 3 tree pages + 2 branches × 2 pages.
        assert_eq!(kv.used_pages(), 3 + 4);
        let b = kv.admit_tokens(&p, 32, 2).unwrap();
        assert_eq!(b.cached_tokens, 48); // full-page hit while live
        // Only the new branch reservations are charged.
        assert_eq!(kv.used_pages(), 3 + 4 + 4);
        kv.check_invariants().unwrap();
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        // Interned pages are retained, not freed.
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.cached_pages(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retained_prefix_serves_later_request() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 40); // 2 full pages + 8-token tail
        let a = kv.admit_tokens(&p, 32, 1).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(kv.used_pages(), 2 + 1 + 2); // tree + tail + branch
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.used_pages(), 0);
        assert_eq!(kv.cached_pages(), 2);
        assert_eq!(kv.cached_prefix_tokens(&p), 32);
        // Re-admit: the 2 full pages come from the cache.
        let b = kv.admit_tokens(&p, 32, 1).unwrap();
        assert_eq!(b.cached_tokens, 32);
        assert_eq!(kv.used_pages(), 2 + 1 + 2);
        assert_eq!(kv.cached_pages(), 0);
        assert_eq!(kv.cache_hit_tokens_total(), 32);
        kv.check_invariants().unwrap();
        for br in b.branches {
            kv.release_branch(br).unwrap();
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn shared_header_divergent_tails_split_in_tree() {
        // Two prompts sharing 2 pages then diverging: the second admit
        // hits exactly the shared pages.
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let mut p1 = prompt(0, 32);
        p1.extend(prompt(500, 16));
        let mut p2 = prompt(0, 32);
        p2.extend(prompt(900, 16));
        let a = kv.admit_tokens(&p1, 16, 1).unwrap();
        let b = kv.admit_tokens(&p2, 16, 1).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(b.cached_tokens, 32);
        // 2 shared + 2 divergent tree pages + 2 branch pages.
        assert_eq!(kv.used_pages(), 2 + 1 + 1 + 1 + 1);
        kv.check_invariants().unwrap();
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        assert_eq!(kv.cached_pages(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn cache_budget_trims_lru_leaves_first() {
        // Budget of 2 retained pages; a released 4-page prefix keeps only
        // its 2 shallowest pages (deepest stamped oldest → evicted first).
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 2);
        let p = prompt(0, 64);
        let a = kv.admit_tokens(&p, 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 2);
        assert_eq!(kv.evicted_pages_total(), 2);
        // The survivors are the root-most pages: a 2-page prefix of the
        // same prompt still hits, the full prompt only partially.
        assert_eq!(kv.cached_prefix_tokens(&p), 32);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_never_touches_live_prefixes() {
        // A live request's interned pages must survive arbitrary cache
        // pressure; only refcount-0 pages are evictable.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 24, 16, 4);
        let live_prompt = prompt(0, 48); // 3 tree pages
        let live = kv.admit_tokens(&live_prompt, 16, 1).unwrap(); // +1 branch page
        // Fill and churn the retained pool with released one-page prompts.
        for i in 0..6 {
            let p = prompt(1000 + 100 * i, 16);
            let a = kv.admit_tokens(&p, 16, 1).unwrap();
            for b in a.branches {
                kv.release_branch(b).unwrap();
            }
            kv.check_invariants().unwrap();
        }
        assert!(kv.evicted_pages_total() > 0, "churn must evict");
        assert_eq!(
            kv.cached_prefix_tokens(&live_prompt),
            48,
            "live prefix evicted from the radix tree"
        );
        // Oldest retained one-pagers were evicted, newest survive.
        assert_eq!(kv.cached_prefix_tokens(&prompt(1000, 16)), 0);
        assert_eq!(kv.cached_prefix_tokens(&prompt(1500, 16)), 16);
        for b in live.branches {
            kv.release_branch(b).unwrap();
        }
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_evicts_retained_pages_on_demand() {
        // 8-page budget total. A retained 3-page prefix must be evicted
        // to make room for a fresh admission that needs the space.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 8, 16, 8);
        let a = kv.admit_tokens(&prompt(0, 48), 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 3);
        // New prompt: 4 tree pages + 2 branch pages = 6 fresh; physical
        // free is 8 - 3 retained, so one retained page must go.
        let b = kv.admit_tokens(&prompt(2000, 64), 32, 1).unwrap();
        assert_eq!(b.cached_tokens, 0);
        assert_eq!(kv.used_pages(), 6);
        assert!(kv.used_pages() + kv.cached_pages() <= kv.capacity_pages());
        assert!(kv.evicted_pages_total() >= 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn retained_hit_counts_against_admission_headroom() {
        // 6-page budget. Retained 2-page prefix; re-admitting it with a
        // branch load that fits only if the retained pages were free must
        // be rejected: the hit pages stop being evictable.
        let mut kv = KvCacheManager::with_prefix_cache(16 * 6, 16, 6);
        let p = prompt(0, 32);
        let a = kv.admit_tokens(&p, 16, 1).unwrap();
        for b in a.branches {
            kv.release_branch(b).unwrap();
        }
        assert_eq!(kv.cached_pages(), 2);
        // Re-lease 2 retained + 5 branch pages > 6 total: must refuse.
        assert!(!kv.can_admit_tokens(&p, 16 * 5, 1));
        assert!(kv.admit_tokens(&p, 16 * 5, 1).is_err());
        // 2 retained + 4 branch pages == 6: fits exactly.
        assert!(kv.can_admit_tokens(&p, 16 * 4, 1));
        let b = kv.admit_tokens(&p, 16 * 4, 1).unwrap();
        assert_eq!(b.cached_tokens, 32);
        assert_eq!(kv.used_pages(), 6);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn sub_page_prompts_stay_private() {
        let mut kv = KvCacheManager::with_prefix_cache(4096, 16, 64);
        let p = prompt(0, 10); // below one page: nothing to intern
        let a = kv.admit_tokens(&p, 16, 1).unwrap();
        assert_eq!(a.cached_tokens, 0);
        assert_eq!(kv.used_pages(), 1 + 1);
        let b = kv.admit_tokens(&p, 16, 1).unwrap();
        assert_eq!(b.cached_tokens, 0, "partial pages are never shared");
        for br in a.branches.into_iter().chain(b.branches) {
            kv.release_branch(br).unwrap();
        }
        assert_eq!(kv.cached_pages(), 0);
        kv.check_invariants().unwrap();
    }
}
