//! SynthMath tokenizer — the rust mirror of `python/compile/vocab.py`.
//!
//! Token ids are compiled in as constants (they define the wire format of
//! the trained model) and *verified* against `artifacts/tokenizer.json` at
//! load time, so a drift between the python and rust sides fails fast
//! instead of silently mis-decoding.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

pub type Token = i32;

pub const PAD: Token = 0;
pub const BOS: Token = 1;
pub const EOS: Token = 2;
pub const Q: Token = 3;
pub const EQ: Token = 4;
pub const THINK: Token = 5;
pub const ETHINK: Token = 6;
pub const ANS: Token = 7;
pub const STEP: Token = 8;
pub const RECHECK: Token = 9;
pub const DIGIT_BASE: Token = 10;
pub const PLUS: Token = 20;
pub const MUL: Token = 21;
pub const EQUALS: Token = 22;
pub const VOCAB_SIZE: usize = 32;

/// Token id of digit `d` (0..=9).
#[inline]
pub fn digit(d: u8) -> Token {
    debug_assert!(d <= 9);
    DIGIT_BASE + d as Token
}

#[inline]
pub fn is_digit(tok: Token) -> bool {
    (DIGIT_BASE..DIGIT_BASE + 10).contains(&tok)
}

#[inline]
pub fn digit_value(tok: Token) -> Option<u8> {
    if is_digit(tok) {
        Some((tok - DIGIT_BASE) as u8)
    } else {
        None
    }
}

/// Extract the answered digit: the digit following the *last* `<ans>`
/// marker (mirrors `data.extract_answer`).
pub fn extract_answer(tokens: &[Token]) -> Option<u8> {
    let mut ans_idx = None;
    for (i, &t) in tokens.iter().enumerate() {
        if t == ANS {
            ans_idx = Some(i);
        }
    }
    let i = ans_idx?;
    tokens.get(i + 1).copied().and_then(digit_value)
}

/// Human-readable rendering (logs / quickstart output).
pub fn detokenize(tokens: &[Token]) -> String {
    tokens
        .iter()
        .map(|&t| name(t))
        .collect::<Vec<_>>()
        .join(" ")
}

pub fn name(tok: Token) -> String {
    match tok {
        PAD => "<pad>".into(),
        BOS => "<bos>".into(),
        EOS => "<eos>".into(),
        Q => "<q>".into(),
        EQ => "</q>".into(),
        THINK => "<think>".into(),
        ETHINK => "</think>".into(),
        ANS => "<ans>".into(),
        STEP => "<step>".into(),
        RECHECK => "<recheck>".into(),
        PLUS => "+".into(),
        MUL => "*".into(),
        EQUALS => "=".into(),
        t if is_digit(t) => format!("{}", t - DIGIT_BASE),
        t => format!("<{t}?>"),
    }
}

/// Verify the compiled-in constants against `artifacts/tokenizer.json`.
pub fn verify_spec(spec: &Json) -> Result<()> {
    let checks: &[(&str, Token)] = &[
        ("pad", PAD),
        ("bos", BOS),
        ("eos", EOS),
        ("q", Q),
        ("eq", EQ),
        ("think", THINK),
        ("ethink", ETHINK),
        ("ans", ANS),
        ("step", STEP),
        ("recheck", RECHECK),
        ("digit_base", DIGIT_BASE),
        ("plus", PLUS),
        ("mul", MUL),
        ("equals", EQUALS),
    ];
    for (key, expected) in checks {
        let got = spec
            .req(key)?
            .as_i64()
            .with_context(|| format!("tokenizer.json `{key}` not a number"))?
            as Token;
        if got != *expected {
            bail!("tokenizer drift: `{key}` is {got} in artifacts but {expected} in rust");
        }
    }
    let vs = spec.req("vocab_size")?.as_usize().unwrap_or(0);
    if vs != VOCAB_SIZE {
        bail!("tokenizer drift: vocab_size {vs} != {VOCAB_SIZE}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digit_roundtrip() {
        for d in 0..=9u8 {
            assert_eq!(digit_value(digit(d)), Some(d));
        }
        assert_eq!(digit_value(PLUS), None);
        assert_eq!(digit_value(DIGIT_BASE + 10), None);
    }

    #[test]
    fn extracts_last_answer() {
        // ... <ans> 3 ... <ans> 7 <eos>
        let toks = vec![BOS, ANS, digit(3), RECHECK, ANS, digit(7), EOS];
        assert_eq!(extract_answer(&toks), Some(7));
    }

    #[test]
    fn answer_missing_or_malformed() {
        assert_eq!(extract_answer(&[BOS, EOS]), None);
        assert_eq!(extract_answer(&[ANS]), None); // nothing after marker
        assert_eq!(extract_answer(&[ANS, PLUS, EOS]), None); // non-digit
    }

    #[test]
    fn verify_spec_accepts_generated() {
        // Simulate the python-side spec.
        let spec = Json::parse(
            r#"{"vocab_size":32,"pad":0,"bos":1,"eos":2,"q":3,"eq":4,
                "think":5,"ethink":6,"ans":7,"step":8,"recheck":9,
                "digit_base":10,"plus":20,"mul":21,"equals":22}"#,
        )
        .unwrap();
        verify_spec(&spec).unwrap();
    }

    #[test]
    fn verify_spec_rejects_drift() {
        let spec = Json::parse(
            r#"{"vocab_size":32,"pad":0,"bos":1,"eos":3,"q":3,"eq":4,
                "think":5,"ethink":6,"ans":7,"step":8,"recheck":9,
                "digit_base":10,"plus":20,"mul":21,"equals":22}"#,
        )
        .unwrap();
        assert!(verify_spec(&spec).is_err());
    }

    #[test]
    fn detokenize_readable() {
        let s = detokenize(&[BOS, Q, digit(3), PLUS, digit(4), EQ, THINK]);
        assert_eq!(s, "<bos> <q> 3 + 4 </q> <think>");
    }
}
