//! Wall-clock serving runtime: a streaming session front end over the
//! stepped scheduler core.
//!
//! The virtual-time serve (`Scheduler::serve`, `serve_cluster`) answers
//! "what would this policy do" in simulated seconds; this module answers
//! it *against the wall clock*. `sart listen` binds a TCP socket and
//! accepts newline-delimited-JSON sessions ([`proto`]); every accepted
//! request is dispatched into the same stepped `Scheduler` the
//! virtual-time paths use, and scheduler steps are paced so virtual time
//! tracks wall time at a configurable exchange rate: one virtual second
//! costs `--time-scale` wall seconds (0.01 replays a 10-minute trace in
//! 6 seconds). [`ServeEvent`]s stream back to each session's socket the
//! moment its scheduler records them, so clients see tokens, prunes and
//! early stops live rather than a report after the fact.
//!
//! Robustness is the same story the virtual-time cluster path tells,
//! replayed against real sockets:
//!
//! - **Fault plans on the wall clock.** The spec's `--fault-plan` and
//!   `--scale-*` knobs arm here too: event times are virtual and map
//!   through `--time-scale` onto the wall clock. When a replica fails,
//!   its in-flight sessions re-dispatch to survivors *without closing
//!   their sockets* — the client sees a `migrated` line (with a
//!   cumulative hop count) and exactly one terminal `finalized`. With no
//!   survivor the work parks until a restart or scale-up re-homes it.
//! - **Connection robustness.** Request lines are read under a bound
//!   (64 KiB) with a poll-based deadline; connections idle past
//!   `--idle-timeout` with no in-flight session are reaped. A malformed
//!   line is answered with a structured `error` line — never by killing
//!   the connection. One connection may pipeline many submits,
//!   multiplexed by request id / client id. Each session's outgoing
//!   queue is bounded (`--session-queue`): a reader too slow to drain
//!   its socket sheds `tokens` lines (counted on `finalized`);
//!   `accepted`/`admitted`/`migrated`/`finalized` are never shed.
//! - **Idempotent resubmits.** A submit may carry a client-assigned
//!   `client_id`. If that id's session is still in flight on a dead
//!   connection, the new connection adopts it mid-stream; if it already
//!   finalized, the retained `finalized` line replays. That makes the
//!   client's reconnect-and-resubmit loop safe against double execution.
//! - **Client resilience.** [`replay_with`] grows per-session deadlines,
//!   seeded jittered exponential backoff honouring the server's
//!   `retry_after_ms`, and reconnect-and-resubmit on connection loss.
//!
//! Threading: the scheduler stack is deliberately not `Send`-friendly
//! (it mutably borrows its engine), so ONE core thread owns every
//! engine/PRM/scheduler and runs the pump; the accept loop and the
//! per-connection reader/writer pairs only talk to it through an mpsc
//! control channel and per-connection outgoing queues. Backpressure is a
//! bounded session table: past `--max-sessions` in-flight sessions,
//! submits are rejected with a load-derived `retry_after_ms` hint and
//! `queue_position` instead of queueing without bound. Shutdown
//! (`{"op":"shutdown"}`, [`ListenerHandle::shutdown`], or SIGTERM via
//! [`ListenerHandle::shutdown_handle`]) stops admitting, drains every
//! in-flight session to its `finalized` event, then exits.
//!
//! Multi-replica specs (`--replicas R`) run R independent scheduler
//! stacks off one shared wall clock, routed least-in-system at submit
//! time — the live analogue of the virtual-time cluster dispatcher.

pub mod proto;

use crate::cluster::{
    pick_drain_candidate, FaultKind, ReplicaState, REPLICA_SEED_STRIDE,
};
use crate::config::{
    EngineChoice, ListenerTuning, LiveConfig, Method, ReplayConfig, ServeSpec,
};
use crate::coordinator::{
    ClockHandle, DrainItem, RequestOutcome, Scheduler, ServeEvent, StepOutcome,
};
use crate::engine::Engine;
use crate::prm::PrmScorer;
use crate::server::{build_engine, build_prm, sched_cfg_for};
use crate::tokenizer::Token;
use crate::util::clock::SimClock;
use crate::util::rng::Rng;
use crate::workload::{Question, Request};
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Longest request line the reader will buffer. Anything longer is
/// discarded in constant memory (the reader skips to the next newline)
/// and answered with an `error` line.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Reader poll interval: how often a blocked read wakes to check the
/// idle clock and the connection's closed flag.
const READ_POLL: Duration = Duration::from_millis(100);

/// Finalized lines retained per client id for resubmit-after-completion
/// dedup, FIFO-evicted past this many distinct ids.
const FINISHED_RETENTION: usize = 4096;

// ---------------------------------------------------------------------------
// Connection plumbing
// ---------------------------------------------------------------------------

/// One queued outgoing line. `pending` is the owning session's
/// queued-line counter for sheddable lines (decremented by the writer
/// once the line hits the socket); terminal/critical lines carry `None`
/// and are never shed.
struct QItem {
    line: String,
    pending: Option<Arc<AtomicUsize>>,
}

/// State shared between a connection's reader thread, its writer thread,
/// and the core. The writer is the *only* thread that touches the socket
/// write half; everyone else enqueues lines through [`ConnShared::push`].
struct ConnShared {
    q: Mutex<VecDeque<QItem>>,
    cv: Condvar,
    /// No new pushes accepted; the writer drains what is queued, then
    /// shuts the socket down. Set by the writer on write failure or
    /// exit, and by the reader on client EOF.
    closed: AtomicBool,
    /// The reader has stopped (EOF, error, idle reap, or panic): no
    /// further submits can arrive on this connection.
    reader_done: AtomicBool,
    /// At least one submit was ever parsed — distinguishes "drained all
    /// sessions, close" from "nothing submitted yet, keep waiting".
    submitted: AtomicBool,
    /// Sessions currently attached to this connection (admitted or
    /// awaiting a terminal reply). The writer only closes a quiet
    /// connection once this reaches zero.
    active: AtomicUsize,
}

impl ConnShared {
    fn new() -> ConnShared {
        ConnShared {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            reader_done: AtomicBool::new(false),
            submitted: AtomicBool::new(false),
            active: AtomicUsize::new(0),
        }
    }

    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Queue a line for the writer. Returns false — dropping the line —
    /// if the connection is already closed.
    fn push(&self, line: String, pending: Option<&Arc<AtomicUsize>>) -> bool {
        if self.is_closed() {
            return false;
        }
        if let Some(p) = pending {
            p.fetch_add(1, Ordering::SeqCst);
        }
        let mut q = self.q.lock().unwrap();
        q.push_back(QItem { line, pending: pending.map(Arc::clone) });
        drop(q);
        self.cv.notify_all();
        true
    }

    /// One session attached to this connection reached a terminal reply
    /// (finalized / rejected / refused / error / dedup replay). Always
    /// called *after* that reply was pushed, so the writer cannot
    /// observe `active == 0` with the terminal line still unqueued.
    fn release_session(&self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }
}

/// Control messages from connection readers to the core thread.
enum Ctl {
    Submit {
        dataset: String,
        question: Question,
        header: Vec<Token>,
        /// Client-assigned idempotency key (reconnect-and-resubmit).
        client_id: Option<String>,
        /// The connection this session's events stream to.
        conn: Arc<ConnShared>,
    },
    Shutdown,
}

/// A running `sart listen` instance.
pub struct ListenerHandle {
    addr: SocketAddr,
    ctl: mpsc::Sender<Ctl>,
    done: Arc<AtomicBool>,
    aborted: Arc<AtomicUsize>,
    core: Option<JoinHandle<Result<()>>>,
    accept: Option<JoinHandle<()>>,
}

impl ListenerHandle {
    /// The bound address (`--addr 127.0.0.1:0` binds an ephemeral port;
    /// this reports the real one).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful shutdown: stop admitting sessions, drain the ones
    /// in flight. Equivalent to a client sending `{"op":"shutdown"}`.
    pub fn shutdown(&self) {
        let _ = self.ctl.send(Ctl::Shutdown);
    }

    /// A cloneable, `Send` handle that can trigger the same graceful
    /// shutdown from another thread — the SIGTERM watcher's hook.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { ctl: self.ctl.clone() }
    }

    /// Sessions whose connection died before their terminal event could
    /// be delivered and that carried no `client_id` to reconnect with:
    /// their table slots were reclaimed and their work dropped.
    /// (Client-id sessions detach instead and wait for a resubmit.)
    pub fn session_aborted(&self) -> usize {
        self.aborted.load(Ordering::SeqCst)
    }

    /// Wait for the listener to finish draining and tear down. Blocks
    /// until shutdown is triggered (by [`ListenerHandle::shutdown`] or a
    /// client's `{"op":"shutdown"}`) and every in-flight session has
    /// received its `finalized` event.
    pub fn join(mut self) -> Result<()> {
        let res = match self.core.take().expect("join called once").join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("listener core thread panicked")),
        };
        self.done.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        res
    }
}

/// See [`ListenerHandle::shutdown_handle`].
#[derive(Clone)]
pub struct ShutdownHandle {
    ctl: mpsc::Sender<Ctl>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        let _ = self.ctl.send(Ctl::Shutdown);
    }
}

/// Bind `live.addr` and serve `spec` against the wall clock with default
/// [`ListenerTuning`]. Returns as soon as the socket is listening; the
/// serve itself runs on background threads until
/// [`ListenerHandle::join`] observes shutdown.
pub fn listen(spec: &ServeSpec, live: &LiveConfig) -> Result<ListenerHandle> {
    listen_with(spec, live, &ListenerTuning::default())
}

/// [`listen`] with explicit robustness knobs.
pub fn listen_with(
    spec: &ServeSpec,
    live: &LiveConfig,
    tuning: &ListenerTuning,
) -> Result<ListenerHandle> {
    if !matches!(spec.engine, EngineChoice::Sim) {
        bail!(
            "sart listen requires --engine sim (decode costs are virtual \
             and paced against the wall clock via --time-scale)"
        );
    }
    if matches!(spec.method, Method::Rebase { .. }) {
        bail!(
            "sart listen does not support the rebase baseline (it has no \
             stepped scheduler to pump)"
        );
    }
    let listener = TcpListener::bind(&live.addr)
        .with_context(|| format!("binding {}", live.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
    let done = Arc::new(AtomicBool::new(false));
    let aborted = Arc::new(AtomicUsize::new(0));

    let core = {
        let spec = spec.clone();
        let live = live.clone();
        let tuning = *tuning;
        let done = done.clone();
        let aborted = aborted.clone();
        thread::Builder::new().name("sart-core".into()).spawn(move || {
            let res = core_loop(&spec, &live, &tuning, ctl_rx, aborted);
            done.store(true, Ordering::SeqCst);
            res
        })?
    };
    let accept = {
        let ctl = ctl_tx.clone();
        let done = done.clone();
        let tuning = *tuning;
        thread::Builder::new()
            .name("sart-accept".into())
            .spawn(move || accept_loop(listener, ctl, done, tuning))?
    };
    Ok(ListenerHandle {
        addr,
        ctl: ctl_tx,
        done,
        aborted,
        core: Some(core),
        accept: Some(accept),
    })
}

fn accept_loop(
    listener: TcpListener,
    ctl: mpsc::Sender<Ctl>,
    done: Arc<AtomicBool>,
    tuning: ListenerTuning,
) {
    loop {
        if done.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let ctl = ctl.clone();
                let _ = thread::Builder::new()
                    .name("sart-conn".into())
                    .spawn(move || handle_conn(stream, ctl, tuning));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => return,
        }
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Closes the connection's shared state (and sends FIN) even if the
/// writer thread panics, so the core's next push fails fast and the
/// session-table slot is reclaimed rather than orphaned.
struct WriterGuard<'a> {
    sh: &'a ConnShared,
    stream: &'a TcpStream,
}

impl Drop for WriterGuard<'_> {
    fn drop(&mut self) {
        self.sh.close();
        let _ = self.stream.shutdown(Shutdown::Both);
    }
}

/// Marks the reader as gone even if it panics: the writer then exits
/// once every attached session has been answered, instead of waiting on
/// submits that can never arrive.
struct ReaderGuard<'a>(&'a ConnShared);

impl Drop for ReaderGuard<'_> {
    fn drop(&mut self) {
        self.0.reader_done.store(true, Ordering::SeqCst);
        self.0.cv.notify_all();
    }
}

/// One connection: a reader thread (this one) parsing pipelined request
/// lines, and a writer thread multiplexing every attached session's
/// event lines back. The connection closes once the client is done
/// (all submitted sessions answered, or EOF with none in flight).
fn handle_conn(
    stream: TcpStream,
    ctl: mpsc::Sender<Ctl>,
    tuning: ListenerTuning,
) {
    let sh = Arc::new(ConnShared::new());
    let Ok(read_half) = stream.try_clone() else { return };
    let writer = {
        let sh = sh.clone();
        thread::Builder::new()
            .name("sart-conn-w".into())
            .spawn(move || writer_loop(stream, &sh))
    };
    let Ok(writer) = writer else { return };
    reader_loop(read_half, &sh, &ctl, &tuning);
    let _ = writer.join();
}

/// Sole owner of the socket's write half: pop queued lines and write
/// them. Exits (shutting the socket down) when the connection is closed,
/// a write fails, or everything this client asked for has been answered:
/// queue drained, no attached session, and either a submit happened or
/// the reader is gone.
fn writer_loop(stream: TcpStream, sh: &ConnShared) {
    let _guard = WriterGuard { sh, stream: &stream };
    loop {
        let item = {
            let mut q = sh.q.lock().unwrap();
            loop {
                if let Some(it) = q.pop_front() {
                    break Some(it);
                }
                if sh.is_closed() {
                    break None;
                }
                if sh.active.load(Ordering::SeqCst) == 0
                    && (sh.submitted.load(Ordering::SeqCst)
                        || sh.reader_done.load(Ordering::SeqCst))
                {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        let Some(item) = item else { return };
        let mut w = &stream;
        let ok = writeln!(w, "{}", item.line).is_ok() && w.flush().is_ok();
        if let Some(p) = item.pending {
            p.fetch_sub(1, Ordering::SeqCst);
        }
        if !ok {
            return; // guard closes; the core notices on its next push
        }
    }
}

enum SkipOutcome {
    Done,
    WouldBlock,
    Gone,
}

/// Discard buffered bytes up to and including the next newline without
/// ever growing a buffer — the oversized-line path.
fn skip_to_newline(reader: &mut BufReader<TcpStream>) -> SkipOutcome {
    loop {
        let (n, done) = match reader.fill_buf() {
            Ok(b) if b.is_empty() => return SkipOutcome::Gone,
            Ok(b) => match b.iter().position(|&x| x == b'\n') {
                Some(p) => (p + 1, true),
                None => (b.len(), false),
            },
            Err(e) if would_block(&e) => return SkipOutcome::WouldBlock,
            Err(_) => return SkipOutcome::Gone,
        };
        reader.consume(n);
        if done {
            return SkipOutcome::Done;
        }
    }
}

/// Parse pipelined request lines until the client goes away or idles
/// out. Reads are bounded ([`MAX_LINE_BYTES`]) and polled
/// ([`READ_POLL`]) so a stalled or abusive peer cannot pin memory or the
/// thread.
fn reader_loop(
    read_half: TcpStream,
    sh: &Arc<ConnShared>,
    ctl: &mpsc::Sender<Ctl>,
    tuning: &ListenerTuning,
) {
    let _guard = ReaderGuard(sh);
    let _ = read_half.set_read_timeout(Some(READ_POLL));
    let mut reader = BufReader::new(read_half);
    let mut line = String::new();
    let mut skipping = false;
    let mut idle_since = Instant::now();
    let idle_timeout = Duration::from_secs_f64(tuning.idle_timeout_s);
    loop {
        if sh.is_closed() {
            return;
        }
        if skipping {
            match skip_to_newline(&mut reader) {
                SkipOutcome::Done => {
                    skipping = false;
                    idle_since = Instant::now();
                    sh.push(
                        proto::error_line(&format!(
                            "request line exceeds {MAX_LINE_BYTES} bytes"
                        )),
                        None,
                    );
                }
                SkipOutcome::Gone => {
                    sh.close();
                    return;
                }
                SkipOutcome::WouldBlock => {}
            }
            continue;
        }
        let cap = (MAX_LINE_BYTES + 1 - line.len()) as u64;
        match (&mut reader).take(cap).read_line(&mut line) {
            Ok(0) => {
                // Client EOF. Parse a trailing unterminated line, then
                // close: nothing further can arrive, and a client that
                // closed its socket is not reading events either.
                let last = line.trim().to_string();
                if !last.is_empty() {
                    handle_line(&last, sh, ctl);
                }
                sh.close();
                return;
            }
            Ok(_) if line.ends_with('\n') => {
                let msg = line.trim().to_string();
                line.clear();
                idle_since = Instant::now();
                if !msg.is_empty() {
                    handle_line(&msg, sh, ctl);
                }
            }
            Ok(_) if line.len() > MAX_LINE_BYTES => {
                line.clear();
                skipping = true;
            }
            Ok(_) => {} // partial line under the cap: keep accumulating
            Err(e) if would_block(&e) => {
                if sh.active.load(Ordering::SeqCst) == 0
                    && idle_since.elapsed() >= idle_timeout
                {
                    sh.push(
                        proto::error_line(
                            "idle timeout: no request line and no session \
                             in flight",
                        ),
                        None,
                    );
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Dispatch one complete request line. Malformed input is answered with
/// a structured `error` line; the connection keeps serving.
fn handle_line(line: &str, sh: &Arc<ConnShared>, ctl: &mpsc::Sender<Ctl>) {
    match proto::parse_client_line(line) {
        Err(e) => {
            sh.push(proto::error_line(&format!("{e:#}")), None);
        }
        Ok(proto::ClientMsg::Shutdown) => {
            // The control send happens-before the ack: a client that has
            // read the ack knows any submit it opens afterwards orders
            // after the shutdown on the control channel, so it will be
            // refused — that makes the graceful-shutdown test (and any
            // script doing `shutdown; submit`) deterministic.
            let _ = ctl.send(Ctl::Shutdown);
            sh.push(proto::shutdown_ack_line(), None);
        }
        Ok(proto::ClientMsg::Submit { dataset, question, header, client_id }) => {
            // active before submitted: the writer's quiescence check
            // reads them in the opposite order, so it can never observe
            // "submitted, zero active" inside this window.
            sh.active.fetch_add(1, Ordering::SeqCst);
            sh.submitted.store(true, Ordering::SeqCst);
            let msg = Ctl::Submit {
                dataset,
                question,
                header,
                client_id,
                conn: sh.clone(),
            };
            if ctl.send(msg).is_err() {
                sh.push(proto::refused_line("listener is down"), None);
                sh.release_session();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Core loop
// ---------------------------------------------------------------------------

/// Load-derived retry hint for a rejected submit, in wall milliseconds:
/// grows with how far past capacity the session table is and with the
/// cluster's pending-prefill backlog, scaled by `--time-scale` so the
/// number means the same thing at any replay speed. Monotone in both
/// load inputs; clamped to [1 ms, 60 s].
pub fn retry_hint_ms(
    in_system: usize,
    max_sessions: usize,
    prefill_backlog_tokens: usize,
    time_scale: f64,
) -> u64 {
    let over = in_system.saturating_sub(max_sessions) + 1;
    let virtual_wait =
        0.04 * over as f64 + 0.0005 * prefill_backlog_tokens as f64;
    (virtual_wait * time_scale * 1000.0).ceil().clamp(1.0, 60_000.0) as u64
}

/// One live session's bookkeeping in the core's table.
struct LiveSession {
    conn: Arc<ConnShared>,
    /// Queued-but-unwritten sheddable lines on `conn` for this session.
    pending: Arc<AtomicUsize>,
    client_id: Option<String>,
    /// Cumulative replica migrations (mirrors the outcome's
    /// `redispatches`).
    hops: usize,
    /// `tokens` lines shed under backpressure (reported on `finalized`).
    shed: usize,
    /// Original arrival instant, preserved across migrations so
    /// latencies measure from first submission.
    arrival0: f64,
    /// The connection died but the session has a `client_id`: keep
    /// computing and wait for a reconnect-resubmit to adopt the stream.
    detached: bool,
}

struct SessionTable {
    sessions: HashMap<usize, LiveSession>,
    by_client: HashMap<String, usize>,
    /// Retained `finalized` lines per client id (resubmit-after-
    /// completion replays these instead of re-running the request).
    finished_by_client: HashMap<String, (usize, String)>,
    finished_order: VecDeque<String>,
    aborted: Arc<AtomicUsize>,
    queue_cap: usize,
}

impl SessionTable {
    fn retain_finalized(&mut self, cid: String, id: usize, line: String) {
        if self.finished_by_client.insert(cid.clone(), (id, line)).is_none() {
            self.finished_order.push_back(cid);
            if self.finished_order.len() > FINISHED_RETENTION {
                if let Some(old) = self.finished_order.pop_front() {
                    self.finished_by_client.remove(&old);
                }
            }
        }
    }

    /// The session's connection died mid-stream. Reconnectable sessions
    /// (with a client id) detach and keep computing; anonymous ones
    /// abort — their slot is reclaimed and the abort counted.
    fn conn_died(&mut self, id: usize) {
        let reconnectable = match self.sessions.get_mut(&id) {
            None => return,
            Some(s) if s.client_id.is_some() => {
                s.detached = true;
                true
            }
            Some(_) => false,
        };
        if !reconnectable {
            if let Some(s) = self.sessions.remove(&id) {
                s.conn.release_session();
                self.aborted.fetch_add(1, Ordering::SeqCst);
            }
        }
    }
}

/// Stream freshly recorded scheduler events to their sessions, applying
/// the shed policy and terminal-line bookkeeping.
fn forward_events(sched: &mut Scheduler<'_>, st: &mut SessionTable) {
    for ev in sched.drain_events() {
        let id = ev.request();
        if matches!(ev, ServeEvent::Finalized { .. }) {
            let Some(sess) = st.sessions.get(&id) else {
                continue; // aborted earlier: nobody is listening
            };
            let mut oc = sched.outcome_by_id(id);
            if let Some(o) = oc.as_mut() {
                // The live fault layer owns migration accounting, same
                // as the cluster dispatcher does in virtual time: the
                // outcome keeps the *original* arrival (re-dispatch
                // delay shows up in its latencies) and the hop count.
                o.arrival = sess.arrival0;
                o.redispatches = sess.hops;
            }
            let line = proto::event_line(&ev, oc.as_ref(), sess.shed);
            let delivered =
                !sess.detached && sess.conn.push(line.clone(), None);
            let sess = st.sessions.remove(&id).expect("session present");
            sess.conn.release_session();
            if let Some(cid) = sess.client_id {
                st.by_client.remove(&cid);
                st.retain_finalized(cid, id, line);
            } else if !delivered {
                st.aborted.fetch_add(1, Ordering::SeqCst);
            }
        } else {
            let sheddable = matches!(ev, ServeEvent::BranchTokens { .. });
            let Some(sess) = st.sessions.get_mut(&id) else { continue };
            if sheddable {
                if sess.detached
                    || sess.pending.load(Ordering::SeqCst) >= st.queue_cap
                {
                    sess.shed += 1;
                    continue;
                }
                if !sess
                    .conn
                    .push(proto::event_line(&ev, None, 0), Some(&sess.pending))
                {
                    sess.shed += 1;
                    st.conn_died(id);
                }
            } else {
                if sess.detached {
                    continue;
                }
                if !sess.conn.push(proto::event_line(&ev, None, 0), None) {
                    st.conn_died(id);
                }
            }
        }
    }
}

/// The single thread that owns every engine/PRM/scheduler stack and
/// pumps them against the wall clock.
fn core_loop(
    spec: &ServeSpec,
    live: &LiveConfig,
    tuning: &ListenerTuning,
    ctl: mpsc::Receiver<Ctl>,
    aborted: Arc<AtomicUsize>,
) -> Result<()> {
    let replicas = spec.replicas.max(1);
    let mut engines: Vec<Box<dyn Engine>> = Vec::with_capacity(replicas);
    let mut prms: Vec<Box<dyn PrmScorer>> = Vec::with_capacity(replicas);
    let mut cfgs = Vec::with_capacity(replicas);
    for i in 0..replicas {
        // Same per-replica seed stride as the virtual-time cluster path.
        let mut rspec = spec.clone();
        rspec.seed = spec.seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
        engines.push(build_engine(&rspec)?);
        prms.push(build_prm(&rspec)?);
        cfgs.push(sched_cfg_for(&rspec)?);
    }
    let mut scheds: Vec<Scheduler> = Vec::with_capacity(replicas);
    for ((e, p), cfg) in engines.iter_mut().zip(prms.iter_mut()).zip(cfgs) {
        let mut s = Scheduler::new(
            cfg,
            e.as_mut(),
            p.as_mut(),
            ClockHandle::Sim(SimClock::new()),
        );
        s.set_emit_events(true);
        scheds.push(s);
    }

    let start = Instant::now();
    let ts = live.time_scale;
    let mut st = SessionTable {
        sessions: HashMap::new(),
        by_client: HashMap::new(),
        finished_by_client: HashMap::new(),
        finished_order: VecDeque::new(),
        aborted,
        queue_cap: tuning.session_queue,
    };
    // Replica lifecycle mirrors the virtual-time dispatcher: all live
    // unless a scale controller starts the fleet at its floor.
    let mut state = vec![ReplicaState::Live; replicas];
    if let Some(sc) = &spec.scale {
        for s in state.iter_mut().skip(sc.min_live) {
            *s = ReplicaState::Down;
        }
    }
    // Fault-plan times are virtual: `--time-scale` maps them onto the
    // wall clock exactly as it paces the schedulers.
    let mut faults: VecDeque<_> = spec.fault_plan.events.clone().into();
    // Requests stranded by a failure with no live survivor, re-homed on
    // the next restart/scale-up. `(failed replica, request)`.
    let mut parked: Vec<(usize, Request)> = Vec::new();
    let mut last_arrival = vec![0.0f64; replicas];
    let mut next_id = 0usize;
    let mut draining = false;
    let mut since_scale = 0usize;
    let mut pending: VecDeque<Ctl> = VecDeque::new();

    loop {
        // 1. Control messages: anything the idle wait deferred, then
        // everything currently queued.
        loop {
            let msg = match pending.pop_front() {
                Some(m) => m,
                None => match ctl.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        draining = true;
                        break;
                    }
                },
            };
            match msg {
                Ctl::Shutdown => draining = true,
                Ctl::Submit { dataset, question, header, client_id, conn } => {
                    if draining {
                        conn.push(proto::refused_line("shutting down"), None);
                        conn.release_session();
                        continue;
                    }
                    // Idempotent resubmit: a known client id adopts its
                    // in-flight session (if its old connection is gone)
                    // or replays its retained finalized line, instead of
                    // double-running the request.
                    if let Some(cid) = client_id.as_deref() {
                        if let Some(&sid) = st.by_client.get(cid) {
                            let sess = st
                                .sessions
                                .get_mut(&sid)
                                .expect("by_client maps to live sessions");
                            if sess.detached || sess.conn.is_closed() {
                                let old =
                                    std::mem::replace(&mut sess.conn, conn);
                                old.release_session();
                                sess.detached = false;
                                // Fresh counter: the old connection's
                                // queued lines died with it.
                                sess.pending = Arc::new(AtomicUsize::new(0));
                                if !sess.conn.push(
                                    proto::accepted_line_with(sid, Some(cid)),
                                    None,
                                ) {
                                    sess.detached = true;
                                }
                            } else {
                                conn.push(
                                    proto::error_line(&format!(
                                        "client_id `{cid}` already in \
                                         flight on another connection"
                                    )),
                                    None,
                                );
                                conn.release_session();
                            }
                            continue;
                        }
                        if let Some((rid, line)) =
                            st.finished_by_client.get(cid)
                        {
                            conn.push(
                                proto::accepted_line_with(*rid, Some(cid)),
                                None,
                            );
                            conn.push(line.clone(), None);
                            conn.release_session();
                            continue;
                        }
                    }
                    if conn.is_closed() && client_id.is_none() {
                        // The client vanished before its submit was even
                        // tabled and cannot reconnect: reclaim now.
                        st.aborted.fetch_add(1, Ordering::SeqCst);
                        conn.release_session();
                        continue;
                    }
                    let vnow = start.elapsed().as_secs_f64() / ts;
                    let table_full = st.sessions.len() >= live.max_sessions;
                    let target = (0..replicas)
                        .filter(|&i| state[i] == ReplicaState::Live)
                        .min_by_key(|&i| {
                            (scheds[i].load().requests_in_system(), i)
                        });
                    let Some(ri) = target.filter(|_| !table_full) else {
                        // Table full, or no live replica right now:
                        // reject with a load-derived retry hint.
                        let backlog: usize = (0..replicas)
                            .filter(|&i| state[i] == ReplicaState::Live)
                            .map(|i| scheds[i].load().pending_prefill_tokens)
                            .sum();
                        let hint = retry_hint_ms(
                            st.sessions.len() + 1,
                            live.max_sessions,
                            backlog,
                            ts,
                        );
                        let qpos = (st.sessions.len() + 1)
                            .saturating_sub(live.max_sessions)
                            .max(1);
                        conn.push(proto::rejected_line(hint, qpos), None);
                        conn.release_session();
                        continue;
                    };
                    // The arrival instant is the wall clock read in
                    // virtual units; per-replica clamping keeps each
                    // scheduler's dispatch order sorted even when two
                    // submits race onto one replica within a clock tick.
                    let arrival = vnow.max(last_arrival[ri]);
                    last_arrival[ri] = arrival;
                    let id = next_id;
                    next_id += 1;
                    scheds[ri].dispatch(Request {
                        id,
                        question,
                        arrival,
                        dataset,
                        header,
                    })?;
                    let pushed = conn.push(
                        proto::accepted_line_with(id, client_id.as_deref()),
                        None,
                    );
                    if !pushed && client_id.is_none() {
                        // Dead before `accepted` and unable to ever
                        // reconnect: don't table it (the request
                        // finishes as an orphan; its events are skipped).
                        st.aborted.fetch_add(1, Ordering::SeqCst);
                        conn.release_session();
                    } else {
                        st.sessions.insert(
                            id,
                            LiveSession {
                                conn,
                                pending: Arc::new(AtomicUsize::new(0)),
                                client_id: client_id.clone(),
                                hops: 0,
                                shed: 0,
                                arrival0: arrival,
                                detached: !pushed,
                            },
                        );
                        if let Some(cid) = client_id {
                            st.by_client.insert(cid, id);
                        }
                    }
                    // Scale controller, evaluated per admitted arrival —
                    // same thresholds and cooldown as the virtual path.
                    since_scale += 1;
                    if let Some(sc) = &spec.scale {
                        if since_scale >= sc.cooldown_arrivals {
                            let live_n = state
                                .iter()
                                .filter(|&&s| s == ReplicaState::Live)
                                .count();
                            let queued: usize = (0..replicas)
                                .filter(|&i| state[i] == ReplicaState::Live)
                                .map(|i| {
                                    scheds[i].load().requests_in_system()
                                })
                                .sum();
                            let backlog: usize = (0..replicas)
                                .filter(|&i| state[i] == ReplicaState::Live)
                                .map(|i| {
                                    scheds[i].load().pending_prefill_tokens
                                })
                                .sum();
                            let pressure = (0..replicas)
                                .filter(|&i| state[i] == ReplicaState::Live)
                                .map(|i| scheds[i].load().kv_pressure)
                                .fold(0.0, f64::max);
                            if sc.wants_scale_up(
                                queued, backlog, pressure, live_n,
                            ) {
                                // Draining first (warm cache), then cold.
                                let cand = (0..replicas)
                                    .find(|&i| {
                                        state[i] == ReplicaState::Draining
                                    })
                                    .or_else(|| {
                                        (0..replicas).find(|&i| {
                                            state[i] == ReplicaState::Down
                                        })
                                    });
                                if let Some(i) = cand {
                                    if state[i] == ReplicaState::Down {
                                        scheds[i].advance_clock_to(vnow);
                                    }
                                    state[i] = ReplicaState::Live;
                                    since_scale = 0;
                                }
                            } else if sc.wants_scale_down(queued, live_n) {
                                let backlogs: Vec<usize> = scheds
                                    .iter()
                                    .map(|s| s.load().pending_prefill_tokens)
                                    .collect();
                                if let Some(i) =
                                    pick_drain_candidate(&state, &backlogs)
                                {
                                    state[i] = ReplicaState::Draining;
                                    since_scale = 0;
                                }
                            }
                        }
                    }
                }
            }
        }

        let vtarget = start.elapsed().as_secs_f64() / ts;

        // 2. Scripted faults whose (virtual) instant the wall clock has
        // reached, in plan order.
        while let Some(&ev) = faults.front() {
            if ev.t > vtarget {
                break;
            }
            faults.pop_front();
            let f = ev.replica;
            match ev.kind {
                FaultKind::Fail => {
                    if state[f] == ReplicaState::Down {
                        bail!(
                            "live fault plan fails replica {f} at t={} but \
                             it is already down",
                            ev.t
                        );
                    }
                    // Catch the victim up to the failure instant and
                    // flush what it managed to emit, then take it down.
                    while scheds[f].now() < ev.t {
                        match scheds[f].step()? {
                            StepOutcome::Worked => {}
                            StepOutcome::Idle => {
                                scheds[f].advance_clock_to(ev.t);
                                break;
                            }
                        }
                    }
                    forward_events(&mut scheds[f], &mut st);
                    let (items, _partial) = scheds[f].fail_and_drain()?;
                    // Anything recorded between the flush and the drain
                    // died with the replica.
                    scheds[f].discard_events();
                    state[f] = ReplicaState::Down;
                    last_arrival[f] = 0.0;
                    for item in items {
                        let DrainItem::Unfinished(mut req) = item else {
                            continue; // finished: already forwarded above
                        };
                        let id = req.id;
                        if !st.sessions.contains_key(&id) {
                            continue; // aborted: nobody is waiting
                        }
                        let target = (0..replicas)
                            .filter(|&i| state[i] == ReplicaState::Live)
                            .min_by_key(|&i| {
                                (scheds[i].load().requests_in_system(), i)
                            });
                        let Some(t) = target else {
                            parked.push((f, req));
                            continue;
                        };
                        let arrival = ev.t.max(last_arrival[t]);
                        last_arrival[t] = arrival;
                        req.arrival = arrival;
                        if let Some(sess) = st.sessions.get_mut(&id) {
                            sess.hops += 1;
                            let line = proto::migrated_line(
                                id, f, t, sess.hops, ev.t,
                            );
                            if !sess.detached && !sess.conn.push(line, None) {
                                st.conn_died(id);
                            }
                        }
                        // conn_died may have aborted an anonymous
                        // session — only re-run work someone awaits.
                        if st.sessions.contains_key(&id) {
                            scheds[t].dispatch(req)?;
                        }
                    }
                }
                FaultKind::Restart => {
                    if state[f] != ReplicaState::Down {
                        bail!(
                            "live fault plan restarts replica {f} at t={} \
                             but it is not down",
                            ev.t
                        );
                    }
                    scheds[f].advance_clock_to(ev.t);
                    state[f] = ReplicaState::Live;
                }
            }
        }

        // 2b. Re-home parked sessions the moment a live replica exists.
        if !parked.is_empty()
            && state.iter().any(|&s| s == ReplicaState::Live)
        {
            for (from, mut req) in std::mem::take(&mut parked) {
                let id = req.id;
                if !st.sessions.contains_key(&id) {
                    continue;
                }
                let t = (0..replicas)
                    .filter(|&i| state[i] == ReplicaState::Live)
                    .min_by_key(|&i| {
                        (scheds[i].load().requests_in_system(), i)
                    })
                    .expect("a live replica exists");
                let arrival = vtarget.max(last_arrival[t]);
                last_arrival[t] = arrival;
                req.arrival = arrival;
                if let Some(sess) = st.sessions.get_mut(&id) {
                    sess.hops += 1;
                    let line =
                        proto::migrated_line(id, from, t, sess.hops, vtarget);
                    if !sess.detached && !sess.conn.push(line, None) {
                        st.conn_died(id);
                    }
                }
                if st.sessions.contains_key(&id) {
                    scheds[t].dispatch(req)?;
                }
            }
        }

        // 3. Step every running replica until its virtual clock catches
        // up with the wall clock (bounded per pass so control stays
        // responsive), streaming fresh events to their sessions.
        let mut worked = false;
        for i in 0..replicas {
            if state[i] == ReplicaState::Down {
                continue;
            }
            let mut budget = 64;
            while scheds[i].now() < vtarget && budget > 0 {
                match scheds[i].step()? {
                    StepOutcome::Worked => {
                        worked = true;
                        budget -= 1;
                    }
                    StepOutcome::Idle => {
                        scheds[i].advance_clock_to(vtarget);
                        break;
                    }
                }
            }
            forward_events(&mut scheds[i], &mut st);
        }

        if draining && st.sessions.is_empty() {
            return Ok(());
        }

        // 4. Pacing: nothing stepped this pass — sleep on the control
        // channel so a submit wakes the loop immediately.
        if !worked {
            match ctl.recv_timeout(Duration::from_millis(2)) {
                Ok(m) => pending.push_back(m),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => draining = true,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Replay client
// ---------------------------------------------------------------------------

/// What one replayed session ended as.
enum SessionEnd {
    Finished {
        outcome: Box<RequestOutcome>,
        wall_ttft: f64,
        wall_e2e: f64,
        /// The session survived at least one replica migration (a
        /// `migrated` line, or a non-zero `redispatches` in the outcome
        /// if the line was missed while reconnecting).
        migrated: bool,
    },
    Rejected,
    Lost,
    /// The per-session `--session-deadline` expired first (also counted
    /// as lost).
    DeadlineExpired,
}

/// Result of replaying a trace against a live listener.
#[derive(Debug, Default)]
pub struct ReplayResult {
    /// Server-reported outcome records, one per finalized session (the
    /// same schema the virtual-time serve produces).
    pub outcomes: Vec<RequestOutcome>,
    /// Wall seconds from session open to the first `tokens` event.
    pub wall_ttft: Vec<f64>,
    /// Wall seconds from session open to `finalized`.
    pub wall_e2e: Vec<f64>,
    /// Accepted sessions that never saw `finalized` (plus transport
    /// errors and expired deadlines) — a correct listener replays with
    /// zero.
    pub requests_lost: usize,
    /// Sessions turned away (`rejected` backpressure or `refused`) after
    /// exhausting any retry budget.
    pub rejected: usize,
    /// Finalized sessions that survived at least one replica migration.
    pub migrated_sessions: usize,
    /// Reconnect/resubmit/backoff attempts across all sessions (0 with
    /// retries off).
    pub retries: usize,
    /// Sessions dropped at their `--session-deadline` (subset of
    /// `requests_lost`).
    pub deadline_expired: usize,
}

/// Fire `trace` at a live listener at trace rate with the legacy
/// single-shot client (no retries, no deadline — see [`replay_with`]).
pub fn replay(
    addr: &str,
    trace: &[Request],
    time_scale: f64,
    send_shutdown: bool,
) -> Result<ReplayResult> {
    replay_with(addr, trace, time_scale, send_shutdown, &ReplayConfig::default())
}

/// Fire `trace` at a live listener at trace rate: request `i` is
/// submitted `arrival_i * time_scale` wall seconds after the first, each
/// on its own connection, and all sessions are drained to completion.
/// `cfg` arms the resilience layer: per-session deadlines, seeded
/// jittered exponential backoff on rejection, and reconnect-and-resubmit
/// (with an idempotent client id) on connection loss. With
/// `send_shutdown`, a `{"op":"shutdown"}` is sent after the last session
/// finishes (and its ack awaited).
pub fn replay_with(
    addr: &str,
    trace: &[Request],
    time_scale: f64,
    send_shutdown: bool,
    cfg: &ReplayConfig,
) -> Result<ReplayResult> {
    if !(time_scale.is_finite() && time_scale > 0.0) {
        bail!("time_scale must be a positive number, got {time_scale}");
    }
    let start = Instant::now();
    let mut handles = Vec::with_capacity(trace.len());
    for r in trace {
        let due = r.arrival * time_scale;
        let elapsed = start.elapsed().as_secs_f64();
        if due > elapsed {
            thread::sleep(Duration::from_secs_f64(due - elapsed));
        }
        let addr = addr.to_string();
        let req = r.clone();
        let cfg = *cfg;
        handles.push(thread::spawn(move || session_with(&addr, &req, &cfg)));
    }
    let mut res = ReplayResult::default();
    for h in handles {
        match h.join() {
            Ok((end, retries)) => {
                res.retries += retries;
                match end {
                    SessionEnd::Finished {
                        outcome,
                        wall_ttft,
                        wall_e2e,
                        migrated,
                    } => {
                        res.outcomes.push(*outcome);
                        res.wall_ttft.push(wall_ttft);
                        res.wall_e2e.push(wall_e2e);
                        if migrated {
                            res.migrated_sessions += 1;
                        }
                    }
                    SessionEnd::Rejected => res.rejected += 1,
                    SessionEnd::Lost => res.requests_lost += 1,
                    SessionEnd::DeadlineExpired => {
                        res.requests_lost += 1;
                        res.deadline_expired += 1;
                    }
                }
            }
            Err(_) => res.requests_lost += 1,
        }
    }
    if send_shutdown {
        let stream =
            TcpStream::connect(addr).context("connecting for shutdown")?;
        let mut w = &stream;
        writeln!(w, "{}", proto::shutdown_line())?;
        let _ = w.flush();
        let mut line = String::new();
        let _ = BufReader::new(stream).read_line(&mut line); // await ack
    }
    Ok(res)
}

/// The wall wait before retry `attempt` (1-based): `base * 2^(attempt-1)`
/// milliseconds, jittered to 50–100% by the session's seeded RNG.
fn backoff_wait(rng: &mut Rng, base_ms: u64, attempt: usize) -> Duration {
    let exp = base_ms.saturating_mul(1u64 << (attempt - 1).min(16));
    let jitter = 0.5 + 0.5 * rng.f64();
    Duration::from_secs_f64(exp as f64 * jitter / 1000.0)
}

/// Sleep out the backoff before retry `attempt`. A server-supplied
/// `retry_after_ms` replaces the configured base for this wait. Returns
/// false if the deadline expires inside (after sleeping only up to it).
fn backoff(
    rng: &mut Rng,
    cfg: &ReplayConfig,
    attempt: usize,
    server_hint_ms: Option<u64>,
    deadline: Option<Instant>,
) -> bool {
    let base = server_hint_ms.unwrap_or(cfg.retry_base_ms).max(1);
    let wait = backoff_wait(rng, base, attempt);
    if let Some(d) = deadline {
        let now = Instant::now();
        if now >= d {
            return false;
        }
        let remaining = d - now;
        if wait >= remaining {
            thread::sleep(remaining);
            return false;
        }
    }
    thread::sleep(wait);
    true
}

fn expired(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// Drive one session with the resilience knobs in `cfg`: submit, read
/// events until `finalized`, and on rejection / connection loss /
/// transport error reconnect-and-resubmit under the retry budget. With
/// retries enabled, submits carry a deterministic client id
/// (`r<seed>-<request id>`) so the server deduplicates resubmits instead
/// of double-running them.
fn session_with(
    addr: &str,
    req: &Request,
    cfg: &ReplayConfig,
) -> (SessionEnd, usize) {
    let t0 = Instant::now();
    let deadline = (cfg.session_deadline_s > 0.0)
        .then(|| t0 + Duration::from_secs_f64(cfg.session_deadline_s));
    let client_id =
        (cfg.retry_max > 0).then(|| format!("r{}-{}", cfg.seed, req.id));
    let mut rng = Rng::new(
        cfg.seed ^ (req.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut retries = 0usize;
    let mut ttft: Option<f64> = None;
    let mut migrated = false;

    // One failed attempt = one backoff + reconnect, shared by every
    // transient failure mode below.
    macro_rules! retry_or {
        ($terminal:expr, $hint:expr) => {{
            if retries >= cfg.retry_max {
                return ($terminal, retries);
            }
            retries += 1;
            if !backoff(&mut rng, cfg, retries, $hint, deadline) {
                return (SessionEnd::DeadlineExpired, retries);
            }
            continue 'attempt;
        }};
    }

    'attempt: loop {
        if expired(deadline) {
            return (SessionEnd::DeadlineExpired, retries);
        }
        let stream = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(_) => retry_or!(SessionEnd::Lost, None),
        };
        if deadline.is_some() {
            // Poll so a stalled server cannot out-wait the deadline.
            let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
        }
        {
            let mut w = &stream;
            let line = proto::submit_line_with(
                &req.dataset,
                &req.question,
                &req.header,
                client_id.as_deref(),
            );
            if writeln!(w, "{line}").and_then(|_| w.flush()).is_err() {
                retry_or!(SessionEnd::Lost, None);
            }
        }
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            match reader.read_line(&mut line) {
                Ok(0) => retry_or!(SessionEnd::Lost, None),
                Ok(_) if line.ends_with('\n') => {}
                Ok(_) => continue, // EOF mid-line surfaces as Ok(0) next
                Err(e) if would_block(&e) => {
                    if expired(deadline) {
                        return (SessionEnd::DeadlineExpired, retries);
                    }
                    continue;
                }
                Err(_) => retry_or!(SessionEnd::Lost, None),
            }
            let msg = proto::parse_server_line(line.trim());
            line.clear();
            match msg {
                Err(_) => retry_or!(SessionEnd::Lost, None),
                Ok(proto::ServerMsg::Rejected { retry_after_ms, .. }) => {
                    retry_or!(SessionEnd::Rejected, Some(retry_after_ms));
                }
                Ok(proto::ServerMsg::Refused { .. }) => {
                    // Refusals are deliberate (draining listener) — not
                    // worth burning the retry budget on.
                    return (SessionEnd::Rejected, retries);
                }
                Ok(proto::ServerMsg::Error { .. }) => {
                    // e.g. our own resubmit racing a half-dead
                    // predecessor connection: transient.
                    retry_or!(SessionEnd::Lost, None);
                }
                Ok(proto::ServerMsg::Migrated { .. }) => migrated = true,
                Ok(proto::ServerMsg::Tokens { .. }) => {
                    ttft.get_or_insert_with(|| t0.elapsed().as_secs_f64());
                }
                Ok(proto::ServerMsg::Finalized { outcome, .. }) => {
                    let wall_e2e = t0.elapsed().as_secs_f64();
                    // A migration while we were reconnecting shows up
                    // only in the outcome.
                    let migrated = migrated || outcome.redispatches > 0;
                    return (
                        SessionEnd::Finished {
                            outcome,
                            wall_ttft: ttft.unwrap_or(wall_e2e),
                            wall_e2e,
                            migrated,
                        },
                        retries,
                    );
                }
                Ok(_) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_hint_is_monotone_in_load() {
        // More sessions past capacity never shrinks the hint...
        let mut prev = 0;
        for in_system in 9..64 {
            let h = retry_hint_ms(in_system, 8, 0, 1.0);
            assert!(h >= prev, "hint fell at in_system={in_system}");
            prev = h;
        }
        // ...nor does a deeper prefill backlog.
        let mut prev = 0;
        for backlog in (0..12).map(|k| k * 1000) {
            let h = retry_hint_ms(9, 8, backlog, 1.0);
            assert!(h >= prev, "hint fell at backlog={backlog}");
            prev = h;
        }
        // Strictly increasing away from the clamp, in both inputs.
        assert!(retry_hint_ms(10, 8, 0, 1.0) > retry_hint_ms(9, 8, 0, 1.0));
        assert!(
            retry_hint_ms(9, 8, 4000, 1.0) > retry_hint_ms(9, 8, 0, 1.0)
        );
    }

    #[test]
    fn retry_hint_scales_with_time_and_clamps() {
        // --time-scale compresses the hint like it compresses the serve.
        let slow = retry_hint_ms(12, 8, 2000, 1.0);
        let fast = retry_hint_ms(12, 8, 2000, 0.01);
        assert!(fast < slow);
        assert!(fast >= 1, "floor is 1ms");
        // Saturated load pegs at the 60s ceiling instead of overflowing.
        assert_eq!(
            retry_hint_ms(usize::MAX / 2, 1, usize::MAX / 2, 1.0),
            60_000
        );
        assert!(retry_hint_ms(2, 1, 0, 1e-12) >= 1);
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_bounded() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for attempt in 1..8 {
            let wa = backoff_wait(&mut a, 25, attempt);
            let wb = backoff_wait(&mut b, 25, attempt);
            assert_eq!(wa, wb, "same seed must give the same schedule");
            let full = 25u64 * (1 << (attempt - 1));
            let lo = Duration::from_secs_f64(full as f64 * 0.5 / 1000.0);
            let hi = Duration::from_secs_f64(full as f64 / 1000.0);
            assert!(wa >= lo && wa <= hi, "jitter outside [50%, 100%]");
        }
        // A different seed de-synchronizes the herd.
        let mut c = Rng::new(43);
        let mut d = Rng::new(42);
        let distinct = (1..8)
            .any(|k| backoff_wait(&mut c, 25, k) != backoff_wait(&mut d, 25, k));
        assert!(distinct);
    }
}
