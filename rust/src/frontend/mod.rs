//! Wall-clock serving runtime: a streaming session front end over the
//! stepped scheduler core.
//!
//! The virtual-time serve (`Scheduler::serve`, `serve_cluster`) answers
//! "what would this policy do" in simulated seconds; this module answers
//! it *against the wall clock*. `sart listen` binds a TCP socket and
//! accepts newline-delimited-JSON sessions ([`proto`]); every accepted
//! request is dispatched into the same stepped `Scheduler` the
//! virtual-time paths use, and scheduler steps are paced so virtual time
//! tracks wall time at a configurable exchange rate: one virtual second
//! costs `--time-scale` wall seconds (0.01 replays a 10-minute trace in
//! 6 seconds). [`ServeEvent`]s stream back to each session's socket the
//! moment its scheduler records them, so clients see tokens, prunes and
//! early stops live rather than a report after the fact.
//!
//! Threading: the scheduler stack is deliberately not `Send`-friendly
//! (it mutably borrows its engine), so ONE core thread owns every
//! engine/PRM/scheduler and runs the pump; the accept loop and the
//! per-connection handlers only talk to it through an mpsc control
//! channel, and each session gets a private response channel whose
//! hangup closes the connection. Backpressure is a bounded session
//! table: past `--max-sessions` in-flight sessions, submits are rejected
//! with a `retry_after_ms` hint instead of queueing without bound.
//! Shutdown (`{"op":"shutdown"}` or [`ListenerHandle::shutdown`]) stops
//! admitting, drains every in-flight session to its `finalized` event,
//! then exits.
//!
//! Multi-replica specs (`--replicas R`) run R independent scheduler
//! stacks off one shared wall clock, routed least-in-system at submit
//! time — the live analogue of the virtual-time cluster dispatcher.

pub mod proto;

use crate::cluster::REPLICA_SEED_STRIDE;
use crate::config::{EngineChoice, LiveConfig, Method, ServeSpec};
use crate::coordinator::{
    ClockHandle, RequestOutcome, Scheduler, ServeEvent, StepOutcome,
};
use crate::engine::Engine;
use crate::prm::PrmScorer;
use crate::server::{build_engine, build_prm, sched_cfg_for};
use crate::tokenizer::Token;
use crate::util::clock::SimClock;
use crate::workload::{Question, Request};
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Control messages from connection handlers to the core thread.
enum Ctl {
    Submit {
        dataset: String,
        question: Question,
        header: Vec<Token>,
        /// The session's private event stream; dropping it closes the
        /// connection.
        resp: mpsc::Sender<String>,
    },
    Shutdown,
}

/// A running `sart listen` instance.
pub struct ListenerHandle {
    addr: SocketAddr,
    ctl: mpsc::Sender<Ctl>,
    done: Arc<AtomicBool>,
    core: Option<JoinHandle<Result<()>>>,
    accept: Option<JoinHandle<()>>,
}

impl ListenerHandle {
    /// The bound address (`--addr 127.0.0.1:0` binds an ephemeral port;
    /// this reports the real one).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful shutdown: stop admitting sessions, drain the ones
    /// in flight. Equivalent to a client sending `{"op":"shutdown"}`.
    pub fn shutdown(&self) {
        let _ = self.ctl.send(Ctl::Shutdown);
    }

    /// Wait for the listener to finish draining and tear down. Blocks
    /// until shutdown is triggered (by [`ListenerHandle::shutdown`] or a
    /// client's `{"op":"shutdown"}`) and every in-flight session has
    /// received its `finalized` event.
    pub fn join(mut self) -> Result<()> {
        let res = match self.core.take().expect("join called once").join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("listener core thread panicked")),
        };
        self.done.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        res
    }
}

/// Bind `live.addr` and serve `spec` against the wall clock. Returns as
/// soon as the socket is listening; the serve itself runs on background
/// threads until [`ListenerHandle::join`] observes shutdown.
pub fn listen(spec: &ServeSpec, live: &LiveConfig) -> Result<ListenerHandle> {
    if !matches!(spec.engine, EngineChoice::Sim) {
        bail!(
            "sart listen requires --engine sim (decode costs are virtual \
             and paced against the wall clock via --time-scale)"
        );
    }
    if matches!(spec.method, Method::Rebase { .. }) {
        bail!(
            "sart listen does not support the rebase baseline (it has no \
             stepped scheduler to pump)"
        );
    }
    let listener = TcpListener::bind(&live.addr)
        .with_context(|| format!("binding {}", live.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
    let done = Arc::new(AtomicBool::new(false));

    let core = {
        let spec = spec.clone();
        let live = live.clone();
        let done = done.clone();
        thread::Builder::new().name("sart-core".into()).spawn(move || {
            let res = core_loop(&spec, &live, ctl_rx);
            done.store(true, Ordering::SeqCst);
            res
        })?
    };
    let accept = {
        let ctl = ctl_tx.clone();
        let done = done.clone();
        thread::Builder::new()
            .name("sart-accept".into())
            .spawn(move || accept_loop(listener, ctl, done))?
    };
    Ok(ListenerHandle {
        addr,
        ctl: ctl_tx,
        done,
        core: Some(core),
        accept: Some(accept),
    })
}

fn accept_loop(
    listener: TcpListener,
    ctl: mpsc::Sender<Ctl>,
    done: Arc<AtomicBool>,
) {
    loop {
        if done.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let ctl = ctl.clone();
                let _ = thread::Builder::new()
                    .name("sart-conn".into())
                    .spawn(move || handle_conn(stream, ctl));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => return,
        }
    }
}

/// One connection = one request line, then stream whatever the core
/// sends for this session until it drops the channel.
fn handle_conn(stream: TcpStream, ctl: mpsc::Sender<Ctl>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut line = String::new();
    if reader.read_line(&mut line).unwrap_or(0) == 0 {
        return;
    }
    let mut w = &stream;
    match proto::parse_client_line(line.trim()) {
        Err(e) => {
            let _ = writeln!(w, "{}", proto::refused_line(&format!("{e:#}")));
        }
        Ok(proto::ClientMsg::Shutdown) => {
            // The control send happens-before the ack: a client that has
            // read the ack knows any submit it opens afterwards orders
            // after the shutdown on the control channel, so it will be
            // refused — that makes the graceful-shutdown test (and any
            // script doing `shutdown; submit`) deterministic.
            let _ = ctl.send(Ctl::Shutdown);
            let _ = writeln!(w, "{}", proto::shutdown_ack_line());
        }
        Ok(proto::ClientMsg::Submit { dataset, question, header }) => {
            let (tx, rx) = mpsc::channel::<String>();
            if ctl
                .send(Ctl::Submit { dataset, question, header, resp: tx })
                .is_err()
            {
                let _ =
                    writeln!(w, "{}", proto::refused_line("listener is down"));
                return;
            }
            for ev in rx {
                if writeln!(w, "{ev}").is_err() {
                    return; // client hung up; the core notices on send
                }
                let _ = w.flush();
            }
        }
    }
}

/// The single thread that owns every engine/PRM/scheduler stack and
/// pumps them against the wall clock.
fn core_loop(
    spec: &ServeSpec,
    live: &LiveConfig,
    ctl: mpsc::Receiver<Ctl>,
) -> Result<()> {
    let replicas = spec.replicas.max(1);
    let mut engines: Vec<Box<dyn Engine>> = Vec::with_capacity(replicas);
    let mut prms: Vec<Box<dyn PrmScorer>> = Vec::with_capacity(replicas);
    let mut cfgs = Vec::with_capacity(replicas);
    for i in 0..replicas {
        // Same per-replica seed stride as the virtual-time cluster path.
        let mut rspec = spec.clone();
        rspec.seed = spec.seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
        engines.push(build_engine(&rspec)?);
        prms.push(build_prm(&rspec)?);
        cfgs.push(sched_cfg_for(&rspec)?);
    }
    let mut scheds: Vec<Scheduler> = Vec::with_capacity(replicas);
    for ((e, p), cfg) in engines.iter_mut().zip(prms.iter_mut()).zip(cfgs) {
        let mut s = Scheduler::new(
            cfg,
            e.as_mut(),
            p.as_mut(),
            ClockHandle::Sim(SimClock::new()),
        );
        s.set_emit_events(true);
        scheds.push(s);
    }

    struct Session {
        resp: mpsc::Sender<String>,
    }
    let start = Instant::now();
    let ts = live.time_scale;
    let mut sessions: HashMap<usize, Session> = HashMap::new();
    let mut last_arrival = vec![0.0f64; replicas];
    let mut next_id = 0usize;
    let mut draining = false;
    let mut pending: VecDeque<Ctl> = VecDeque::new();

    loop {
        // 1. Control messages: anything the idle wait deferred, then
        // everything currently queued.
        loop {
            let msg = match pending.pop_front() {
                Some(m) => m,
                None => match ctl.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        draining = true;
                        break;
                    }
                },
            };
            match msg {
                Ctl::Shutdown => draining = true,
                Ctl::Submit { dataset, question, header, resp } => {
                    if draining {
                        let _ =
                            resp.send(proto::refused_line("shutting down"));
                        continue;
                    }
                    if sessions.len() >= live.max_sessions {
                        let _ = resp.send(proto::rejected_line(100));
                        continue;
                    }
                    // The arrival instant is the wall clock read in
                    // virtual units; per-replica clamping keeps each
                    // scheduler's dispatch order sorted even when two
                    // submits race onto one replica within a clock tick.
                    let vnow = start.elapsed().as_secs_f64() / ts;
                    let ri = (0..replicas)
                        .min_by_key(|&i| {
                            (scheds[i].load().requests_in_system(), i)
                        })
                        .expect("at least one replica");
                    let arrival = vnow.max(last_arrival[ri]);
                    last_arrival[ri] = arrival;
                    let id = next_id;
                    next_id += 1;
                    scheds[ri].dispatch(Request {
                        id,
                        question,
                        arrival,
                        dataset,
                        header,
                    })?;
                    let _ = resp.send(proto::accepted_line(id));
                    sessions.insert(id, Session { resp });
                }
            }
        }

        // 2. Step every replica until its virtual clock catches up with
        // the wall clock (bounded per pass so control stays responsive).
        let vtarget = start.elapsed().as_secs_f64() / ts;
        let mut worked = false;
        for i in 0..replicas {
            let mut budget = 64;
            while scheds[i].now() < vtarget && budget > 0 {
                match scheds[i].step()? {
                    StepOutcome::Worked => {
                        worked = true;
                        budget -= 1;
                    }
                    StepOutcome::Idle => {
                        scheds[i].advance_clock_to(vtarget);
                        break;
                    }
                }
            }
            // 3. Stream freshly recorded events to their sessions.
            for ev in scheds[i].drain_events() {
                let id = ev.request();
                let finalized = matches!(ev, ServeEvent::Finalized { .. });
                let line = if finalized {
                    let oc = scheds[i].outcome_by_id(id);
                    proto::event_line(&ev, oc.as_ref())
                } else {
                    proto::event_line(&ev, None)
                };
                if let Some(sess) = sessions.get(&id) {
                    let _ = sess.resp.send(line); // client may have hung up
                }
                if finalized {
                    // Dropping the channel ends the handler's stream and
                    // closes the connection.
                    sessions.remove(&id);
                }
            }
        }

        if draining && sessions.is_empty() {
            return Ok(());
        }

        // 4. Pacing: nothing stepped this pass — sleep on the control
        // channel so a submit wakes the loop immediately.
        if !worked {
            match ctl.recv_timeout(Duration::from_millis(2)) {
                Ok(m) => pending.push_back(m),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => draining = true,
            }
        }
    }
}

/// What one replayed session ended as.
enum SessionEnd {
    Finished {
        outcome: Box<RequestOutcome>,
        wall_ttft: f64,
        wall_e2e: f64,
    },
    Rejected,
    Lost,
}

/// Result of replaying a trace against a live listener.
#[derive(Debug, Default)]
pub struct ReplayResult {
    /// Server-reported outcome records, one per finalized session (the
    /// same schema the virtual-time serve produces).
    pub outcomes: Vec<RequestOutcome>,
    /// Wall seconds from session open to the first `tokens` event.
    pub wall_ttft: Vec<f64>,
    /// Wall seconds from session open to `finalized`.
    pub wall_e2e: Vec<f64>,
    /// Accepted sessions that never saw `finalized` (plus transport
    /// errors) — a correct listener replays with zero.
    pub requests_lost: usize,
    /// Sessions turned away (`rejected` backpressure or `refused`).
    pub rejected: usize,
}

/// Fire `trace` at a live listener at trace rate: request `i` is
/// submitted `arrival_i * time_scale` wall seconds after the first, each
/// on its own connection, and all sessions are drained to completion.
/// With `send_shutdown`, a `{"op":"shutdown"}` is sent after the last
/// session finishes (and its ack awaited).
pub fn replay(
    addr: &str,
    trace: &[Request],
    time_scale: f64,
    send_shutdown: bool,
) -> Result<ReplayResult> {
    if !(time_scale.is_finite() && time_scale > 0.0) {
        bail!("time_scale must be a positive number, got {time_scale}");
    }
    let start = Instant::now();
    let mut handles = Vec::with_capacity(trace.len());
    for r in trace {
        let due = r.arrival * time_scale;
        let elapsed = start.elapsed().as_secs_f64();
        if due > elapsed {
            thread::sleep(Duration::from_secs_f64(due - elapsed));
        }
        let addr = addr.to_string();
        let req = r.clone();
        handles.push(thread::spawn(move || session(&addr, &req)));
    }
    let mut res = ReplayResult::default();
    for h in handles {
        match h.join() {
            Ok(Ok(SessionEnd::Finished { outcome, wall_ttft, wall_e2e })) => {
                res.outcomes.push(*outcome);
                res.wall_ttft.push(wall_ttft);
                res.wall_e2e.push(wall_e2e);
            }
            Ok(Ok(SessionEnd::Rejected)) => res.rejected += 1,
            Ok(Ok(SessionEnd::Lost)) | Ok(Err(_)) | Err(_) => {
                res.requests_lost += 1;
            }
        }
    }
    if send_shutdown {
        let stream =
            TcpStream::connect(addr).context("connecting for shutdown")?;
        let mut w = &stream;
        writeln!(w, "{}", proto::shutdown_line())?;
        let _ = w.flush();
        let mut line = String::new();
        let _ = BufReader::new(stream).read_line(&mut line); // await ack
    }
    Ok(res)
}

/// Drive one session: submit, then read events until `finalized`.
fn session(addr: &str, req: &Request) -> Result<SessionEnd> {
    let stream = TcpStream::connect(addr)?;
    let t0 = Instant::now();
    {
        let mut w = &stream;
        writeln!(
            w,
            "{}",
            proto::submit_line(&req.dataset, &req.question, &req.header)
        )?;
        w.flush()?;
    }
    let mut reader = BufReader::new(stream);
    let mut ttft: Option<f64> = None;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(SessionEnd::Lost); // server hung up mid-session
        }
        match proto::parse_server_line(line.trim())? {
            proto::ServerMsg::Rejected { .. }
            | proto::ServerMsg::Refused { .. } => {
                return Ok(SessionEnd::Rejected)
            }
            proto::ServerMsg::Tokens { .. } => {
                ttft.get_or_insert_with(|| t0.elapsed().as_secs_f64());
            }
            proto::ServerMsg::Finalized { outcome, .. } => {
                let wall_e2e = t0.elapsed().as_secs_f64();
                return Ok(SessionEnd::Finished {
                    outcome,
                    wall_ttft: ttft.unwrap_or(wall_e2e),
                    wall_e2e,
                });
            }
            _ => {}
        }
    }
}
