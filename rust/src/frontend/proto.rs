//! The wire protocol of the live front end: newline-delimited JSON.
//!
//! One connection carries one *or more* sessions. The client sends
//! request lines (`{"op":"submit",...}` or `{"op":"shutdown"}`); the
//! server answers each submit with a control line, then streams event
//! lines, one per [`ServeEvent`], and closes the connection once every
//! session submitted on it has seen its `finalized` line. Submits may be
//! pipelined: a submit can carry a caller-chosen `client_id`, echoed on
//! the `accepted` line, so the client can correlate the server-assigned
//! request id of each session (all later event lines carry only the
//! request id). A resubmitted `client_id` is deduplicated server-side —
//! the reconnecting client reattaches to its in-flight session (or gets
//! the retained `finalized` line if it already completed) instead of
//! dispatching the work twice.
//!
//! A malformed line is answered with a structured `error` line and the
//! connection keeps serving; `refused` is reserved for submits the
//! listener will not take (draining after shutdown, listener down).
//! Everything is hand-rolled over [`crate::util::json`] — no
//! serialization dependencies.
//!
//! The `finalized` line embeds the full [`RequestOutcome`] record, so a
//! replay client can reconstruct the exact `RunOutput` schema the
//! virtual-time server writes and every bench/gate tool keeps working
//! on live runs. Under a live fault plan a session may see a `migrated`
//! line (its replica died; the request re-dispatched to a survivor)
//! before its single `finalized`; under slow-reader backpressure the
//! `finalized` line reports how many non-terminal `tokens` lines were
//! shed on its way there.

use crate::coordinator::{RequestOutcome, ServeEvent};
use crate::tokenizer::Token;
use crate::util::json::Json;
use crate::workload::{Question, NUM_KEYS};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn unum(x: usize) -> Json {
    Json::Num(x as f64)
}

fn tokens_json(toks: &[Token]) -> Json {
    Json::Arr(toks.iter().map(|&t| Json::Num(t as f64)).collect())
}

fn tokens_from(j: &Json, what: &str) -> Result<Vec<Token>> {
    j.as_arr()
        .with_context(|| format!("`{what}` must be an array"))?
        .iter()
        .map(|t| {
            t.as_i64()
                .map(|v| v as Token)
                .with_context(|| format!("`{what}` entries must be numbers"))
        })
        .collect()
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .with_context(|| format!("`{key}` must be a number"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.req(key)?
        .as_f64()
        .with_context(|| format!("`{key}` must be a number"))
}

/// Serialize one [`RequestOutcome`] (the `outcome` field of a
/// `finalized` line and the `outcomes` array of a `RunOutput` dump).
pub fn outcome_to_json(o: &RequestOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("id".into(), unum(o.id));
    m.insert("dataset".into(), Json::Str(o.dataset.clone()));
    m.insert("arrival".into(), num(o.arrival));
    m.insert("admitted_at".into(), num(o.admitted_at));
    m.insert("prefill_done_at".into(), num(o.prefill_done_at));
    m.insert("finished_at".into(), num(o.finished_at));
    m.insert(
        "answer".into(),
        o.answer.map_or(Json::Null, |a| unum(a as usize)),
    );
    m.insert("truth".into(), unum(o.truth as usize));
    m.insert("branches_started".into(), unum(o.branches_started));
    m.insert("branches_pruned".into(), unum(o.branches_pruned));
    m.insert("branches_completed".into(), unum(o.branches_completed));
    m.insert("tokens_generated".into(), unum(o.tokens_generated));
    m.insert(
        "response_lengths".into(),
        Json::Arr(o.response_lengths.iter().map(|&l| unum(l)).collect()),
    );
    m.insert("cached_prompt_tokens".into(), unum(o.cached_prompt_tokens));
    m.insert("redispatches".into(), unum(o.redispatches));
    m.insert("preemptions".into(), unum(o.preemptions));
    Json::Obj(m)
}

/// Inverse of [`outcome_to_json`].
pub fn outcome_from_json(j: &Json) -> Result<RequestOutcome> {
    Ok(RequestOutcome {
        id: req_usize(j, "id")?,
        dataset: j
            .req("dataset")?
            .as_str()
            .context("`dataset` must be a string")?
            .to_string(),
        arrival: req_f64(j, "arrival")?,
        admitted_at: req_f64(j, "admitted_at")?,
        prefill_done_at: req_f64(j, "prefill_done_at")?,
        finished_at: req_f64(j, "finished_at")?,
        answer: match j.req("answer")? {
            Json::Null => None,
            v => Some(
                v.as_usize().context("`answer` must be a number or null")?
                    as u8,
            ),
        },
        truth: req_usize(j, "truth")? as u8,
        branches_started: req_usize(j, "branches_started")?,
        branches_pruned: req_usize(j, "branches_pruned")?,
        branches_completed: req_usize(j, "branches_completed")?,
        tokens_generated: req_usize(j, "tokens_generated")?,
        response_lengths: j
            .req("response_lengths")?
            .as_arr()
            .context("`response_lengths` must be an array")?
            .iter()
            .map(|l| {
                l.as_usize()
                    .context("`response_lengths` entries must be numbers")
            })
            .collect::<Result<_>>()?,
        cached_prompt_tokens: req_usize(j, "cached_prompt_tokens")?,
        redispatches: req_usize(j, "redispatches")?,
        // Absent in dumps that predate memory-pressure serving.
        preemptions: match j.get("preemptions") {
            Some(v) => {
                v.as_usize().context("`preemptions` must be a number")?
            }
            None => 0,
        },
    })
}

fn question_to_json(q: &Question) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "mapping".into(),
        Json::Arr(q.mapping.iter().map(|&v| unum(v as usize)).collect()),
    );
    m.insert("start".into(), unum(q.start as usize));
    m.insert("hops".into(), unum(q.hops as usize));
    Json::Obj(m)
}

fn question_from_json(j: &Json) -> Result<Question> {
    let arr = j
        .req("mapping")?
        .as_arr()
        .context("`mapping` must be an array")?;
    if arr.len() != NUM_KEYS {
        bail!("`mapping` must have exactly {NUM_KEYS} entries");
    }
    let mut mapping = [0u8; NUM_KEYS];
    for (i, v) in arr.iter().enumerate() {
        mapping[i] =
            v.as_usize().context("`mapping` entries must be numbers")? as u8;
    }
    Ok(Question {
        mapping,
        start: req_usize(j, "start")? as u8,
        hops: req_usize(j, "hops")? as u8,
    })
}

/// A parsed client → server request line.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    Submit {
        dataset: String,
        question: Question,
        header: Vec<Token>,
        /// Caller-chosen correlation id: echoed on the `accepted` line
        /// and the key for server-side resubmit deduplication. `None`
        /// keeps the PR-7 single-shot wire format byte-identical.
        client_id: Option<String>,
    },
    Shutdown,
}

/// One `{"op":"submit",...}` line (no client id — the single-shot form).
pub fn submit_line(
    dataset: &str,
    question: &Question,
    header: &[Token],
) -> String {
    submit_line_with(dataset, question, header, None)
}

/// [`submit_line`] carrying an optional client-assigned correlation id.
pub fn submit_line_with(
    dataset: &str,
    question: &Question,
    header: &[Token],
    client_id: Option<&str>,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("submit".into()));
    m.insert("dataset".into(), Json::Str(dataset.into()));
    m.insert("question".into(), question_to_json(question));
    m.insert("header".into(), tokens_json(header));
    if let Some(cid) = client_id {
        m.insert("client_id".into(), Json::Str(cid.into()));
    }
    Json::Obj(m).to_string()
}

/// The `{"op":"shutdown"}` line.
pub fn shutdown_line() -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("shutdown".into()));
    Json::Obj(m).to_string()
}

/// Parse one client request line.
pub fn parse_client_line(line: &str) -> Result<ClientMsg> {
    let j = Json::parse(line).context("malformed request line")?;
    match j.req("op")?.as_str().context("`op` must be a string")? {
        "submit" => Ok(ClientMsg::Submit {
            dataset: j
                .req("dataset")?
                .as_str()
                .context("`dataset` must be a string")?
                .to_string(),
            question: question_from_json(j.req("question")?)?,
            header: tokens_from(j.req("header")?, "header")?,
            client_id: match j.get("client_id") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .context("`client_id` must be a string")?
                        .to_string(),
                ),
            },
        }),
        "shutdown" => Ok(ClientMsg::Shutdown),
        other => bail!("unknown op `{other}` (submit|shutdown)"),
    }
}

/// A parsed server → client event line.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// Session admitted to the session table; `request` is the id every
    /// later event of this session carries. Echoes the submit's
    /// `client_id` (if any) so pipelined submits correlate.
    Accepted { request: usize, client_id: Option<String> },
    /// Bounded-queue backpressure: the session table is full. The retry
    /// hint is load-derived (table occupancy + prefill backlog, scaled
    /// to wall milliseconds) and `queue_position` is where this submit
    /// would have stood in the wait line (1 = next slot to free).
    Rejected { retry_after_ms: u64, queue_position: usize },
    /// The listener will not take this submit (draining, down).
    Refused { error: String },
    /// A malformed or abusive request line; the connection keeps
    /// serving — only the offending line is answered, never the socket.
    Error { error: String },
    /// Acknowledgement of a `shutdown` op.
    ShutdownAck,
    Admitted { request: usize, t: f64 },
    Tokens { request: usize, branch: usize, tokens: Vec<Token> },
    Pruned { request: usize, branch: usize, t: f64 },
    Capped { request: usize, branch: usize, t: f64 },
    /// A running branch swapped out under memory pressure (its pages
    /// went to a higher-priority admission); the session keeps
    /// streaming — the branch resumes later by recomputation and its
    /// `tokens` lines pick up where they left off. The outcome's
    /// `preemptions` counts these.
    Preempted { request: usize, branch: usize, t: f64 },
    EarlyStop { request: usize, t: f64 },
    /// The session's replica failed; its request re-dispatched from
    /// replica `from` to `to` without the socket closing. `hops` is the
    /// cumulative migration count (== the outcome's `redispatches`).
    Migrated { request: usize, from: usize, to: usize, hops: usize, t: f64 },
    Finalized {
        request: usize,
        answer: Option<u8>,
        votes: usize,
        t: f64,
        /// `tokens` lines shed under slow-reader backpressure (0 and
        /// absent on the wire for a well-drained session).
        shed: usize,
        outcome: Box<RequestOutcome>,
    },
}

pub fn accepted_line(request: usize) -> String {
    accepted_line_with(request, None)
}

/// [`accepted_line`] echoing the submit's client-assigned id.
pub fn accepted_line_with(request: usize, client_id: Option<&str>) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("accepted".into()));
    m.insert("request".into(), unum(request));
    if let Some(cid) = client_id {
        m.insert("client_id".into(), Json::Str(cid.into()));
    }
    Json::Obj(m).to_string()
}

pub fn rejected_line(retry_after_ms: u64, queue_position: usize) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("rejected".into()));
    m.insert("retry_after_ms".into(), unum(retry_after_ms as usize));
    m.insert("queue_position".into(), unum(queue_position));
    Json::Obj(m).to_string()
}

pub fn refused_line(error: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("refused".into()));
    m.insert("error".into(), Json::Str(error.into()));
    Json::Obj(m).to_string()
}

/// A recoverable per-line failure (malformed JSON, unknown op, oversized
/// line, duplicate client id): answered in-band, connection preserved.
pub fn error_line(error: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("error".into()));
    m.insert("error".into(), Json::Str(error.into()));
    Json::Obj(m).to_string()
}

/// The live fault path's migration notice (see [`ServerMsg::Migrated`]).
pub fn migrated_line(
    request: usize,
    from: usize,
    to: usize,
    hops: usize,
    t: f64,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("migrated".into()));
    m.insert("request".into(), unum(request));
    m.insert("from".into(), unum(from));
    m.insert("to".into(), unum(to));
    m.insert("hops".into(), unum(hops));
    m.insert("t".into(), num(t));
    Json::Obj(m).to_string()
}

pub fn shutdown_ack_line() -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("shutdown_ack".into()));
    Json::Obj(m).to_string()
}

/// Serialize one scheduler [`ServeEvent`] as a server event line. A
/// `Finalized` event carries the full outcome record when the caller
/// supplies one (the listener always does), plus a `shed` count when any
/// `tokens` lines were dropped under backpressure (`shed == 0` keeps the
/// line byte-identical to the PR-7 format).
pub fn event_line(
    ev: &ServeEvent,
    outcome: Option<&RequestOutcome>,
    shed: usize,
) -> String {
    let mut m = BTreeMap::new();
    match ev {
        ServeEvent::Admitted { request, at } => {
            m.insert("event".into(), Json::Str("admitted".into()));
            m.insert("request".into(), unum(*request));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::BranchTokens { request, branch, tokens } => {
            m.insert("event".into(), Json::Str("tokens".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("tokens".into(), tokens_json(tokens));
        }
        ServeEvent::BranchPruned { request, branch, at } => {
            m.insert("event".into(), Json::Str("pruned".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::BranchCapped { request, branch, at } => {
            m.insert("event".into(), Json::Str("capped".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::BranchPreempted { request, branch, at } => {
            m.insert("event".into(), Json::Str("preempted".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::EarlyStop { request, at } => {
            m.insert("event".into(), Json::Str("early_stop".into()));
            m.insert("request".into(), unum(*request));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::Finalized { request, answer, votes, at } => {
            m.insert("event".into(), Json::Str("finalized".into()));
            m.insert("request".into(), unum(*request));
            m.insert(
                "answer".into(),
                answer.map_or(Json::Null, |a| unum(a as usize)),
            );
            m.insert("votes".into(), unum(*votes));
            m.insert("t".into(), num(*at));
            if shed > 0 {
                m.insert("shed".into(), unum(shed));
            }
            if let Some(o) = outcome {
                m.insert("outcome".into(), outcome_to_json(o));
            }
        }
    }
    Json::Obj(m).to_string()
}

/// Parse one server event line.
pub fn parse_server_line(line: &str) -> Result<ServerMsg> {
    let j = Json::parse(line).context("malformed event line")?;
    let ev = j.req("event")?.as_str().context("`event` must be a string")?;
    Ok(match ev {
        "accepted" => ServerMsg::Accepted {
            request: req_usize(&j, "request")?,
            client_id: match j.get("client_id") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .context("`client_id` must be a string")?
                        .to_string(),
                ),
            },
        },
        "rejected" => ServerMsg::Rejected {
            retry_after_ms: req_usize(&j, "retry_after_ms")? as u64,
            queue_position: req_usize(&j, "queue_position")?,
        },
        "refused" => ServerMsg::Refused {
            error: j
                .req("error")?
                .as_str()
                .context("`error` must be a string")?
                .to_string(),
        },
        "error" => ServerMsg::Error {
            error: j
                .req("error")?
                .as_str()
                .context("`error` must be a string")?
                .to_string(),
        },
        "shutdown_ack" => ServerMsg::ShutdownAck,
        "migrated" => ServerMsg::Migrated {
            request: req_usize(&j, "request")?,
            from: req_usize(&j, "from")?,
            to: req_usize(&j, "to")?,
            hops: req_usize(&j, "hops")?,
            t: req_f64(&j, "t")?,
        },
        "admitted" => ServerMsg::Admitted {
            request: req_usize(&j, "request")?,
            t: req_f64(&j, "t")?,
        },
        "tokens" => ServerMsg::Tokens {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            tokens: tokens_from(j.req("tokens")?, "tokens")?,
        },
        "pruned" => ServerMsg::Pruned {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            t: req_f64(&j, "t")?,
        },
        "capped" => ServerMsg::Capped {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            t: req_f64(&j, "t")?,
        },
        "preempted" => ServerMsg::Preempted {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            t: req_f64(&j, "t")?,
        },
        "early_stop" => ServerMsg::EarlyStop {
            request: req_usize(&j, "request")?,
            t: req_f64(&j, "t")?,
        },
        "finalized" => ServerMsg::Finalized {
            request: req_usize(&j, "request")?,
            answer: match j.req("answer")? {
                Json::Null => None,
                v => Some(
                    v.as_usize()
                        .context("`answer` must be a number or null")?
                        as u8,
                ),
            },
            votes: req_usize(&j, "votes")?,
            t: req_f64(&j, "t")?,
            shed: match j.get("shed") {
                None => 0,
                Some(v) => {
                    v.as_usize().context("`shed` must be a number")?
                }
            },
            outcome: Box::new(outcome_from_json(j.req("outcome")?)?),
        },
        other => bail!("unknown event `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::TaskSpec;

    fn outcome() -> RequestOutcome {
        RequestOutcome {
            id: 7,
            dataset: "synth-gaokao".into(),
            arrival: 0.5,
            admitted_at: 0.75,
            prefill_done_at: 1.0,
            finished_at: 4.25,
            answer: Some(3),
            truth: 3,
            branches_started: 4,
            branches_pruned: 1,
            branches_completed: 2,
            tokens_generated: 120,
            response_lengths: vec![40, 80],
            cached_prompt_tokens: 16,
            redispatches: 0,
            preemptions: 0,
        }
    }

    #[test]
    fn outcome_round_trips() {
        let o = outcome();
        let line = outcome_to_json(&o).to_string();
        let back =
            outcome_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, o);
        // None answer survives as JSON null.
        let mut o = outcome();
        o.answer = None;
        let back = outcome_from_json(
            &Json::parse(&outcome_to_json(&o).to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.answer, None);
    }

    #[test]
    fn submit_round_trips() {
        let task = TaskSpec::by_name("synth-gaokao").unwrap();
        let q = Question::sample(&task, &mut Rng::new(7));
        let line = submit_line("synth-gaokao", &q, &[5, 6, 7]);
        assert!(!line.contains("client_id"));
        match parse_client_line(&line).unwrap() {
            ClientMsg::Submit { dataset, question, header, client_id } => {
                assert_eq!(dataset, "synth-gaokao");
                assert_eq!(question, q);
                assert_eq!(header, vec![5, 6, 7]);
                assert_eq!(client_id, None);
            }
            other => panic!("wrong message: {other:?}"),
        }
        let line =
            submit_line_with("synth-gaokao", &q, &[5, 6, 7], Some("r7-0"));
        match parse_client_line(&line).unwrap() {
            ClientMsg::Submit { client_id, .. } => {
                assert_eq!(client_id.as_deref(), Some("r7-0"));
            }
            other => panic!("wrong message: {other:?}"),
        }
        assert_eq!(
            parse_client_line(&shutdown_line()).unwrap(),
            ClientMsg::Shutdown
        );
        assert!(parse_client_line("{\"op\":\"wat\"}").is_err());
        assert!(parse_client_line("not json").is_err());
    }

    #[test]
    fn every_event_variant_round_trips() {
        let cases = vec![
            ServeEvent::Admitted { request: 3, at: 1.5 },
            ServeEvent::BranchTokens {
                request: 3,
                branch: 2,
                tokens: vec![10, 11, 2],
            },
            ServeEvent::BranchPruned { request: 3, branch: 1, at: 2.0 },
            ServeEvent::BranchCapped { request: 3, branch: 0, at: 2.5 },
            ServeEvent::BranchPreempted { request: 3, branch: 2, at: 2.75 },
            ServeEvent::EarlyStop { request: 3, at: 3.0 },
        ];
        for ev in &cases {
            let msg = parse_server_line(&event_line(ev, None, 0)).unwrap();
            match (ev, &msg) {
                (
                    ServeEvent::Admitted { request, at },
                    ServerMsg::Admitted { request: r, t },
                ) => {
                    assert_eq!((r, t), (request, at));
                }
                (
                    ServeEvent::BranchTokens { request, branch, tokens },
                    ServerMsg::Tokens { request: r, branch: b, tokens: tk },
                ) => {
                    assert_eq!((r, b, tk), (request, branch, tokens));
                }
                (
                    ServeEvent::BranchPruned { request, branch, at },
                    ServerMsg::Pruned { request: r, branch: b, t },
                ) => {
                    assert_eq!((r, b, t), (request, branch, at));
                }
                (
                    ServeEvent::BranchCapped { request, branch, at },
                    ServerMsg::Capped { request: r, branch: b, t },
                ) => {
                    assert_eq!((r, b, t), (request, branch, at));
                }
                (
                    ServeEvent::BranchPreempted { request, branch, at },
                    ServerMsg::Preempted { request: r, branch: b, t },
                ) => {
                    assert_eq!((r, b, t), (request, branch, at));
                }
                (
                    ServeEvent::EarlyStop { request, at },
                    ServerMsg::EarlyStop { request: r, t },
                ) => {
                    assert_eq!((r, t), (request, at));
                }
                (ev, msg) => panic!("mismatched parse: {ev:?} -> {msg:?}"),
            }
        }
        // Finalized carries the embedded outcome.
        let o = outcome();
        let ev = ServeEvent::Finalized {
            request: 7,
            answer: Some(3),
            votes: 2,
            at: 4.25,
        };
        let clean = event_line(&ev, Some(&o), 0);
        assert!(!clean.contains("\"shed\""));
        match parse_server_line(&clean).unwrap() {
            ServerMsg::Finalized {
                request,
                answer,
                votes,
                t,
                shed,
                outcome,
            } => {
                assert_eq!(request, 7);
                assert_eq!(answer, Some(3));
                assert_eq!(votes, 2);
                assert_eq!(t, 4.25);
                assert_eq!(shed, 0);
                assert_eq!(*outcome, o);
            }
            other => panic!("wrong message: {other:?}"),
        }
        // A shed count rides on the finalized line only when nonzero.
        let shedded = event_line(&ev, Some(&o), 5);
        match parse_server_line(&shedded).unwrap() {
            ServerMsg::Finalized { shed, .. } => assert_eq!(shed, 5),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn control_lines_round_trip() {
        let bare = accepted_line(9);
        assert!(!bare.contains("client_id"));
        assert_eq!(
            parse_server_line(&bare).unwrap(),
            ServerMsg::Accepted { request: 9, client_id: None }
        );
        assert_eq!(
            parse_server_line(&accepted_line_with(9, Some("r7-9"))).unwrap(),
            ServerMsg::Accepted { request: 9, client_id: Some("r7-9".into()) }
        );
        assert_eq!(
            parse_server_line(&rejected_line(100, 3)).unwrap(),
            ServerMsg::Rejected { retry_after_ms: 100, queue_position: 3 }
        );
        assert_eq!(
            parse_server_line(&refused_line("shutting down")).unwrap(),
            ServerMsg::Refused { error: "shutting down".into() }
        );
        assert_eq!(
            parse_server_line(&error_line("malformed request line")).unwrap(),
            ServerMsg::Error { error: "malformed request line".into() }
        );
        assert_eq!(
            parse_server_line(&migrated_line(4, 1, 0, 2, 3.5)).unwrap(),
            ServerMsg::Migrated { request: 4, from: 1, to: 0, hops: 2, t: 3.5 }
        );
        assert_eq!(
            parse_server_line(&shutdown_ack_line()).unwrap(),
            ServerMsg::ShutdownAck
        );
        assert!(parse_server_line("{\"event\":\"wat\"}").is_err());
    }
}
