//! The wire protocol of the live front end: newline-delimited JSON.
//!
//! One connection carries one session. The client opens with a single
//! request line (`{"op":"submit",...}` or `{"op":"shutdown"}`); the
//! server answers with a stream of event lines, one per
//! [`ServeEvent`], closing the connection after `finalized` (or after a
//! single `rejected`/`refused` line). Everything is hand-rolled over
//! [`crate::util::json`] — no serialization dependencies.
//!
//! The `finalized` line embeds the full [`RequestOutcome`] record, so a
//! replay client can reconstruct the exact `RunOutput` schema the
//! virtual-time server writes and every bench/gate tool keeps working
//! on live runs.

use crate::coordinator::{RequestOutcome, ServeEvent};
use crate::tokenizer::Token;
use crate::util::json::Json;
use crate::workload::{Question, NUM_KEYS};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn unum(x: usize) -> Json {
    Json::Num(x as f64)
}

fn tokens_json(toks: &[Token]) -> Json {
    Json::Arr(toks.iter().map(|&t| Json::Num(t as f64)).collect())
}

fn tokens_from(j: &Json, what: &str) -> Result<Vec<Token>> {
    j.as_arr()
        .with_context(|| format!("`{what}` must be an array"))?
        .iter()
        .map(|t| {
            t.as_i64()
                .map(|v| v as Token)
                .with_context(|| format!("`{what}` entries must be numbers"))
        })
        .collect()
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .with_context(|| format!("`{key}` must be a number"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.req(key)?
        .as_f64()
        .with_context(|| format!("`{key}` must be a number"))
}

/// Serialize one [`RequestOutcome`] (the `outcome` field of a
/// `finalized` line and the `outcomes` array of a `RunOutput` dump).
pub fn outcome_to_json(o: &RequestOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("id".into(), unum(o.id));
    m.insert("dataset".into(), Json::Str(o.dataset.clone()));
    m.insert("arrival".into(), num(o.arrival));
    m.insert("admitted_at".into(), num(o.admitted_at));
    m.insert("prefill_done_at".into(), num(o.prefill_done_at));
    m.insert("finished_at".into(), num(o.finished_at));
    m.insert(
        "answer".into(),
        o.answer.map_or(Json::Null, |a| unum(a as usize)),
    );
    m.insert("truth".into(), unum(o.truth as usize));
    m.insert("branches_started".into(), unum(o.branches_started));
    m.insert("branches_pruned".into(), unum(o.branches_pruned));
    m.insert("branches_completed".into(), unum(o.branches_completed));
    m.insert("tokens_generated".into(), unum(o.tokens_generated));
    m.insert(
        "response_lengths".into(),
        Json::Arr(o.response_lengths.iter().map(|&l| unum(l)).collect()),
    );
    m.insert("cached_prompt_tokens".into(), unum(o.cached_prompt_tokens));
    m.insert("redispatches".into(), unum(o.redispatches));
    Json::Obj(m)
}

/// Inverse of [`outcome_to_json`].
pub fn outcome_from_json(j: &Json) -> Result<RequestOutcome> {
    Ok(RequestOutcome {
        id: req_usize(j, "id")?,
        dataset: j
            .req("dataset")?
            .as_str()
            .context("`dataset` must be a string")?
            .to_string(),
        arrival: req_f64(j, "arrival")?,
        admitted_at: req_f64(j, "admitted_at")?,
        prefill_done_at: req_f64(j, "prefill_done_at")?,
        finished_at: req_f64(j, "finished_at")?,
        answer: match j.req("answer")? {
            Json::Null => None,
            v => Some(
                v.as_usize().context("`answer` must be a number or null")?
                    as u8,
            ),
        },
        truth: req_usize(j, "truth")? as u8,
        branches_started: req_usize(j, "branches_started")?,
        branches_pruned: req_usize(j, "branches_pruned")?,
        branches_completed: req_usize(j, "branches_completed")?,
        tokens_generated: req_usize(j, "tokens_generated")?,
        response_lengths: j
            .req("response_lengths")?
            .as_arr()
            .context("`response_lengths` must be an array")?
            .iter()
            .map(|l| {
                l.as_usize()
                    .context("`response_lengths` entries must be numbers")
            })
            .collect::<Result<_>>()?,
        cached_prompt_tokens: req_usize(j, "cached_prompt_tokens")?,
        redispatches: req_usize(j, "redispatches")?,
    })
}

fn question_to_json(q: &Question) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "mapping".into(),
        Json::Arr(q.mapping.iter().map(|&v| unum(v as usize)).collect()),
    );
    m.insert("start".into(), unum(q.start as usize));
    m.insert("hops".into(), unum(q.hops as usize));
    Json::Obj(m)
}

fn question_from_json(j: &Json) -> Result<Question> {
    let arr = j
        .req("mapping")?
        .as_arr()
        .context("`mapping` must be an array")?;
    if arr.len() != NUM_KEYS {
        bail!("`mapping` must have exactly {NUM_KEYS} entries");
    }
    let mut mapping = [0u8; NUM_KEYS];
    for (i, v) in arr.iter().enumerate() {
        mapping[i] =
            v.as_usize().context("`mapping` entries must be numbers")? as u8;
    }
    Ok(Question {
        mapping,
        start: req_usize(j, "start")? as u8,
        hops: req_usize(j, "hops")? as u8,
    })
}

/// A parsed client → server request line.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    Submit { dataset: String, question: Question, header: Vec<Token> },
    Shutdown,
}

/// One `{"op":"submit",...}` line.
pub fn submit_line(
    dataset: &str,
    question: &Question,
    header: &[Token],
) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("submit".into()));
    m.insert("dataset".into(), Json::Str(dataset.into()));
    m.insert("question".into(), question_to_json(question));
    m.insert("header".into(), tokens_json(header));
    Json::Obj(m).to_string()
}

/// The `{"op":"shutdown"}` line.
pub fn shutdown_line() -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("shutdown".into()));
    Json::Obj(m).to_string()
}

/// Parse one client request line.
pub fn parse_client_line(line: &str) -> Result<ClientMsg> {
    let j = Json::parse(line).context("malformed request line")?;
    match j.req("op")?.as_str().context("`op` must be a string")? {
        "submit" => Ok(ClientMsg::Submit {
            dataset: j
                .req("dataset")?
                .as_str()
                .context("`dataset` must be a string")?
                .to_string(),
            question: question_from_json(j.req("question")?)?,
            header: tokens_from(j.req("header")?, "header")?,
        }),
        "shutdown" => Ok(ClientMsg::Shutdown),
        other => bail!("unknown op `{other}` (submit|shutdown)"),
    }
}

/// A parsed server → client event line.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// Session admitted to the session table; `request` is the id every
    /// later event of this session carries.
    Accepted { request: usize },
    /// Bounded-queue backpressure: the session table is full, retry
    /// after the hinted delay.
    Rejected { retry_after_ms: u64 },
    /// The listener is shutting down (or the request line was invalid).
    Refused { error: String },
    /// Acknowledgement of a `shutdown` op.
    ShutdownAck,
    Admitted { request: usize, t: f64 },
    Tokens { request: usize, branch: usize, tokens: Vec<Token> },
    Pruned { request: usize, branch: usize, t: f64 },
    Capped { request: usize, branch: usize, t: f64 },
    EarlyStop { request: usize, t: f64 },
    Finalized {
        request: usize,
        answer: Option<u8>,
        votes: usize,
        t: f64,
        outcome: Box<RequestOutcome>,
    },
}

pub fn accepted_line(request: usize) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("accepted".into()));
    m.insert("request".into(), unum(request));
    Json::Obj(m).to_string()
}

pub fn rejected_line(retry_after_ms: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("rejected".into()));
    m.insert("retry_after_ms".into(), unum(retry_after_ms as usize));
    Json::Obj(m).to_string()
}

pub fn refused_line(error: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("refused".into()));
    m.insert("error".into(), Json::Str(error.into()));
    Json::Obj(m).to_string()
}

pub fn shutdown_ack_line() -> String {
    let mut m = BTreeMap::new();
    m.insert("event".into(), Json::Str("shutdown_ack".into()));
    Json::Obj(m).to_string()
}

/// Serialize one scheduler [`ServeEvent`] as a server event line. A
/// `Finalized` event carries the full outcome record when the caller
/// supplies one (the listener always does).
pub fn event_line(ev: &ServeEvent, outcome: Option<&RequestOutcome>) -> String {
    let mut m = BTreeMap::new();
    match ev {
        ServeEvent::Admitted { request, at } => {
            m.insert("event".into(), Json::Str("admitted".into()));
            m.insert("request".into(), unum(*request));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::BranchTokens { request, branch, tokens } => {
            m.insert("event".into(), Json::Str("tokens".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("tokens".into(), tokens_json(tokens));
        }
        ServeEvent::BranchPruned { request, branch, at } => {
            m.insert("event".into(), Json::Str("pruned".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::BranchCapped { request, branch, at } => {
            m.insert("event".into(), Json::Str("capped".into()));
            m.insert("request".into(), unum(*request));
            m.insert("branch".into(), unum(*branch));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::EarlyStop { request, at } => {
            m.insert("event".into(), Json::Str("early_stop".into()));
            m.insert("request".into(), unum(*request));
            m.insert("t".into(), num(*at));
        }
        ServeEvent::Finalized { request, answer, votes, at } => {
            m.insert("event".into(), Json::Str("finalized".into()));
            m.insert("request".into(), unum(*request));
            m.insert(
                "answer".into(),
                answer.map_or(Json::Null, |a| unum(a as usize)),
            );
            m.insert("votes".into(), unum(*votes));
            m.insert("t".into(), num(*at));
            if let Some(o) = outcome {
                m.insert("outcome".into(), outcome_to_json(o));
            }
        }
    }
    Json::Obj(m).to_string()
}

/// Parse one server event line.
pub fn parse_server_line(line: &str) -> Result<ServerMsg> {
    let j = Json::parse(line).context("malformed event line")?;
    let ev = j.req("event")?.as_str().context("`event` must be a string")?;
    Ok(match ev {
        "accepted" => ServerMsg::Accepted { request: req_usize(&j, "request")? },
        "rejected" => ServerMsg::Rejected {
            retry_after_ms: req_usize(&j, "retry_after_ms")? as u64,
        },
        "refused" => ServerMsg::Refused {
            error: j
                .req("error")?
                .as_str()
                .context("`error` must be a string")?
                .to_string(),
        },
        "shutdown_ack" => ServerMsg::ShutdownAck,
        "admitted" => ServerMsg::Admitted {
            request: req_usize(&j, "request")?,
            t: req_f64(&j, "t")?,
        },
        "tokens" => ServerMsg::Tokens {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            tokens: tokens_from(j.req("tokens")?, "tokens")?,
        },
        "pruned" => ServerMsg::Pruned {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            t: req_f64(&j, "t")?,
        },
        "capped" => ServerMsg::Capped {
            request: req_usize(&j, "request")?,
            branch: req_usize(&j, "branch")?,
            t: req_f64(&j, "t")?,
        },
        "early_stop" => ServerMsg::EarlyStop {
            request: req_usize(&j, "request")?,
            t: req_f64(&j, "t")?,
        },
        "finalized" => ServerMsg::Finalized {
            request: req_usize(&j, "request")?,
            answer: match j.req("answer")? {
                Json::Null => None,
                v => Some(
                    v.as_usize()
                        .context("`answer` must be a number or null")?
                        as u8,
                ),
            },
            votes: req_usize(&j, "votes")?,
            t: req_f64(&j, "t")?,
            outcome: Box::new(outcome_from_json(j.req("outcome")?)?),
        },
        other => bail!("unknown event `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::TaskSpec;

    fn outcome() -> RequestOutcome {
        RequestOutcome {
            id: 7,
            dataset: "synth-gaokao".into(),
            arrival: 0.5,
            admitted_at: 0.75,
            prefill_done_at: 1.0,
            finished_at: 4.25,
            answer: Some(3),
            truth: 3,
            branches_started: 4,
            branches_pruned: 1,
            branches_completed: 2,
            tokens_generated: 120,
            response_lengths: vec![40, 80],
            cached_prompt_tokens: 16,
            redispatches: 0,
        }
    }

    #[test]
    fn outcome_round_trips() {
        let o = outcome();
        let line = outcome_to_json(&o).to_string();
        let back =
            outcome_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, o);
        // None answer survives as JSON null.
        let mut o = outcome();
        o.answer = None;
        let back = outcome_from_json(
            &Json::parse(&outcome_to_json(&o).to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.answer, None);
    }

    #[test]
    fn submit_round_trips() {
        let task = TaskSpec::by_name("synth-gaokao").unwrap();
        let q = Question::sample(&task, &mut Rng::new(7));
        let line = submit_line("synth-gaokao", &q, &[5, 6, 7]);
        match parse_client_line(&line).unwrap() {
            ClientMsg::Submit { dataset, question, header } => {
                assert_eq!(dataset, "synth-gaokao");
                assert_eq!(question, q);
                assert_eq!(header, vec![5, 6, 7]);
            }
            other => panic!("wrong message: {other:?}"),
        }
        assert_eq!(
            parse_client_line(&shutdown_line()).unwrap(),
            ClientMsg::Shutdown
        );
        assert!(parse_client_line("{\"op\":\"wat\"}").is_err());
        assert!(parse_client_line("not json").is_err());
    }

    #[test]
    fn every_event_variant_round_trips() {
        let cases = vec![
            ServeEvent::Admitted { request: 3, at: 1.5 },
            ServeEvent::BranchTokens {
                request: 3,
                branch: 2,
                tokens: vec![10, 11, 2],
            },
            ServeEvent::BranchPruned { request: 3, branch: 1, at: 2.0 },
            ServeEvent::BranchCapped { request: 3, branch: 0, at: 2.5 },
            ServeEvent::EarlyStop { request: 3, at: 3.0 },
        ];
        for ev in &cases {
            let msg = parse_server_line(&event_line(ev, None)).unwrap();
            match (ev, &msg) {
                (
                    ServeEvent::Admitted { request, at },
                    ServerMsg::Admitted { request: r, t },
                ) => {
                    assert_eq!((r, t), (request, at));
                }
                (
                    ServeEvent::BranchTokens { request, branch, tokens },
                    ServerMsg::Tokens { request: r, branch: b, tokens: tk },
                ) => {
                    assert_eq!((r, b, tk), (request, branch, tokens));
                }
                (
                    ServeEvent::BranchPruned { request, branch, at },
                    ServerMsg::Pruned { request: r, branch: b, t },
                ) => {
                    assert_eq!((r, b, t), (request, branch, at));
                }
                (
                    ServeEvent::BranchCapped { request, branch, at },
                    ServerMsg::Capped { request: r, branch: b, t },
                ) => {
                    assert_eq!((r, b, t), (request, branch, at));
                }
                (
                    ServeEvent::EarlyStop { request, at },
                    ServerMsg::EarlyStop { request: r, t },
                ) => {
                    assert_eq!((r, t), (request, at));
                }
                (ev, msg) => panic!("mismatched parse: {ev:?} -> {msg:?}"),
            }
        }
        // Finalized carries the embedded outcome.
        let o = outcome();
        let ev = ServeEvent::Finalized {
            request: 7,
            answer: Some(3),
            votes: 2,
            at: 4.25,
        };
        match parse_server_line(&event_line(&ev, Some(&o))).unwrap() {
            ServerMsg::Finalized { request, answer, votes, t, outcome } => {
                assert_eq!(request, 7);
                assert_eq!(answer, Some(3));
                assert_eq!(votes, 2);
                assert_eq!(t, 4.25);
                assert_eq!(*outcome, o);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn control_lines_round_trip() {
        assert_eq!(
            parse_server_line(&accepted_line(9)).unwrap(),
            ServerMsg::Accepted { request: 9 }
        );
        assert_eq!(
            parse_server_line(&rejected_line(100)).unwrap(),
            ServerMsg::Rejected { retry_after_ms: 100 }
        );
        assert_eq!(
            parse_server_line(&refused_line("shutting down")).unwrap(),
            ServerMsg::Refused { error: "shutting down".into() }
        );
        assert_eq!(
            parse_server_line(&shutdown_ack_line()).unwrap(),
            ServerMsg::ShutdownAck
        );
        assert!(parse_server_line("{\"event\":\"wat\"}").is_err());
    }
}
