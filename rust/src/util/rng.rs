//! Deterministic RNGs (splitmix64 / xoshiro256**) and distributions.
//!
//! Every stochastic component of the system — branch sampling,
//! workload question generation, Poisson arrivals, the simulation engine —
//! takes an explicit seed, so every experiment in EXPERIMENTS.md is
//! exactly reproducible.

/// xoshiro256** seeded via splitmix64 — fast, high-quality, std-only.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per branch) from this RNG's
    /// seed space without correlating with `self`'s own sequence.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift (n << 2^32 for all our uses).
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (inter-arrival times).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal variate (the paper-like heavy-tail length model used by
    /// the simulation engine).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Geometric count of successes before failure: P(k) = p^k (1-p).
    pub fn geometric(&mut self, p_continue: f64) -> usize {
        let mut k = 0;
        while self.chance(p_continue) {
            k += 1;
            if k > 10_000 {
                break; // safety bound; unreachable for sane p
            }
        }
        k
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn geometric_mean() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.geometric(0.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}"); // p/(1-p) = 1
    }

    #[test]
    fn fork_decorrelates() {
        let mut base = Rng::new(11);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
