//! Std-only utility substrates.
//!
//! The offline build environment has no serde/rand/criterion, so the
//! small pieces of infrastructure the coordinator needs are implemented
//! here from scratch: a JSON parser ([`json`]), deterministic RNGs
//! ([`rng`]), descriptive statistics ([`stats`]) and a real/virtual clock
//! abstraction ([`clock`]).

pub mod clock;
pub mod json;
pub mod rng;
pub mod stats;
