//! Minimal recursive-descent JSON parser and writer.
//!
//! Covers the subset the artifacts use (objects, arrays, strings with
//! escapes, f64 numbers, bools, null). Numbers are stored as `f64`; all
//! integer fields in our manifests are well below 2^53 so this is exact.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field access; returns `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (manifest loading).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization. Round-trips everything `parse` accepts
/// (`json.to_string()` via the blanket `ToString`).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates unsupported (not used by our files).
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {} }"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert!(j.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }
}
