//! Real and virtual clocks.
//!
//! The coordinator is written against [`Clock`] so the exact same
//! scheduling code runs (a) in real time against the HLO engine and (b) in
//! virtual time against the simulation engine, where decode-step cost is
//! modeled and time advances discretely. Virtual time makes the full-scale
//! figure sweeps deterministic and fast.

use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

/// Monotonic seconds-since-start.
pub trait Clock {
    fn now(&self) -> f64;
}

/// Wall-clock backed by `Instant`.
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { start: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Discrete-event virtual clock; shared (Rc) between the simulation
/// engine (which advances it) and the scheduler/metrics (which read it).
#[derive(Clone)]
pub struct SimClock {
    t: Rc<Cell<f64>>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { t: Rc::new(Cell::new(0.0)) }
    }

    pub fn advance(&self, dt: f64) {
        debug_assert!(dt >= 0.0, "time can only move forward");
        self.t.set(self.t.get() + dt);
    }

    /// Jump directly to an absolute time (used when the scheduler idles
    /// until the next arrival).
    pub fn advance_to(&self, t: f64) {
        if t > self.t.get() {
            self.t.set(t);
        }
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        self.t.get()
    }
}

/// The single time authority of a serve: one enum instead of a trait
/// object so the scheduler, the cluster dispatcher and the wall-clock
/// front end all charge cost and idle through the same two methods, and
/// the virtual/real distinction lives in exactly one place.
pub enum ClockHandle {
    Real(RealClock),
    Sim(SimClock),
}

impl ClockHandle {
    pub fn now(&self) -> f64 {
        match self {
            ClockHandle::Real(c) => c.now(),
            ClockHandle::Sim(c) => c.now(),
        }
    }

    /// Account engine cost: virtual clocks advance by it, real clocks
    /// already paid it in wall time.
    pub fn charge(&self, cost: f64) {
        if let ClockHandle::Sim(c) = self {
            c.advance(cost);
        }
    }

    /// Idle until absolute time `t`: virtual clocks jump, real clocks
    /// sleep in short slices so arrivals stay responsive.
    pub fn idle_until(&self, t: f64) {
        match self {
            ClockHandle::Sim(c) => c.advance_to(t),
            ClockHandle::Real(c) => {
                let dt = t - c.now();
                if dt > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        dt.min(0.01),
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        let c2 = c.clone();
        c2.advance(0.5);
        assert_eq!(c.now(), 2.0); // shared state
    }

    #[test]
    fn advance_to_never_rewinds() {
        let c = SimClock::new();
        c.advance(5.0);
        c.advance_to(3.0);
        assert_eq!(c.now(), 5.0);
        c.advance_to(7.0);
        assert_eq!(c.now(), 7.0);
    }

    #[test]
    fn real_clock_monotone() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn clock_handle_charges_virtual_only() {
        let sim = SimClock::new();
        let h = ClockHandle::Sim(sim.clone());
        h.charge(2.0);
        assert_eq!(h.now(), 2.0);
        h.idle_until(5.0);
        assert_eq!(sim.now(), 5.0);
        h.idle_until(1.0); // never rewinds
        assert_eq!(h.now(), 5.0);

        let h = ClockHandle::Real(RealClock::new());
        let before = h.now();
        h.charge(100.0); // wall time is not advanced by charges
        assert!(h.now() - before < 1.0);
    }
}
