//! Descriptive statistics: percentiles, histograms, summaries.
//!
//! The paper reports percentile latencies (P50/P90/P97/P99) and
//! length/queuing-time distributions; this module computes them and
//! renders the aligned text tables the figure harnesses print.

/// Percentile by linear interpolation on the sorted sample (numpy
/// `percentile(..., method="linear")`), matching how the paper's plots
/// are typically produced. Sorts with `total_cmp`, so NaN inputs order
/// after +∞ instead of panicking (they only contaminate the top
/// percentiles they actually occupy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample");
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_unstable_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, p)
}

/// Percentile on an already-sorted (`total_cmp` order) sample. Callers
/// computing several percentiles should sort once and use this —
/// `Summary::of` previously re-sorted the sample four times.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    assert!(!v.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

pub fn std_dev(samples: &[f64]) -> f64 {
    if samples.len() < 2 {
        return 0.0;
    }
    let m = mean(samples);
    (samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
        / (samples.len() - 1) as f64)
        .sqrt()
}

/// Standard latency summary used across all experiment tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p97: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        // One sort serves every percentile (this used to sort the sample
        // once per percentile). total_cmp puts NaNs after +∞, so the max
        // (last finite-or-not element) and the percentiles are defined
        // without panicking on NaN inputs.
        let mut v: Vec<f64> = samples.to_vec();
        v.sort_unstable_by(|a, b| a.total_cmp(b));
        Summary {
            n: v.len(),
            mean: mean(samples),
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p97: percentile_sorted(&v, 97.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

/// Fixed-width bucket histogram over [0, bucket_width * n_buckets); the
/// last bucket absorbs overflow (paper Fig. 2 length buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub bucket_width: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(bucket_width: f64, n_buckets: usize) -> Histogram {
        assert!(bucket_width > 0.0 && n_buckets > 0);
        Histogram { bucket_width, counts: vec![0; n_buckets] }
    }

    pub fn add(&mut self, x: f64) {
        let idx = ((x / self.bucket_width) as usize)
            .min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Label like "2-3" for bucket i (units of bucket_width).
    pub fn label(&self, i: usize) -> String {
        format!("{}-{}", i, i + 1)
    }
}

/// Render an aligned text table (the figure harness output format).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
        for (i, cell) in cells.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{:>w$}", cell, w = widths[i]));
        }
        out.push('\n');
    };
    let header_cells: Vec<String> =
        headers.iter().map(|s| s.to_string()).collect();
    fmt_row(&header_cells, &widths, &mut out);
    let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        fmt_row(row, &widths, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn summary_sane() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::of(&v);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p97 && s.p97 <= s.p99);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(10.0, 3);
        h.add(5.0);
        h.add(15.0);
        h.add(999.0); // overflow -> last bucket
        assert_eq!(h.counts, vec![1, 1, 1]);
        assert_eq!(h.total(), 3);
        assert_eq!(h.label(2), "2-3");
    }

    #[test]
    fn table_renders() {
        let t = render_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["10".into(), "x".into()]],
        );
        assert!(t.contains("a"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn percentile_tolerates_nan_input() {
        // total_cmp orders NaN after +inf: low/mid percentiles stay
        // finite and correct, and nothing panics (partial_cmp().unwrap()
        // used to abort here).
        let v = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&v, 100.0).is_nan());
        let s = Summary::of(&v);
        assert_eq!(s.n, 4);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        assert!(s.max.is_nan());
        // All-NaN input: defined (all-NaN percentiles), no panic.
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn percentile_sorted_matches_unsorted_entry_point() {
        let v = [9.0, 1.0, 5.0, 3.0, 7.0];
        let mut sorted = v.to_vec();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&v, p), percentile_sorted(&sorted, p));
        }
    }

    #[test]
    fn std_dev_known() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&v) - 2.138089935).abs() < 1e-6);
    }
}
