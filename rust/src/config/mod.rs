//! Serve configuration and a std-only CLI argument parser.
//!
//! Every binary (the `sart` server, the examples, the figure harnesses)
//! shares [`Args`] for flag parsing and [`ServeSpec`] as the full
//! description of one serving run: method × workload × engine × budgets.
//! Defaults mirror the paper (§5.1): M = N/2, α = 0.5, β = N/2, with T
//! and lengths scaled to this testbed's token scale (paper T=400 at
//! ~4-8k-token responses ≈ T=16 at our ~40-200-token responses).

use crate::cluster::{FaultPlan, LbPolicy, ScaleConfig};
use crate::coordinator::{AdaptiveConfig, Policy};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Boolean flags (never consume a following value). Everything else
/// written as `--key value` or `--key=value` is a key/value pair.
const KNOWN_FLAGS: &[&str] = &[
    "stepwise",
    "quiet",
    "verbose",
    "csv",
    "no-header",
    "help",
    "gossip-adapt",
    "shutdown",
    "adaptive",
];

/// Minimal `--key value` / `--key=value` / `--flag` parser.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.values.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.values
                        .insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.usize_or(name, default as usize)? as u64)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Serving method (CLI surface of the policies + Rebase).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Vanilla,
    SelfConsistency { n: usize },
    Sart { n: usize, m: usize, alpha: f32, beta: usize },
    SartNoPrune { n: usize, m: usize },
    Rebase { n: usize },
}

impl Method {
    /// Parse e.g. `sart`, `sart:8`, `self-consistency:4`, `rebase:8`,
    /// `vanilla`, `sart-noprune:8`. `n` defaults to 8; SART's M/α/β follow
    /// the paper defaults (N/2, 0.5, N/2) unless overridden by flags.
    pub fn parse(s: &str, args: &Args) -> Result<Method> {
        let (name, n_str) = s.split_once(':').unwrap_or((s, ""));
        let n = if n_str.is_empty() {
            args.usize_or("n", 8)?
        } else {
            n_str.parse().context("method :N suffix")?
        };
        if n == 0 {
            bail!("N must be positive");
        }
        let m = args.usize_or("m", (n / 2).max(1))?;
        let alpha = args.f64_or("alpha", 0.5)? as f32;
        let beta = args.usize_or("beta", (n / 2).max(1))?;
        if m == 0 {
            bail!("M must be positive (a 0-vote quorum can never finalize)");
        }
        if m > n {
            bail!("M={m} cannot exceed N={n}");
        }
        Ok(match name {
            "vanilla" => Method::Vanilla,
            "self-consistency" | "sc" => Method::SelfConsistency { n },
            "sart" => Method::Sart { n, m, alpha, beta },
            "sart-noprune" => Method::SartNoPrune { n, m },
            "rebase" => Method::Rebase { n },
            _ => bail!(
                "unknown method `{name}` (vanilla|self-consistency|sart|\
                 sart-noprune|rebase)"
            ),
        })
    }

    pub fn policy(&self) -> Option<Policy> {
        Some(match *self {
            Method::Vanilla => Policy::Vanilla,
            Method::SelfConsistency { n } => Policy::SelfConsistency { n },
            Method::Sart { n, m, alpha, beta } => {
                Policy::Sart { n, m, alpha, beta }
            }
            Method::SartNoPrune { n, m } => Policy::SartNoPrune { n, m },
            Method::Rebase { .. } => return None,
        })
    }

    pub fn label(&self) -> String {
        match *self {
            Method::Vanilla => "vanilla".into(),
            Method::SelfConsistency { n } => format!("self-consistency(N={n})"),
            Method::Sart { n, m, .. } => format!("sart(N={n},M={m})"),
            Method::SartNoPrune { n, m } => format!("sart-noprune(N={n},M={m})"),
            Method::Rebase { n } => format!("rebase(N={n})"),
        }
    }
}

/// Engine selection.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineChoice {
    /// Virtual-time simulation (full-scale figure sweeps, tests).
    Sim,
    /// AOT artifacts via PJRT; `model` is a manifest model name,
    /// `fused` picks the fused-chunk decode path.
    Hlo { model: String, fused: bool },
}

/// PRM selection.
#[derive(Debug, Clone, PartialEq)]
pub enum PrmChoice {
    Oracle { sigma: f64 },
    Hlo,
}

/// Everything one serving run needs.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub method: Method,
    pub dataset: String,
    pub n_requests: usize,
    /// Requests/second Poisson rate; 0 = all at t=0.
    pub rate: f64,
    pub engine: EngineChoice,
    pub prm: PrmChoice,
    /// Engine replicas behind the dispatch layer (1 = single-engine path).
    pub replicas: usize,
    /// Load-balancing policy across replicas (ignored at `replicas = 1`).
    pub lb: LbPolicy,
    /// Prefix-digest gossip period for `--lb prefix-affinity`
    /// (`--gossip-rounds`): replicas re-advertise their digest sets into
    /// the dispatcher's table every this-many scheduler steps, and
    /// routing becomes a table lookup instead of a per-replica tree
    /// probe. 0 (the default) = probe-per-replica, the pre-gossip
    /// behaviour; a nonzero period with any other policy is rejected
    /// (it would be silently ignored). `--replicas 1` keeps accepting a
    /// period — placement is forced either way (the cluster-layer
    /// property pins R = 1 with gossip on byte-identical to the
    /// single-engine serve), and rejecting it would break `--replicas`
    /// sweeps under fixed affinity flags.
    pub gossip_rounds: usize,
    /// Adapt the gossip period at runtime from observed stale table
    /// routes (`--gossip-adapt`; needs a nonzero `--gossip-rounds`).
    pub gossip_adapt: bool,
    /// Scripted replica failures/restarts (`--fault-plan
    /// fail@2.5:1,restart@6.0:1`); the default empty plan is inert.
    pub fault_plan: FaultPlan,
    /// Queue-pressure scale controller (`--scale-min` enables it;
    /// `--scale-up-queue`, `--scale-down-queue`, `--scale-up-prefill`,
    /// `--scale-cooldown` tune it). `None` keeps the replica set static.
    pub scale: Option<ScaleConfig>,
    pub slots: usize,
    pub kv_capacity_tokens: usize,
    pub kv_page_tokens: usize,
    /// Cross-request prefix-cache retention budget in pages (`--prefix-cache`;
    /// 0 disables the cache — the pre-cache admission accounting).
    pub prefix_cache_pages: usize,
    /// Chunked prefill: stream each admission's uncovered prompt suffix
    /// in chunks of at most this many tokens (`--prefill-chunk`; 0 =
    /// monolithic prefill, the historical behaviour).
    pub prefill_chunk_tokens: usize,
    /// Per-round streamed-prefill token budget (`--prefill-budget`;
    /// defaults to the chunk size when chunking is on — one chunk per
    /// round — and 0 = unlimited otherwise).
    pub max_batched_prefill_tokens: usize,
    /// Stream-aware admission (`--kv-stream`; needs `--prefill-chunk`):
    /// admit once the first prefill chunk fits and grow the page pledge
    /// per chunk, instead of pledging the whole uncovered suffix up
    /// front.
    pub kv_stream: bool,
    /// Reward-driven preemption (`--kv-preempt`): under page pressure,
    /// swap out the lowest-reward running branches and resume them by
    /// recomputation when pages free up.
    pub kv_preempt: bool,
    /// Adaptive test-time compute (`--adaptive` plus the `--adaptive-*`
    /// and `--fast-*` tuning knobs): per-request runtime shrinking of
    /// N / M / the thinking cap. `None` (the default) is the static
    /// policy, byte-identical to the pre-adaptive serve.
    pub adaptive: Option<AdaptiveConfig>,
    /// Fraction of requests drawn from the *hard* task spec in the mixed
    /// easy/hard trace (`--hard-share`; 0 = the plain single-dataset
    /// generators, byte-identical to before).
    pub hard_share: f64,
    /// Fraction of requests carrying a shared few-shot header
    /// (`--prefix-share`; 0 = the plain trace generators).
    pub prefix_share: f64,
    /// Number of distinct header templates in a prefix-heavy trace.
    pub prefix_templates: usize,
    /// Worked examples per header template (controls header length).
    pub prefix_shots: usize,
    pub t_round: usize,
    pub temperature: f32,
    pub max_new: usize,
    pub seed: u64,
}

impl ServeSpec {
    /// Build from CLI args with paper-scaled defaults.
    pub fn from_args(args: &Args) -> Result<ServeSpec> {
        let method = Method::parse(&args.get_or("method", "sart"), args)?;
        let engine = match args.get_or("engine", "sim").as_str() {
            "sim" => EngineChoice::Sim,
            "hlo" => EngineChoice::Hlo {
                model: args.get_or("model", "r1mini-tiny"),
                fused: !args.flag("stepwise"),
            },
            other => bail!("unknown engine `{other}` (sim|hlo)"),
        };
        let prm = match args.get_or("prm", "auto").as_str() {
            "oracle" => PrmChoice::Oracle { sigma: args.f64_or("prm-sigma", 0.08)? },
            "hlo" => PrmChoice::Hlo,
            // auto: match the engine.
            "auto" => match &engine {
                EngineChoice::Sim => {
                    PrmChoice::Oracle { sigma: args.f64_or("prm-sigma", 0.08)? }
                }
                EngineChoice::Hlo { .. } => PrmChoice::Hlo,
            },
            other => bail!("unknown prm `{other}` (oracle|hlo|auto)"),
        };
        let replicas = args.usize_or("replicas", 1)?;
        if replicas == 0 {
            bail!("--replicas must be at least 1");
        }
        let lb = LbPolicy::parse(&args.get_or("lb", "round-robin"))?;
        let gossip_rounds = args.usize_or("gossip-rounds", 0)?;
        if gossip_rounds > 0 && lb != LbPolicy::PrefixAffinity {
            bail!(
                "--gossip-rounds only applies to --lb prefix-affinity \
                 (the other policies never consult the digest table; a \
                 silently ignored period would misreport gossip as active)"
            );
        }
        let gossip_adapt = args.flag("gossip-adapt");
        if gossip_adapt && gossip_rounds == 0 {
            bail!(
                "--gossip-adapt needs a gossip period to adapt \
                 (--gossip-rounds > 0)"
            );
        }
        let fault_plan = match args.get("fault-plan") {
            None => FaultPlan::default(),
            Some(s) => FaultPlan::parse(s).context("--fault-plan")?,
        };
        if let Some(m) = fault_plan.max_replica() {
            if m >= replicas {
                bail!(
                    "--fault-plan names replica {m} but --replicas is \
                     {replicas}"
                );
            }
        }
        let scale = match args.get("scale-min") {
            None => {
                for k in [
                    "scale-up-queue",
                    "scale-down-queue",
                    "scale-up-prefill",
                    "scale-pressure",
                    "scale-cooldown",
                ] {
                    if args.get(k).is_some() {
                        bail!(
                            "--{k} needs the scale controller enabled \
                             (--scale-min)"
                        );
                    }
                }
                None
            }
            Some(_) => {
                let sc = ScaleConfig {
                    min_live: args.usize_or("scale-min", 1)?,
                    scale_up_queue: args.usize_or("scale-up-queue", 4)?,
                    scale_up_prefill_tokens: args
                        .usize_or("scale-up-prefill", 0)?,
                    scale_up_pressure: args.f64_or("scale-pressure", 0.0)?,
                    scale_down_queue: args.usize_or("scale-down-queue", 0)?,
                    cooldown_arrivals: args.usize_or("scale-cooldown", 8)?,
                };
                sc.validate()?;
                if sc.min_live > replicas {
                    bail!(
                        "--scale-min {} exceeds --replicas {replicas}",
                        sc.min_live
                    );
                }
                Some(sc)
            }
        };
        let prefix_share = args.f64_or("prefix-share", 0.0)?;
        if !(0.0..=1.0).contains(&prefix_share) {
            bail!("--prefix-share must be in [0, 1], got {prefix_share}");
        }
        let prefix_templates = args.usize_or("prefix-templates", 3)?;
        if prefix_templates == 0 {
            bail!("--prefix-templates must be at least 1");
        }
        let prefill_chunk_tokens = args.usize_or("prefill-chunk", 0)?;
        let max_batched_prefill_tokens =
            args.usize_or("prefill-budget", prefill_chunk_tokens)?;
        if prefill_chunk_tokens == 0 && max_batched_prefill_tokens > 0 {
            bail!(
                "--prefill-budget needs chunked prefill (--prefill-chunk > 0): \
                 monolithic prefill cannot be budgeted per round"
            );
        }
        let kv_stream = args.flag("kv-stream");
        if kv_stream && prefill_chunk_tokens == 0 {
            bail!(
                "--kv-stream needs chunked prefill (--prefill-chunk > 0): \
                 a monolithic prefill has no chunks to grow a pledge over"
            );
        }
        let kv_preempt = args.flag("kv-preempt");
        let adaptive = if args.flag("adaptive") {
            let d = AdaptiveConfig::default();
            let cfg = AdaptiveConfig {
                spread_tol: args.f64_or("adaptive-spread", d.spread_tol as f64)?
                    as f32,
                prune_keep: args.usize_or("adaptive-keep", d.prune_keep)?,
                tail_pct: args.f64_or("adaptive-tail", d.tail_pct)?,
                cap_slack: args.f64_or("adaptive-slack", d.cap_slack)?,
                min_samples: args
                    .usize_or("adaptive-min-samples", d.min_samples)?,
                fast_reward: args.f64_or("fast-reward", d.fast_reward as f64)?
                    as f32,
                fast_len: args.f64_or("fast-len", d.fast_len)?,
            };
            if !(cfg.spread_tol.is_finite() && cfg.spread_tol >= 0.0) {
                bail!(
                    "--adaptive-spread must be a non-negative reward \
                     tolerance, got {}",
                    cfg.spread_tol
                );
            }
            if cfg.prune_keep == 0 {
                bail!(
                    "--adaptive-keep must be at least 1 (a spread prune \
                     keeping 0 branches would strand the request)"
                );
            }
            if !(cfg.tail_pct > 0.0 && cfg.tail_pct <= 100.0) {
                bail!(
                    "--adaptive-tail must be a percentile in (0, 100], \
                     got {}",
                    cfg.tail_pct
                );
            }
            if !(cfg.cap_slack.is_finite() && cfg.cap_slack > 0.0) {
                bail!(
                    "--adaptive-slack must be a positive length multiplier, \
                     got {}",
                    cfg.cap_slack
                );
            }
            if !(cfg.fast_len.is_finite() && cfg.fast_len > 0.0) {
                bail!(
                    "--fast-len must be a positive mean completion length, \
                     got {}",
                    cfg.fast_len
                );
            }
            Some(cfg)
        } else {
            for k in [
                "adaptive-spread",
                "adaptive-keep",
                "adaptive-tail",
                "adaptive-slack",
                "adaptive-min-samples",
                "fast-reward",
                "fast-len",
            ] {
                if args.get(k).is_some() {
                    bail!(
                        "--{k} needs the adaptive policy enabled (--adaptive)"
                    );
                }
            }
            None
        };
        let prefix_shots = args.usize_or("prefix-shots", 3)?;
        if prefix_share > 0.0 && prefix_shots == 0 {
            bail!(
                "--prefix-shots must be at least 1 when --prefix-share > 0 \
                 (zero-shot headers are empty, silently degenerating the \
                 prefix workload to a plain trace)"
            );
        }
        let hard_share = args.f64_or("hard-share", 0.0)?;
        if !(0.0..=1.0).contains(&hard_share) {
            bail!("--hard-share must be in [0, 1], got {hard_share}");
        }
        if hard_share > 0.0 && prefix_share > 0.0 {
            bail!(
                "--hard-share and --prefix-share cannot be combined: the \
                 mixed easy/hard trace has no headered variant"
            );
        }
        Ok(ServeSpec {
            method,
            dataset: args.get_or("dataset", "synth-gaokao"),
            n_requests: args.usize_or("requests", 32)?,
            rate: args.f64_or("rate", 1.0)?,
            engine,
            prm,
            replicas,
            lb,
            gossip_rounds,
            gossip_adapt,
            fault_plan,
            scale,
            slots: args.usize_or("slots", 8)?,
            kv_capacity_tokens: args.usize_or("kv-tokens", 4096)?,
            kv_page_tokens: args.usize_or("kv-page", 16)?,
            prefix_cache_pages: args.usize_or("prefix-cache", 0)?,
            prefill_chunk_tokens,
            max_batched_prefill_tokens,
            kv_stream,
            kv_preempt,
            adaptive,
            hard_share,
            prefix_share,
            prefix_templates,
            prefix_shots,
            t_round: args.usize_or("t-round", 16)?,
            temperature: args.f64_or("temp", 1.0)? as f32,
            max_new: args.usize_or("max-new", 224)?,
            seed: args.u64_or("seed", 0)?,
        })
    }
}

/// Configuration of the wall-clock front end (`sart listen` and the
/// `sart replay` client). Orthogonal to [`ServeSpec`]: the spec says what
/// to serve, this says how the serve meets real time.
#[derive(Debug, Clone, PartialEq)]
pub struct LiveConfig {
    /// Listen/connect address (`--addr`; port 0 binds an ephemeral port
    /// and the listener reports the real one).
    pub addr: String,
    /// Wall seconds per virtual second (`--time-scale`): 1.0 replays a
    /// trace in real time, 0.01 replays it 100× faster. Applies to both
    /// the listener's virtual-clock pacing and the replay client's
    /// arrival sleeps.
    pub time_scale: f64,
    /// Admission bound on concurrent in-flight sessions
    /// (`--max-sessions`): past it, submits are rejected with a
    /// `retry_after_ms` hint instead of queueing unboundedly.
    pub max_sessions: usize,
}

impl LiveConfig {
    pub fn from_args(args: &Args) -> Result<LiveConfig> {
        let time_scale = args.f64_or("time-scale", 1.0)?;
        if !(time_scale.is_finite() && time_scale > 0.0) {
            bail!("--time-scale must be a positive number, got {time_scale}");
        }
        let max_sessions = args.usize_or("max-sessions", 256)?;
        if max_sessions == 0 {
            bail!("--max-sessions must be at least 1");
        }
        Ok(LiveConfig {
            addr: args.get_or("addr", "127.0.0.1:8477"),
            time_scale,
            max_sessions,
        })
    }
}

/// Listener-side robustness knobs, split from [`LiveConfig`] so the
/// historical three-field config keeps its exact shape (everything here
/// has a safe default and most callers never touch it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ListenerTuning {
    /// Reap a connection with no in-flight session after this many wall
    /// seconds without a complete request line (`--idle-timeout`) — the
    /// slow-loris defence. Connections with live sessions are never
    /// reaped; their events keep flowing.
    pub idle_timeout_s: f64,
    /// Per-session bound on queued-but-unwritten event lines
    /// (`--session-queue`). A reader too slow to drain its socket sheds
    /// non-terminal `tokens` lines past this depth (counted on the
    /// `finalized` line); `accepted`/`admitted`/`migrated`/`finalized`
    /// are never shed. 0 sheds every `tokens` line — a deliberate
    /// headers-only mode (and the deterministic way to test shedding).
    pub session_queue: usize,
}

impl Default for ListenerTuning {
    fn default() -> Self {
        ListenerTuning { idle_timeout_s: 30.0, session_queue: 256 }
    }
}

impl ListenerTuning {
    pub fn from_args(args: &Args) -> Result<ListenerTuning> {
        let d = ListenerTuning::default();
        let idle_timeout_s = args.f64_or("idle-timeout", d.idle_timeout_s)?;
        if !(idle_timeout_s.is_finite() && idle_timeout_s > 0.0) {
            bail!(
                "--idle-timeout must be a positive number of seconds, \
                 got {idle_timeout_s}"
            );
        }
        Ok(ListenerTuning {
            idle_timeout_s,
            session_queue: args.usize_or("session-queue", d.session_queue)?,
        })
    }
}

/// Client-side resilience knobs of `sart replay`. The default
/// (`retry_max = 0`, no deadline) reproduces the original single-shot
/// client: one connection per session, first hiccup loses it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplayConfig {
    /// Retry budget per session (`--retry-max`): reconnect-and-resubmit
    /// attempts after a rejection, connection loss, or transport error.
    /// 0 disables retries *and* client ids (exact legacy wire format).
    pub retry_max: usize,
    /// Base backoff in wall milliseconds (`--retry-base-ms`). Attempt k
    /// sleeps `base * 2^k`, jittered to 50–100% by the session's seeded
    /// RNG; a server `retry_after_ms` hint replaces the base for that
    /// attempt.
    pub retry_base_ms: u64,
    /// Per-session wall-clock deadline in seconds (`--session-deadline`);
    /// a session that has not finalized by then counts as expired
    /// (and lost). 0 = no deadline.
    pub session_deadline_s: f64,
    /// Seed for the backoff jitter (`--seed`, shared with the trace):
    /// the whole retry schedule is deterministic under a fixed seed.
    pub seed: u64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            retry_max: 0,
            retry_base_ms: 25,
            session_deadline_s: 0.0,
            seed: 0,
        }
    }
}

impl ReplayConfig {
    pub fn from_args(args: &Args) -> Result<ReplayConfig> {
        let d = ReplayConfig::default();
        let retry_base_ms = args.u64_or("retry-base-ms", d.retry_base_ms)?;
        if retry_base_ms == 0 {
            bail!("--retry-base-ms must be at least 1");
        }
        let session_deadline_s =
            args.f64_or("session-deadline", d.session_deadline_s)?;
        if !(session_deadline_s.is_finite() && session_deadline_s >= 0.0) {
            bail!(
                "--session-deadline must be a non-negative number of \
                 seconds (0 = none), got {session_deadline_s}"
            );
        }
        Ok(ReplayConfig {
            retry_max: args.usize_or("retry-max", d.retry_max)?,
            retry_base_ms,
            session_deadline_s,
            seed: args.u64_or("seed", 0)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = args("--n 8 --alpha=0.6 --stepwise pos1");
        assert_eq!(a.get("n"), Some("8"));
        assert_eq!(a.get("alpha"), Some("0.6"));
        assert!(a.flag("stepwise"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn method_parsing_defaults() {
        let a = args("");
        assert_eq!(Method::parse("vanilla", &a).unwrap(), Method::Vanilla);
        assert_eq!(
            Method::parse("sc:4", &a).unwrap(),
            Method::SelfConsistency { n: 4 }
        );
        match Method::parse("sart:8", &a).unwrap() {
            Method::Sart { n, m, alpha, beta } => {
                assert_eq!((n, m, beta), (8, 4, 4));
                assert!((alpha - 0.5).abs() < 1e-6);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn method_overrides() {
        let a = args("--m 3 --alpha 0.7 --beta 2");
        match Method::parse("sart:8", &a).unwrap() {
            Method::Sart { n, m, alpha, beta } => {
                assert_eq!((n, m, beta), (8, 3, 2));
                assert!((alpha - 0.7).abs() < 1e-6);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn method_rejects_bad() {
        let a = args("");
        assert!(Method::parse("wat", &a).is_err());
        assert!(Method::parse("sart:0", &a).is_err());
        let a = args("--m 9");
        assert!(Method::parse("sart:4", &a).is_err());
        // M = 0 could never reach quorum — reject at parse time for every
        // method that carries M, not just when a serve later hangs.
        let a = args("--m 0");
        let err = Method::parse("sart:4", &a).unwrap_err().to_string();
        assert!(err.contains("M must be positive"), "unclear error: {err}");
        assert!(Method::parse("sart-noprune:4", &a).is_err());
    }

    #[test]
    fn spec_defaults() {
        let a = args("");
        let s = ServeSpec::from_args(&a).unwrap();
        assert_eq!(s.engine, EngineChoice::Sim);
        assert_eq!(s.prm, PrmChoice::Oracle { sigma: 0.08 });
        assert_eq!(s.slots, 8);
        assert_eq!(s.dataset, "synth-gaokao");
        assert_eq!(s.replicas, 1);
        assert_eq!(s.lb, LbPolicy::RoundRobin);
        assert_eq!(s.gossip_rounds, 0, "gossip must default to probe mode");
        assert!(!s.gossip_adapt, "period adaptation must default off");
        assert!(s.fault_plan.is_empty(), "fault plan must default inert");
        assert_eq!(s.scale, None, "scale controller must default off");
        assert_eq!(s.prefix_cache_pages, 0, "cache must default off");
        assert_eq!(s.prefill_chunk_tokens, 0, "chunking must default off");
        assert_eq!(s.max_batched_prefill_tokens, 0);
        assert_eq!(s.prefix_share, 0.0);
        assert_eq!(s.prefix_templates, 3);
        assert_eq!(s.prefix_shots, 3);
        assert_eq!(s.adaptive, None, "adaptive policy must default off");
        assert_eq!(s.hard_share, 0.0, "mixed workload must default off");
    }

    #[test]
    fn spec_adaptive_flags() {
        let s = ServeSpec::from_args(&args("--adaptive")).unwrap();
        assert_eq!(s.adaptive, Some(AdaptiveConfig::default()));
        let s = ServeSpec::from_args(&args(
            "--adaptive --adaptive-spread 0.1 --adaptive-keep 3 \
             --adaptive-tail 95 --adaptive-slack 1.5 \
             --adaptive-min-samples 4 --fast-reward 0.7 --fast-len 32",
        ))
        .unwrap();
        let a = s.adaptive.unwrap();
        assert!((a.spread_tol - 0.1).abs() < 1e-6);
        assert_eq!(a.prune_keep, 3);
        assert_eq!(a.tail_pct, 95.0);
        assert_eq!(a.cap_slack, 1.5);
        assert_eq!(a.min_samples, 4);
        assert!((a.fast_reward - 0.7).abs() < 1e-6);
        assert_eq!(a.fast_len, 32.0);
        // Tuning knobs without the enabling flag are silent no-ops — reject.
        assert!(ServeSpec::from_args(&args("--adaptive-keep 3")).is_err());
        assert!(ServeSpec::from_args(&args("--fast-reward 0.7")).is_err());
        // Degenerate tunings are caught at parse time.
        assert!(ServeSpec::from_args(
            &args("--adaptive --adaptive-keep 0")
        )
        .is_err());
        assert!(ServeSpec::from_args(
            &args("--adaptive --adaptive-tail 0")
        )
        .is_err());
        assert!(ServeSpec::from_args(
            &args("--adaptive --adaptive-tail 101")
        )
        .is_err());
        assert!(ServeSpec::from_args(
            &args("--adaptive --adaptive-slack 0")
        )
        .is_err());
        assert!(ServeSpec::from_args(
            &args("--adaptive --adaptive-spread -0.5")
        )
        .is_err());
        assert!(ServeSpec::from_args(&args("--adaptive --fast-len 0")).is_err());
    }

    #[test]
    fn spec_hard_share_flags() {
        let s = ServeSpec::from_args(&args("--hard-share 0.4")).unwrap();
        assert_eq!(s.hard_share, 0.4);
        assert!(ServeSpec::from_args(&args("--hard-share 1.5")).is_err());
        assert!(ServeSpec::from_args(&args("--hard-share -0.1")).is_err());
        // The mixed trace has no headered variant.
        assert!(ServeSpec::from_args(
            &args("--hard-share 0.4 --prefix-share 0.5")
        )
        .is_err());
    }

    #[test]
    fn spec_prefill_chunk_flags() {
        // Budget defaults to the chunk size (one chunk per round).
        let s = ServeSpec::from_args(&args("--prefill-chunk 32")).unwrap();
        assert_eq!(s.prefill_chunk_tokens, 32);
        assert_eq!(s.max_batched_prefill_tokens, 32);
        let s = ServeSpec::from_args(
            &args("--prefill-chunk 32 --prefill-budget 96"),
        )
        .unwrap();
        assert_eq!(s.max_batched_prefill_tokens, 96);
        // Explicit 0 budget = unlimited (drain streams in one round).
        let s = ServeSpec::from_args(
            &args("--prefill-chunk 32 --prefill-budget 0"),
        )
        .unwrap();
        assert_eq!(s.max_batched_prefill_tokens, 0);
        // A budget without chunking is meaningless.
        assert!(ServeSpec::from_args(&args("--prefill-budget 64")).is_err());
    }

    #[test]
    fn spec_prefix_flags() {
        let a = args(
            "--prefix-share 0.8 --prefix-cache 128 --prefix-templates 2 \
             --prefix-shots 4 --lb prefix-affinity",
        );
        let s = ServeSpec::from_args(&a).unwrap();
        assert_eq!(s.prefix_share, 0.8);
        assert_eq!(s.prefix_cache_pages, 128);
        assert_eq!(s.prefix_templates, 2);
        assert_eq!(s.prefix_shots, 4);
        assert_eq!(s.lb, LbPolicy::PrefixAffinity);
        assert!(ServeSpec::from_args(&args("--prefix-share 1.5")).is_err());
        assert!(ServeSpec::from_args(&args("--prefix-templates 0")).is_err());
        assert!(ServeSpec::from_args(
            &args("--prefix-share 0.5 --prefix-shots 0")
        )
        .is_err());
        // Shots are irrelevant (and unchecked) without a prefix workload.
        assert!(ServeSpec::from_args(&args("--prefix-shots 0")).is_ok());
    }

    #[test]
    fn spec_cluster_flags() {
        let a = args("--replicas 4 --lb p2c");
        let s = ServeSpec::from_args(&a).unwrap();
        assert_eq!(s.replicas, 4);
        assert_eq!(s.lb, LbPolicy::PowerOfTwoChoices);
        assert!(ServeSpec::from_args(&args("--replicas 0")).is_err());
        assert!(ServeSpec::from_args(&args("--lb wat")).is_err());
        let a = args("--replicas 4 --lb prefix-affinity --gossip-rounds 8");
        let s = ServeSpec::from_args(&a).unwrap();
        assert_eq!(s.gossip_rounds, 8);
        assert!(ServeSpec::from_args(
            &args("--lb prefix-affinity --gossip-rounds wat")
        )
        .is_err());
        // A gossip period without prefix-affinity routing would be
        // silently ignored — reject it like other unsupported combos.
        assert!(ServeSpec::from_args(&args("--gossip-rounds 8")).is_err());
        assert!(ServeSpec::from_args(
            &args("--replicas 4 --lb p2c --gossip-rounds 8")
        )
        .is_err());
    }

    #[test]
    fn spec_fault_flags() {
        let a = args("--replicas 4 --fault-plan fail@2.5:1,restart@6.0:1");
        let s = ServeSpec::from_args(&a).unwrap();
        assert_eq!(s.fault_plan.events.len(), 2);
        assert_eq!(s.fault_plan.max_replica(), Some(1));
        // Plans naming replicas outside the cluster are caught at parse
        // time, not deep inside the serve.
        assert!(ServeSpec::from_args(
            &args("--replicas 2 --fault-plan fail@1.0:2")
        )
        .is_err());
        assert!(ServeSpec::from_args(&args("--fault-plan wat")).is_err());
        // Adaptation without a period to adapt is rejected, with one OK.
        assert!(ServeSpec::from_args(&args("--gossip-adapt")).is_err());
        let s = ServeSpec::from_args(&args(
            "--replicas 4 --lb prefix-affinity --gossip-rounds 8 \
             --gossip-adapt",
        ))
        .unwrap();
        assert!(s.gossip_adapt);
    }

    #[test]
    fn spec_scale_flags() {
        let a = args(
            "--replicas 4 --scale-min 2 --scale-up-queue 6 \
             --scale-down-queue 2 --scale-cooldown 4",
        );
        let sc = ServeSpec::from_args(&a).unwrap().scale.unwrap();
        assert_eq!(sc.min_live, 2);
        assert_eq!(sc.scale_up_queue, 6);
        assert_eq!(sc.scale_down_queue, 2);
        assert_eq!(sc.scale_up_prefill_tokens, 0);
        assert_eq!(sc.cooldown_arrivals, 4);
        // Tuning knobs without the controller are silent no-ops — reject.
        assert!(ServeSpec::from_args(&args("--scale-up-queue 6")).is_err());
        // No hysteresis band.
        assert!(ServeSpec::from_args(
            &args("--replicas 4 --scale-min 2 --scale-up-queue 4 \
                   --scale-down-queue 4")
        )
        .is_err());
        // Floor above the replica count.
        assert!(ServeSpec::from_args(
            &args("--replicas 2 --scale-min 3")
        )
        .is_err());
    }

    #[test]
    fn live_config_flags() {
        let l = LiveConfig::from_args(&args("")).unwrap();
        assert_eq!(l.addr, "127.0.0.1:8477");
        assert_eq!(l.time_scale, 1.0);
        assert_eq!(l.max_sessions, 256);
        let l = LiveConfig::from_args(&args(
            "--addr 127.0.0.1:0 --time-scale 0.01 --max-sessions 4",
        ))
        .unwrap();
        assert_eq!(l.addr, "127.0.0.1:0");
        assert_eq!(l.time_scale, 0.01);
        assert_eq!(l.max_sessions, 4);
        assert!(LiveConfig::from_args(&args("--time-scale 0")).is_err());
        assert!(LiveConfig::from_args(&args("--time-scale -1")).is_err());
        assert!(LiveConfig::from_args(&args("--time-scale wat")).is_err());
        assert!(LiveConfig::from_args(&args("--max-sessions 0")).is_err());
        // `--shutdown` is a boolean flag (replay client), not a kv pair.
        let a = args("--shutdown --addr 127.0.0.1:9");
        assert!(a.flag("shutdown"));
        assert_eq!(a.get("addr"), Some("127.0.0.1:9"));
    }

    #[test]
    fn listener_tuning_flags() {
        let t = ListenerTuning::from_args(&args("")).unwrap();
        assert_eq!(t, ListenerTuning::default());
        assert_eq!(t.idle_timeout_s, 30.0);
        assert_eq!(t.session_queue, 256);
        let t = ListenerTuning::from_args(&args(
            "--idle-timeout 0.5 --session-queue 0",
        ))
        .unwrap();
        assert_eq!(t.idle_timeout_s, 0.5);
        assert_eq!(t.session_queue, 0, "0 = shed every tokens line");
        assert!(ListenerTuning::from_args(&args("--idle-timeout 0")).is_err());
        assert!(ListenerTuning::from_args(&args("--idle-timeout -2")).is_err());
        assert!(
            ListenerTuning::from_args(&args("--idle-timeout inf")).is_err()
        );
    }

    #[test]
    fn replay_config_flags() {
        let c = ReplayConfig::from_args(&args("")).unwrap();
        assert_eq!(c, ReplayConfig::default());
        assert_eq!(c.retry_max, 0, "retries must default off (legacy wire)");
        assert_eq!(c.retry_base_ms, 25);
        assert_eq!(c.session_deadline_s, 0.0);
        let c = ReplayConfig::from_args(&args(
            "--retry-max 3 --retry-base-ms 10 --session-deadline 2.5 \
             --seed 41",
        ))
        .unwrap();
        assert_eq!(c.retry_max, 3);
        assert_eq!(c.retry_base_ms, 10);
        assert_eq!(c.session_deadline_s, 2.5);
        assert_eq!(c.seed, 41, "jitter seed rides on --seed");
        assert!(ReplayConfig::from_args(&args("--retry-base-ms 0")).is_err());
        assert!(
            ReplayConfig::from_args(&args("--session-deadline -1")).is_err()
        );
    }

    #[test]
    fn spec_hlo_auto_prm() {
        let a = args("--engine hlo --model r1mini-small");
        let s = ServeSpec::from_args(&a).unwrap();
        assert_eq!(
            s.engine,
            EngineChoice::Hlo { model: "r1mini-small".into(), fused: true }
        );
        assert_eq!(s.prm, PrmChoice::Hlo);
    }
}
