//! The real engine: AOT-compiled JAX/Pallas graphs executed via PJRT.
//!
//! All mutable engine state lives in ONE device-resident packed f32 buffer
//! (see `python/compile/model.py` "Packed serving state"): each call
//! passes the state buffer in and keeps the returned buffer for the next
//! call, so the KV cache never crosses the host boundary. Host readbacks
//! are limited to the small control segments (logits / tokens / lengths /
//! alive) via partial `copy_raw_to_host_sync`.
//!
//! Two decode paths exist (the §Perf ablation):
//!
//! * **Fused** (default): one `decode_chunk` executable runs `chunk_t`
//!   steps with in-graph gumbel sampling — one PJRT dispatch + one small
//!   readback per T tokens per slot.
//! * **Stepwise**: one `decode` dispatch per token with host-side
//!   sampling — the pre-optimization baseline, also used when a round is
//!   not a multiple of `chunk_t`.

use super::{
    ChunkResult, ChunkStream, Engine, EngineCaps, PrefillChunkEntry,
    PrefillEntry, SlotId,
};
use crate::runtime::xla;
use crate::runtime::{read_f32, Manifest, ModelExecutables, Runtime, StateLayout};
use crate::sampler::sample_token;
use crate::tokenizer as tok;
use crate::tokenizer::Token;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Which decode path to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    Fused,
    Stepwise,
}

/// PJRT-backed engine over a fixed slot batch.
pub struct HloEngine {
    rt: Runtime,
    exes: ModelExecutables,
    layout: StateLayout,
    caps: EngineCaps,
    mode: DecodeMode,
    temp_top_k: usize,
    state: xla::PjRtBuffer,
    /// Host mirror of per-slot cache lengths (authoritative copy is the
    /// state buffer; mirror is for bookkeeping/assertions).
    lengths: Vec<usize>,
    occupied: Vec<bool>,
    /// Per-slot chunked-prefill streams (None = no stream in flight).
    /// The compiled prefill executable consumes whole prompts, so chunks
    /// accumulate host-side (the cursor bookkeeping is what the
    /// scheduler's streaming contract needs validated) and the device
    /// dispatch happens once, at the completing chunk. Device-side
    /// chunked prompt processing needs a dedicated executable — see
    /// `python/compile/model.py`.
    pending: Vec<Option<ChunkStream>>,
    /// Host logits cache for the stepwise path (refreshed per dispatch).
    host_logits: Vec<Vec<f32>>,
    logits_fresh: bool,
    /// Per-slot sampling streams (stepwise) and the fused-key stream.
    rngs: Vec<Rng>,
    chunk_rng: Rng,
    /// Σ prompt tokens the KV manager reported as cache-covered
    /// ([`PrefillEntry::cached_tokens`]). The packed per-slot state tensor
    /// has no cross-slot page sharing, so this engine must still compute
    /// the full prompt — the counter records what a page-sharing device
    /// layout would have skipped (the calibration target for
    /// `SimCostModel::prefill_per_token`).
    pub cached_prefill_tokens: usize,
}

impl HloEngine {
    /// Load a model from the manifest at a compiled batch-size bucket.
    pub fn load(
        rt: Runtime,
        manifest: &Manifest,
        model: &str,
        batch: usize,
        mode: DecodeMode,
        seed: u64,
    ) -> Result<HloEngine> {
        let art = manifest.model(model)?;
        let exes = rt.load_model(art, batch)?;
        let layout = StateLayout::new(&art.config, batch, art.chunk_t);
        let zeros = vec![0f32; layout.total];
        let state = rt.upload_f32(&zeros, &[layout.total])?;
        Ok(HloEngine {
            caps: EngineCaps {
                slots: batch,
                max_seq: art.config.max_seq,
                prompt_len: art.config.prompt_len,
                chunk_t: art.chunk_t,
            },
            layout,
            exes,
            mode,
            temp_top_k: 0,
            state,
            lengths: vec![0; batch],
            occupied: vec![false; batch],
            pending: (0..batch).map(|_| None).collect(),
            host_logits: vec![vec![0.0; art.config.vocab_size]; batch],
            logits_fresh: false,
            rngs: (0..batch).map(|i| Rng::new(seed ^ i as u64)).collect(),
            chunk_rng: Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            cached_prefill_tokens: 0,
            rt,
        })
    }

    /// Total compile time of the three executables (startup metric).
    pub fn compile_seconds(&self) -> f64 {
        self.exes.decode.compile_seconds
            + self.exes.prefill.compile_seconds
            + self.exes.decode_chunk.compile_seconds
    }

    fn vocab(&self) -> usize {
        self.layout.logits.1 / self.caps.slots
    }

    /// Fetch the control prefix [tokens_out|logits|lengths|alive] via the
    /// param-free `peek` executable (on-device slice + small literal copy;
    /// the CPU PJRT client cannot partially read the big state buffer).
    fn read_control(&self) -> Result<Vec<f32>> {
        let control_len = self.layout.kv.0;
        let out = self.exes.peek.run(&[&self.state])?;
        read_f32(&out, 0, control_len)
    }

    fn refresh_logits(&mut self) -> Result<()> {
        let control = self.read_control()?;
        let (off, _) = self.layout.logits;
        let v = self.vocab();
        for s in 0..self.caps.slots {
            self.host_logits[s]
                .copy_from_slice(&control[off + s * v..off + (s + 1) * v]);
        }
        self.logits_fresh = true;
        Ok(())
    }

    fn decode_fused(
        &mut self,
        active: &[SlotId],
        steps: usize,
        temp: f32,
        out: &mut ChunkResult,
    ) -> Result<()> {
        let t0 = Instant::now();
        let b = self.caps.slots;
        let ct = self.caps.chunk_t;
        let chunks = steps.div_ceil(ct);
        let mut alive: Vec<bool> = vec![true; active.len()];
        let inv_temp = self.rt.upload_f32(&[1.0 / temp.max(1e-6)], &[])?;
        for _ in 0..chunks {
            if !alive.iter().any(|&a| a) {
                break;
            }
            let mut mask = vec![0i32; b];
            for (i, &s) in active.iter().enumerate() {
                if alive[i] {
                    mask[s] = 1;
                }
            }
            let mask_buf = self.rt.upload_i32(&mask, &[b])?;
            let k = self.chunk_rng.next_u64();
            let key = self
                .rt
                .upload_u32(&[(k >> 32) as u32, k as u32], &[2])?;
            let new_state = self.exes.decode_chunk.run(&[
                &self.state,
                &mask_buf,
                &key,
                &inv_temp,
            ])?;
            self.state = new_state;
            // Small readback of the control prefix: tokens, lengths, alive.
            let control = self.read_control()?;
            let toks = &control[self.layout.tokens_out.0
                ..self.layout.tokens_out.0 + self.layout.tokens_out.1];
            let lens = &control[self.layout.lengths.0
                ..self.layout.lengths.0 + self.layout.lengths.1];
            for (i, &s) in active.iter().enumerate() {
                if !alive[i] {
                    continue;
                }
                self.lengths[s] = lens[s] as usize;
                for t_idx in 0..ct {
                    let t = toks[s * ct + t_idx] as Token;
                    if t == tok::PAD {
                        break; // this slot finished earlier in the chunk
                    }
                    out.emitted[i].1.push(t);
                    if t == tok::EOS {
                        alive[i] = false;
                        break;
                    }
                }
            }
        }
        self.logits_fresh = false; // host cache stale after device sampling
        out.cost = t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn decode_stepwise(
        &mut self,
        active: &[SlotId],
        steps: usize,
        temp: f32,
        out: &mut ChunkResult,
    ) -> Result<()> {
        let t0 = Instant::now();
        let b = self.caps.slots;
        if !self.logits_fresh {
            self.refresh_logits()?;
        }
        let mut alive: Vec<bool> = vec![true; active.len()];
        for _ in 0..steps {
            // Sample one token per alive slot from the cached logits.
            let mut toks = vec![tok::PAD; b];
            let mut mask = vec![0i32; b];
            let mut any = false;
            for (i, &s) in active.iter().enumerate() {
                if !alive[i] {
                    continue;
                }
                let t = sample_token(&self.host_logits[s], temp,
                                     self.temp_top_k, &mut self.rngs[s]);
                out.emitted[i].1.push(t);
                if t == tok::EOS {
                    alive[i] = false;
                    continue;
                }
                toks[s] = t;
                mask[s] = 1;
                any = true;
            }
            if !any {
                break;
            }
            let toks_buf = self.rt.upload_i32(&toks, &[b])?;
            let mask_buf = self.rt.upload_i32(&mask, &[b])?;
            let new_state =
                self.exes.decode.run(&[&self.state, &toks_buf, &mask_buf])?;
            self.state = new_state;
            self.refresh_logits()?;
            for &s in active.iter() {
                if mask[s] == 1 {
                    self.lengths[s] += 1;
                }
            }
        }
        out.cost = t0.elapsed().as_secs_f64();
        Ok(())
    }
}

/// Reset `out` for this round's active slots, recycling its per-slot token
/// buffers from the previous round in place (the [`Engine::decode_into`]
/// contract: no per-round allocation in steady state).
fn reset_chunk(out: &mut ChunkResult, active: &[SlotId]) {
    out.emitted.truncate(active.len());
    for (i, &s) in active.iter().enumerate() {
        match out.emitted.get_mut(i) {
            Some(e) => {
                e.0 = s;
                e.1.clear();
            }
            None => out.emitted.push((s, Vec::new())),
        }
    }
    out.cost = 0.0;
}

impl Engine for HloEngine {
    fn caps(&self) -> EngineCaps {
        self.caps
    }

    fn prefill(&mut self, entries: &[PrefillEntry]) -> Result<f64> {
        if entries.is_empty() {
            return Ok(0.0);
        }
        let t0 = Instant::now();
        let b = self.caps.slots;
        let sp = self.caps.prompt_len;
        let mut toks = vec![tok::PAD; b * sp];
        let mut lens = vec![0i32; b];
        let mut mask = vec![0i32; b];
        for e in entries {
            if e.slot >= b {
                bail!("slot {} out of range", e.slot);
            }
            if e.prompt.len() > sp {
                bail!("prompt len {} > bucket {sp}", e.prompt.len());
            }
            if e.prompt.is_empty() {
                bail!("empty prompt");
            }
            if e.cached_tokens > e.prompt.len() {
                bail!(
                    "cached_tokens {} exceeds prompt length {}",
                    e.cached_tokens,
                    e.prompt.len()
                );
            }
            self.cached_prefill_tokens += e.cached_tokens;
            self.pending[e.slot] = None; // supersede any stream in flight
            for (j, &t) in e.prompt.iter().enumerate() {
                toks[e.slot * sp + j] = t;
            }
            lens[e.slot] = e.prompt.len() as i32;
            mask[e.slot] = 1;
            self.lengths[e.slot] = e.prompt.len();
            self.occupied[e.slot] = true;
            self.rngs[e.slot] = Rng::new(e.seed);
        }
        let toks_buf = self.rt.upload_i32(&toks, &[b, sp])?;
        let lens_buf = self.rt.upload_i32(&lens, &[b])?;
        let mask_buf = self.rt.upload_i32(&mask, &[b])?;
        let new_state = self
            .exes
            .prefill
            .run(&[&self.state, &toks_buf, &lens_buf, &mask_buf])
            .context("prefill execute")?;
        self.state = new_state;
        self.logits_fresh = false;
        Ok(t0.elapsed().as_secs_f64())
    }

    fn prefill_chunk(&mut self, entries: &[PrefillChunkEntry]) -> Result<f64> {
        let t0 = Instant::now();
        let sp = self.caps.prompt_len;
        let mut ready: Vec<PrefillEntry> = Vec::new();
        for e in entries {
            if e.slot >= self.caps.slots {
                bail!("slot {} out of range", e.slot);
            }
            ChunkStream::validate(self.pending[e.slot].as_ref(), e, sp)?;
            if e.completes() {
                self.pending[e.slot] = None;
                ready.push(PrefillEntry {
                    slot: e.slot,
                    // One copy, at the single device dispatch.
                    prompt: e.prompt.to_vec(),
                    seed: e.seed,
                    cached_tokens: e.cached_tokens,
                });
            } else {
                self.occupied[e.slot] = false; // not decodable mid-stream
                match &mut self.pending[e.slot] {
                    Some(p) => p.filled = e.start + e.len,
                    None => {
                        self.pending[e.slot] = Some(ChunkStream::begin(e))
                    }
                }
            }
        }
        if !ready.is_empty() {
            self.prefill(&ready)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    fn decode_into(
        &mut self,
        active: &[SlotId],
        steps: usize,
        temp: f32,
        out: &mut ChunkResult,
    ) -> Result<()> {
        for &s in active {
            if s >= self.caps.slots {
                bail!("slot {s} out of range");
            }
            if !self.occupied[s] {
                bail!("decode on empty slot {s}");
            }
        }
        if active.is_empty() || steps == 0 {
            out.emitted.clear();
            out.cost = 0.0;
            return Ok(());
        }
        reset_chunk(out, active);
        match self.mode {
            DecodeMode::Fused => self.decode_fused(active, steps, temp, out),
            DecodeMode::Stepwise => self.decode_stepwise(active, steps, temp, out),
        }
    }

    fn replay(&mut self, entries: &[super::ReplayEntry]) -> Result<f64> {
        if entries.is_empty() {
            return Ok(0.0);
        }
        let t0 = Instant::now();
        // 1. Prefill the prompts.
        let prefills: Vec<PrefillEntry> = entries
            .iter()
            .map(|e| PrefillEntry {
                slot: e.slot,
                prompt: e.prompt.clone(),
                seed: e.seed,
                cached_tokens: 0,
            })
            .collect();
        self.prefill(&prefills)?;
        // 2. Teacher-force the prefixes with batched single-step decodes.
        let b = self.caps.slots;
        let max_forced = entries.iter().map(|e| e.forced.len()).max().unwrap();
        for step in 0..max_forced {
            let mut toks = vec![tok::PAD; b];
            let mut mask = vec![0i32; b];
            let mut any = false;
            for e in entries {
                if let Some(&t) = e.forced.get(step) {
                    toks[e.slot] = t;
                    mask[e.slot] = 1;
                    self.lengths[e.slot] += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
            let toks_buf = self.rt.upload_i32(&toks, &[b])?;
            let mask_buf = self.rt.upload_i32(&mask, &[b])?;
            let new_state =
                self.exes.decode.run(&[&self.state, &toks_buf, &mask_buf])?;
            self.state = new_state;
        }
        self.logits_fresh = false;
        Ok(t0.elapsed().as_secs_f64())
    }

    fn release(&mut self, slot: SlotId) {
        if slot < self.caps.slots {
            self.occupied[slot] = false;
            self.lengths[slot] = 0;
            self.pending[slot] = None;
        }
    }

    fn describe(&self) -> String {
        format!(
            "HloEngine(slots={}, chunk_t={}, mode={:?})",
            self.caps.slots, self.caps.chunk_t, self.mode
        )
    }
}
