//! Virtual-time simulation engine.
//!
//! Replays the SynthHop corpus generative process per branch: at prefill
//! the prompt is parsed back into a [`Question`] and a full scripted
//! response is drawn from the dataset's trajectory distribution with the
//! branch's own seed; decode rounds then release it in chunks. The cost
//! model charges `step_base + step_per_slot * |active|` per decode step
//! and a per-slot prefill cost — the same batch-size-dependent shape as
//! the real engine, so queuing/batching phenomena (and thus the paper's
//! figures) reproduce at full scale in deterministic virtual time.
//!
//! Decode is the hot path of every full-scale sweep, so it avoids
//! per-token work entirely: the script and its EOS position are fixed at
//! prefill, each round emits one `memcpy`-style slice copy per slot, and
//! the per-slot emit buffers handed back through
//! [`Engine::decode_into`]'s `out` parameter are recycled across rounds.

use super::{
    ChunkResult, ChunkStream, Engine, EngineCaps, PrefillChunkEntry,
    PrefillEntry, SlotId,
};
use crate::tokenizer as tok;
use crate::tokenizer::Token;
use crate::util::rng::Rng;
use crate::workload::{Question, TaskSpec};
use anyhow::{bail, Result};

/// Virtual cost model (seconds). Defaults calibrated to the HLO engine on
/// the dev machine (see EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone, Copy)]
pub struct SimCostModel {
    pub step_base: f64,
    pub step_per_slot: f64,
    pub prefill_base: f64,
    pub prefill_per_slot: f64,
    /// Per *uncached* prompt token — tokens covered by the cross-request
    /// prefix cache ([`PrefillEntry::cached_tokens`]) are free, which is
    /// exactly the serving win the cache buys. Defaults to 0.0 (the
    /// pre-cache flat-per-slot prefill model, so cache-disabled serves
    /// stay byte-identical to the historical cost model); the prefix
    /// bench and calibrated runs set it explicitly.
    pub prefill_per_token: f64,
}

impl Default for SimCostModel {
    fn default() -> Self {
        SimCostModel {
            step_base: 2.0e-3,
            step_per_slot: 0.25e-3,
            prefill_base: 4.0e-3,
            prefill_per_slot: 1.0e-3,
            prefill_per_token: 0.0,
        }
    }
}

struct SlotState {
    /// Full scripted response, fixed at prefill.
    script: Vec<Token>,
    /// Next script position to emit.
    pos: usize,
    /// Position of the script's EOS token (None only for malformed
    /// scripts; defensive).
    eos_at: Option<usize>,
}

impl SlotState {
    /// Tokens this slot can still emit (up to and including EOS).
    fn available(&self) -> usize {
        match self.eos_at {
            Some(e) if e >= self.pos => e - self.pos + 1,
            _ => self.script.len() - self.pos,
        }
    }

    /// Decode steps this slot occupies before going dead: one per emitted
    /// token, plus one trailing step when the script exhausts without EOS
    /// (mirrors the stepwise reference semantics exactly).
    fn alive_steps(&self) -> usize {
        match self.eos_at {
            Some(e) if e >= self.pos => e - self.pos + 1,
            _ => self.script.len() - self.pos + 1,
        }
    }
}

/// Scripted-response engine in virtual time.
pub struct SimEngine {
    caps: EngineCaps,
    spec: TaskSpec,
    cost: SimCostModel,
    slots: Vec<Option<SlotState>>,
    /// Per-slot chunked-prefill streams (None = no stream in flight).
    /// The script is drawn (and the slot installed) only when the
    /// completing chunk lands, so the generative process is
    /// byte-identical to a monolithic prefill of the same prompt/seed.
    pending: Vec<Option<ChunkStream>>,
    /// Recycled emit buffers (drained from the caller's previous
    /// `ChunkResult`, refilled on the next round).
    spare: Vec<Vec<Token>>,
}

impl SimEngine {
    pub fn new(slots: usize, max_seq: usize, spec: TaskSpec,
               cost: SimCostModel) -> SimEngine {
        SimEngine {
            caps: EngineCaps {
                slots,
                max_seq,
                prompt_len: 32,
                chunk_t: 16,
            },
            spec,
            cost,
            slots: (0..slots).map(|_| None).collect(),
            pending: (0..slots).map(|_| None).collect(),
            spare: Vec::new(),
        }
    }

    /// Raise the advisory prompt bucket (prefix-heavy workloads carry a
    /// shared few-shot header ahead of the 27-token question).
    pub fn set_prompt_bucket(&mut self, prompt_len: usize) {
        self.caps.prompt_len = prompt_len.min(self.caps.max_seq);
    }

    fn check_slot(&self, slot: SlotId) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("slot {slot} out of range ({})", self.slots.len());
        }
        Ok(())
    }

    fn install(&mut self, slot: SlotId, script: Vec<Token>) {
        let eos_at = script.iter().position(|&t| t == tok::EOS);
        self.slots[slot] = Some(SlotState { script, pos: 0, eos_at });
    }

    /// Draw the full scripted response for a (complete) serving prompt —
    /// shared by monolithic and chunked prefill so the two entry points
    /// produce byte-identical generative behaviour.
    fn draw_script(&self, prompt: &[Token], seed: u64) -> Result<Vec<Token>> {
        // Header-aware: the question is the trailing <bos>…<think>
        // window; any shared few-shot header tightens the response
        // budget but does not change the generative process.
        let q = Question::from_serving_prompt(prompt)?;
        let header_len = prompt.len() - q.prompt_tokens().len();
        let mut rng = Rng::new(seed);
        Ok(crate::workload::sample_response(
            &q,
            &self.spec,
            &mut rng,
            self.caps.max_seq.saturating_sub(header_len),
        ))
    }

    /// Return a token buffer to the reuse pool, bounded by the slot count
    /// so long serves (one release per terminated branch) cannot grow the
    /// pool without bound.
    fn recycle(&mut self, mut v: Vec<Token>) {
        if self.spare.len() < self.slots.len() {
            v.clear();
            self.spare.push(v);
        }
    }
}

impl Engine for SimEngine {
    fn caps(&self) -> EngineCaps {
        self.caps
    }

    fn prefill(&mut self, entries: &[PrefillEntry]) -> Result<f64> {
        let mut uncached_tokens = 0usize;
        for e in entries {
            self.check_slot(e.slot)?;
            if e.prompt.len() > self.caps.prompt_len {
                bail!("prompt length {} exceeds bucket {}", e.prompt.len(),
                      self.caps.prompt_len);
            }
            if e.cached_tokens > e.prompt.len() {
                bail!("cached_tokens {} exceeds prompt length {}",
                      e.cached_tokens, e.prompt.len());
            }
            let script = self.draw_script(&e.prompt, e.seed)?;
            // A monolithic prefill supersedes any chunk stream in flight
            // on this slot (re-prefill semantics, matching slot reuse).
            self.pending[e.slot] = None;
            self.install(e.slot, script);
            uncached_tokens += e.prompt.len() - e.cached_tokens;
        }
        Ok(self.cost.prefill_base
            + self.cost.prefill_per_slot * entries.len() as f64
            + self.cost.prefill_per_token * uncached_tokens as f64)
    }

    fn prefill_chunk(&mut self, entries: &[PrefillChunkEntry]) -> Result<f64> {
        let mut streamed_tokens = 0usize;
        for e in entries {
            self.check_slot(e.slot)?;
            // Cursor protocol lives in ChunkStream::validate (shared with
            // the HLO engine): fresh streams start at the cached prefix,
            // continuations resume exactly where the previous chunk ended
            // with an unchanged identity.
            ChunkStream::validate(
                self.pending[e.slot].as_ref(),
                e,
                self.caps.prompt_len,
            )?;
            streamed_tokens += e.len;
            if e.completes() {
                let script = self.draw_script(&e.prompt, e.seed)?;
                self.pending[e.slot] = None;
                self.install(e.slot, script);
            } else {
                // Mid-prefill: the slot must not be decodable until the
                // completing chunk lands.
                match &mut self.pending[e.slot] {
                    Some(p) => p.filled = e.start + e.len,
                    None => {
                        if let Some(st) = self.slots[e.slot].take() {
                            self.recycle(st.script);
                        }
                        self.pending[e.slot] = Some(ChunkStream::begin(e));
                    }
                }
            }
        }
        // Same cost shape as a monolithic prefill dispatch: streaming a
        // suffix over k chunks pays the same per-token total plus k-1
        // extra dispatch overheads — chunking is not free, it just
        // bounds the per-round decode stall.
        Ok(self.cost.prefill_base
            + self.cost.prefill_per_slot * entries.len() as f64
            + self.cost.prefill_per_token * streamed_tokens as f64)
    }

    fn decode_into(&mut self, active: &[SlotId], steps: usize, _temp: f32,
                   out: &mut ChunkResult) -> Result<()> {
        // Recycle the caller's previous-round buffers (pool capped at the
        // slot count — steady state needs one buffer per active slot).
        for (_, v) in out.emitted.drain(..) {
            self.recycle(v);
        }
        out.cost = 0.0;
        for &s in active {
            self.check_slot(s)?;
            if self.slots[s].is_none() {
                bail!("decode on empty slot {s}");
            }
        }
        // Steps actually run: the round ends early once every slot has
        // emitted EOS (slots keep occupying their lane until then — the
        // batch runs at its configured width, as in the HLO engine).
        let mut charged = 0usize;
        for &s in active {
            let st = self.slots[s].as_ref().unwrap();
            charged = charged.max(st.alive_steps().min(steps));
        }
        for &s in active {
            let st = self.slots[s].as_mut().unwrap();
            let k = st.available().min(charged);
            let mut buf = self.spare.pop().unwrap_or_default();
            buf.extend_from_slice(&st.script[st.pos..st.pos + k]);
            st.pos += k;
            out.emitted.push((s, buf));
        }
        out.cost = charged as f64
            * (self.cost.step_base
                + self.cost.step_per_slot * active.len() as f64);
        Ok(())
    }

    fn replay(&mut self, entries: &[super::ReplayEntry]) -> Result<f64> {
        let mut max_forced = 0usize;
        for e in entries {
            self.check_slot(e.slot)?;
            let q = Question::from_serving_prompt(&e.prompt)?;
            // Same header-tightened sequence budget as `prefill`, so the
            // two entry points enforce one invariant per prompt shape.
            let header_len = e.prompt.len() - q.prompt_tokens().len();
            let mut rng = Rng::new(e.seed);
            let script = crate::workload::continue_response(
                &q, &self.spec, &e.forced, &mut rng,
                self.caps.max_seq.saturating_sub(header_len));
            self.install(e.slot, script);
            max_forced = max_forced.max(e.forced.len());
        }
        // Cost: one prefill plus one teacher-forced decode step per forced
        // token (the whole point of measuring Rebase's replay overhead).
        Ok(self.cost.prefill_base
            + self.cost.prefill_per_slot * entries.len() as f64
            + max_forced as f64
                * (self.cost.step_base
                    + self.cost.step_per_slot * entries.len() as f64))
    }

    fn release(&mut self, slot: SlotId) {
        if let Some(p) = self.pending.get_mut(slot) {
            *p = None; // abandon any chunk stream in flight
        }
        let taken = self.slots.get_mut(slot).and_then(|s| s.take());
        if let Some(st) = taken {
            // Recycle the script allocation as a future emit buffer.
            self.recycle(st.script);
        }
    }

    fn describe(&self) -> String {
        format!("SimEngine(slots={}, dataset={})", self.caps.slots,
                self.spec.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Question;

    fn engine() -> SimEngine {
        SimEngine::new(4, 256, TaskSpec::synth_gaokao(),
                       SimCostModel::default())
    }

    fn prompt(seed: u64) -> Vec<Token> {
        let mut rng = Rng::new(seed);
        Question::sample(&TaskSpec::synth_gaokao(), &mut rng).prompt_tokens()
    }

    #[test]
    fn prefill_and_decode_to_completion() {
        let mut e = engine();
        e.prefill(&[PrefillEntry { slot: 0, prompt: prompt(1), seed: 7, cached_tokens: 0 }])
            .unwrap();
        let mut all = Vec::new();
        for _ in 0..50 {
            let r = e.decode(&[0], 16, 1.0).unwrap();
            let toks = &r.emitted[0].1;
            all.extend_from_slice(toks);
            if all.last() == Some(&tok::EOS) {
                break;
            }
        }
        assert_eq!(*all.last().unwrap(), tok::EOS);
        assert!(tok::extract_answer(&all).is_some());
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut e = engine();
            e.prefill(&[PrefillEntry { slot: 1, prompt: prompt(3), seed: 42, cached_tokens: 0 }])
                .unwrap();
            let mut out = Vec::new();
            loop {
                let r = e.decode(&[1], 16, 1.0).unwrap();
                out.extend(r.emitted[0].1.clone());
                if out.last() == Some(&tok::EOS) {
                    return out;
                }
            }
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn decode_into_reuses_buffers_and_matches_decode() {
        // The buffer-reusing path must be byte-identical to fresh
        // allocation round by round.
        let mut a = engine();
        let mut b = engine();
        for eng in [&mut a, &mut b] {
            eng.prefill(&[
                PrefillEntry { slot: 0, prompt: prompt(5), seed: 1, cached_tokens: 0 },
                PrefillEntry { slot: 1, prompt: prompt(6), seed: 2, cached_tokens: 0 },
            ])
            .unwrap();
        }
        let mut reused = ChunkResult::default();
        for _ in 0..20 {
            a.decode_into(&[0, 1], 16, 1.0, &mut reused).unwrap();
            let fresh = b.decode(&[0, 1], 16, 1.0).unwrap();
            assert_eq!(reused.emitted, fresh.emitted);
            assert_eq!(reused.cost, fresh.cost);
        }
    }

    #[test]
    fn seeds_diversify_branches() {
        // A deterministic question can legitimately yield identical clean
        // derivations for a pair of seeds; across many seeds the scripted
        // trajectories must nonetheless diversify (slips + rethink loops).
        let mut outs = std::collections::HashSet::new();
        for seed in 0..8u64 {
            let mut e = engine();
            e.prefill(&[PrefillEntry {
                slot: 0,
                prompt: prompt(5),
                seed,
                cached_tokens: 0,
            }])
            .unwrap();
            let mut out = Vec::new();
            for _ in 0..64 {
                let r = e.decode(&[0], 16, 1.0).unwrap();
                out.extend(r.emitted[0].1.clone());
                if out.last() == Some(&crate::tokenizer::EOS) {
                    break;
                }
            }
            outs.insert(out);
        }
        assert!(outs.len() >= 2, "only {} distinct trajectories", outs.len());
    }

    #[test]
    fn eos_stops_emission_within_round() {
        let mut e = engine();
        e.prefill(&[PrefillEntry { slot: 0, prompt: prompt(9), seed: 3, cached_tokens: 0 }])
            .unwrap();
        let r = e.decode(&[0], 10_000, 1.0).unwrap();
        let toks = &r.emitted[0].1;
        assert_eq!(toks.iter().filter(|&&t| t == tok::EOS).count(), 1);
        assert_eq!(*toks.last().unwrap(), tok::EOS);
    }

    #[test]
    fn cost_scales_with_batch_width() {
        let mut e = engine();
        let entries: Vec<_> = (0..4)
            .map(|s| PrefillEntry { slot: s, prompt: prompt(s as u64), seed: s as u64, cached_tokens: 0 })
            .collect();
        e.prefill(&entries).unwrap();
        let r1 = e.decode(&[0], 4, 1.0).unwrap();
        let mut e2 = engine();
        let entries2: Vec<_> = (0..4)
            .map(|s| PrefillEntry { slot: s, prompt: prompt(s as u64), seed: s as u64, cached_tokens: 0 })
            .collect();
        e2.prefill(&entries2).unwrap();
        let r4 = e2.decode(&[0, 1, 2, 3], 4, 1.0).unwrap();
        assert!(r4.cost > r1.cost);
    }

    #[test]
    fn decode_on_empty_slot_fails() {
        let mut e = engine();
        assert!(e.decode(&[2], 4, 1.0).is_err());
    }

    #[test]
    fn cached_tokens_discount_prefill_cost_only() {
        // Same prompt/seed with and without a cache hit: identical script
        // (decode behaviour unchanged), strictly cheaper prefill under a
        // token-priced cost model.
        let model = SimCostModel {
            prefill_per_token: 0.2e-3,
            ..SimCostModel::default()
        };
        let priced = || {
            SimEngine::new(4, 256, TaskSpec::synth_gaokao(), model)
        };
        let p = prompt(4);
        let cold = priced()
            .prefill(&[PrefillEntry {
                slot: 0, prompt: p.clone(), seed: 9, cached_tokens: 0,
            }])
            .unwrap();
        let mut warm_engine = priced();
        let warm = warm_engine
            .prefill(&[PrefillEntry {
                slot: 0, prompt: p.clone(), seed: 9, cached_tokens: 16,
            }])
            .unwrap();
        assert!(cold > warm, "hit must be cheaper: {cold} vs {warm}");
        assert!((cold - warm - 16.0 * model.prefill_per_token).abs() < 1e-12,
                "cold {cold} vs warm {warm}");
        let mut cold_engine = engine();
        cold_engine
            .prefill(&[PrefillEntry {
                slot: 0, prompt: p, seed: 9, cached_tokens: 0,
            }])
            .unwrap();
        assert_eq!(
            cold_engine.decode(&[0], 256, 1.0).unwrap().emitted,
            warm_engine.decode(&[0], 256, 1.0).unwrap().emitted,
        );
        // Over-claimed cache coverage is rejected.
        let mut e = engine();
        assert!(e
            .prefill(&[PrefillEntry {
                slot: 0, prompt: prompt(4), seed: 1, cached_tokens: 999,
            }])
            .is_err());
    }

    #[test]
    fn headered_prompt_decodes_the_trailing_question() {
        use crate::workload::few_shot_header;
        let mut e = SimEngine::new(4, 512, TaskSpec::synth_gaokao(),
                                   SimCostModel::default());
        e.set_prompt_bucket(256);
        let mut rng = Rng::new(21);
        let q = Question::sample(&TaskSpec::synth_gaokao(), &mut rng);
        let mut headered =
            few_shot_header(&TaskSpec::synth_gaokao(), 5, 3);
        headered.extend(q.prompt_tokens());
        e.prefill(&[PrefillEntry {
            slot: 0, prompt: headered, seed: 7, cached_tokens: 0,
        }])
        .unwrap();
        let mut all = Vec::new();
        for _ in 0..64 {
            let r = e.decode(&[0], 16, 1.0).unwrap();
            all.extend_from_slice(&r.emitted[0].1);
            if all.last() == Some(&tok::EOS) {
                break;
            }
        }
        assert_eq!(*all.last().unwrap(), tok::EOS);
        assert!(tok::extract_answer(&all).is_some());
    }

    #[test]
    fn chunked_prefill_matches_monolithic_script() {
        // Streaming the same prompt/seed in chunks must decode the exact
        // script a monolithic prefill produces, paying the same per-token
        // total plus one extra dispatch overhead per extra chunk.
        let model = SimCostModel {
            prefill_per_token: 0.2e-3,
            ..SimCostModel::default()
        };
        let p = prompt(11);
        let mut mono = SimEngine::new(4, 256, TaskSpec::synth_gaokao(), model);
        let mono_cost = mono
            .prefill(&[PrefillEntry {
                slot: 0, prompt: p.clone(), seed: 5, cached_tokens: 0,
            }])
            .unwrap();
        let mut chunked =
            SimEngine::new(4, 256, TaskSpec::synth_gaokao(), model);
        let mut cost = 0.0;
        let step = 10;
        let mut start = 0;
        while start < p.len() {
            let len = step.min(p.len() - start);
            cost += chunked
                .prefill_chunk(&[PrefillChunkEntry {
                    slot: 0,
                    prompt: p.clone().into(),
                    seed: 5,
                    cached_tokens: 0,
                    start,
                    len,
                }])
                .unwrap();
            if start + len < p.len() {
                assert!(
                    chunked.decode(&[0], 1, 1.0).is_err(),
                    "mid-prefill slot must not decode"
                );
            }
            start += len;
        }
        let n_chunks = p.len().div_ceil(step);
        let overhead = (n_chunks - 1) as f64
            * (model.prefill_base + model.prefill_per_slot);
        assert!(
            (cost - mono_cost - overhead).abs() < 1e-12,
            "chunked {cost} vs mono {mono_cost} + overhead {overhead}"
        );
        assert_eq!(
            mono.decode(&[0], 256, 1.0).unwrap().emitted,
            chunked.decode(&[0], 256, 1.0).unwrap().emitted,
        );
    }

    #[test]
    fn chunk_cursor_protocol_enforced() {
        let p = prompt(3);
        let mk = || {
            SimEngine::new(4, 256, TaskSpec::synth_gaokao(),
                           SimCostModel::default())
        };
        let entry = |seed, start, len| PrefillChunkEntry {
            slot: 0,
            prompt: p.clone().into(),
            seed,
            cached_tokens: 0,
            start,
            len,
        };
        // Fresh stream must start at the cached prefix (0 here).
        let mut e = mk();
        assert!(e.prefill_chunk(&[entry(1, 4, 4)]).is_err());
        // Continuation must resume exactly where the last chunk ended.
        let mut e = mk();
        e.prefill_chunk(&[entry(1, 0, 4)]).unwrap();
        assert!(e.prefill_chunk(&[entry(1, 8, 4)]).is_err());
        // Identity (seed) must not change mid-stream.
        assert!(e.prefill_chunk(&[entry(2, 4, 4)]).is_err());
        // Overrunning the prompt is rejected.
        assert!(e.prefill_chunk(&[entry(1, 4, p.len())]).is_err());
        // Release abandons the stream; a fresh one then starts over.
        e.release(0);
        e.prefill_chunk(&[entry(1, 0, p.len())]).unwrap();
        e.decode(&[0], 1, 1.0).unwrap();
    }

    #[test]
    fn install_only_chunk_serves_fully_cached_prompt() {
        let p = prompt(7);
        let mut e = SimEngine::new(4, 256, TaskSpec::synth_gaokao(),
                                   SimCostModel::default());
        let cost = e
            .prefill_chunk(&[PrefillChunkEntry {
                slot: 0,
                prompt: p.clone().into(),
                seed: 4,
                cached_tokens: p.len(),
                start: p.len(),
                len: 0,
            }])
            .unwrap();
        // No prompt compute: dispatch overhead only.
        let m = SimCostModel::default();
        assert!((cost - m.prefill_base - m.prefill_per_slot).abs() < 1e-12);
        // Decodes the same script as a monolithic prefill, same seed.
        let mut mono = SimEngine::new(4, 256, TaskSpec::synth_gaokao(),
                                      SimCostModel::default());
        mono.prefill(&[PrefillEntry {
            slot: 0, prompt: p, seed: 4, cached_tokens: 0,
        }])
        .unwrap();
        assert_eq!(
            mono.decode(&[0], 256, 1.0).unwrap().emitted,
            e.decode(&[0], 256, 1.0).unwrap().emitted,
        );
    }

    #[test]
    fn release_frees_slot() {
        let mut e = engine();
        e.prefill(&[PrefillEntry { slot: 0, prompt: prompt(1), seed: 7, cached_tokens: 0 }])
            .unwrap();
        e.release(0);
        assert!(e.decode(&[0], 1, 1.0).is_err());
        // Slot is reusable after release.
        e.prefill(&[PrefillEntry { slot: 0, prompt: prompt(2), seed: 8, cached_tokens: 0 }])
            .unwrap();
        e.decode(&[0], 1, 1.0).unwrap();
    }
}
