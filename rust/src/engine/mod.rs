//! The batched decode engine: fixed KV slots, continuous refill.
//!
//! The coordinator talks to a slot-oriented [`Engine`]: it prefills prompts
//! into free slots, runs decode rounds over the active slots, and releases
//! slots when branches terminate. Two implementations share the trait:
//!
//! * [`hlo::HloEngine`] — the real thing: executes the AOT-compiled
//!   JAX/Pallas graphs via PJRT with the KV cache resident on device.
//! * [`sim::SimEngine`] — a virtual-time twin that replays the corpus
//!   generative process; used by unit/property tests and the full-scale
//!   figure sweeps (deterministic, no artifacts needed).
//!
//! Engine methods return their compute *cost* in seconds — wall-clock for
//! the HLO engine, modeled for the sim — and the caller owns the clock.

pub mod hlo;
pub mod sim;

use crate::tokenizer::Token;
use anyhow::Result;

/// Index of a KV slot in the engine's fixed batch.
pub type SlotId = usize;

/// Static shape information the scheduler needs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineCaps {
    /// Number of KV slots == compiled batch size.
    pub slots: usize,
    /// KV positions per slot (prompt + generation).
    pub max_seq: usize,
    /// Prompt bucket (prompts longer than this are rejected).
    pub prompt_len: usize,
    /// Fused-chunk length (decode rounds should be multiples of this for
    /// the fused path to be used).
    pub chunk_t: usize,
}

/// A prompt to install into a slot.
#[derive(Debug, Clone)]
pub struct PrefillEntry {
    pub slot: SlotId,
    pub prompt: Vec<Token>,
    /// Per-branch RNG stream seed (sampling determinism).
    pub seed: u64,
    /// Leading prompt tokens whose KV is already resident — covered by
    /// the cross-request prefix cache on a request's first branch start
    /// (a page multiple; 0 on cold prompts), or the whole prompt for
    /// sibling branches forking from their request's shared prefix. The
    /// sim cost model charges prefill only for the uncovered suffix; the
    /// HLO engine records the hit but still recomputes (its packed
    /// per-slot state has no cross-slot page sharing — see `hlo.rs`).
    pub cached_tokens: usize,
}

/// A fork to install into a slot: prompt + a teacher-forced prefix the
/// branch continues from (Rebase tree expansion). Forced prefixes must end
/// at a derivation-step boundary.
#[derive(Debug, Clone)]
pub struct ReplayEntry {
    pub slot: SlotId,
    pub prompt: Vec<Token>,
    pub forced: Vec<Token>,
    pub seed: u64,
}

/// Outcome of a decode round.
#[derive(Debug, Clone, Default)]
pub struct ChunkResult {
    /// Newly generated tokens per slot, in slot order of the `active`
    /// argument. A branch that completes mid-round ends with EOS and emits
    /// nothing further.
    pub emitted: Vec<(SlotId, Vec<Token>)>,
    /// Engine compute seconds (wall for HLO, modeled for sim).
    pub cost: f64,
}

/// Batched decode engine over fixed KV slots.
pub trait Engine {
    fn caps(&self) -> EngineCaps;

    /// (Re)initialize slots with prompts. Returns compute cost (seconds).
    fn prefill(&mut self, entries: &[PrefillEntry]) -> Result<f64>;

    /// Run up to `steps` decode steps for `active` slots, writing the
    /// round's result into `out` (any previous contents are replaced).
    /// Slots not listed are frozen. A slot that emits EOS stops generating
    /// within the round.
    ///
    /// This is the hot-path entry point: a caller that keeps one
    /// [`ChunkResult`] alive across rounds lets the engine recycle the
    /// per-slot token buffers instead of reallocating them every round
    /// (the scheduler decodes once per round for the lifetime of a serve).
    fn decode_into(
        &mut self,
        active: &[SlotId],
        steps: usize,
        temp: f32,
        out: &mut ChunkResult,
    ) -> Result<()>;

    /// Convenience wrapper over [`Engine::decode_into`] allocating a fresh
    /// result (fine for tests and one-shot probes).
    fn decode(&mut self, active: &[SlotId], steps: usize, temp: f32)
        -> Result<ChunkResult> {
        let mut out = ChunkResult::default();
        self.decode_into(active, steps, temp, &mut out)?;
        Ok(out)
    }

    /// Install forks: prefill the prompt then teacher-force a prefix, so
    /// the slot continues generation from mid-trajectory. This is how
    /// tree-search baselines expand a node without KV-fork support — and
    /// the replay cost is exactly the inefficiency the paper observes for
    /// Rebase on long responses.
    fn replay(&mut self, entries: &[ReplayEntry]) -> Result<f64>;

    /// Mark a slot reusable without further decoding (prune/early-stop).
    fn release(&mut self, slot: SlotId);

    /// Human-readable identity for logs/metrics.
    fn describe(&self) -> String;
}
