//! The batched decode engine: fixed KV slots, continuous refill.
//!
//! The coordinator talks to a slot-oriented [`Engine`]: it prefills prompts
//! into free slots, runs decode rounds over the active slots, and releases
//! slots when branches terminate. Two implementations share the trait:
//!
//! * [`hlo::HloEngine`] — the real thing: executes the AOT-compiled
//!   JAX/Pallas graphs via PJRT with the KV cache resident on device.
//! * [`sim::SimEngine`] — a virtual-time twin that replays the corpus
//!   generative process; used by unit/property tests and the full-scale
//!   figure sweeps (deterministic, no artifacts needed).
//!
//! Engine methods return their compute *cost* in seconds — wall-clock for
//! the HLO engine, modeled for the sim — and the caller owns the clock.

pub mod hlo;
pub mod sim;

use crate::tokenizer::Token;
use anyhow::Result;
use std::sync::Arc;

/// Index of a KV slot in the engine's fixed batch.
pub type SlotId = usize;

/// Static shape information the scheduler needs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineCaps {
    /// Number of KV slots == compiled batch size.
    pub slots: usize,
    /// KV positions per slot (prompt + generation).
    pub max_seq: usize,
    /// Prompt bucket (prompts longer than this are rejected).
    pub prompt_len: usize,
    /// Fused-chunk length (decode rounds should be multiples of this for
    /// the fused path to be used).
    pub chunk_t: usize,
}

/// A prompt to install into a slot.
#[derive(Debug, Clone)]
pub struct PrefillEntry {
    pub slot: SlotId,
    pub prompt: Vec<Token>,
    /// Per-branch RNG stream seed (sampling determinism).
    pub seed: u64,
    /// Leading prompt tokens whose KV is already resident — covered by
    /// the cross-request prefix cache on a request's first branch start
    /// (a page multiple; 0 on cold prompts), or the whole prompt for
    /// sibling branches forking from their request's shared prefix. The
    /// sim cost model charges prefill only for the uncovered suffix; the
    /// HLO engine records the hit but still recomputes (its packed
    /// per-slot state has no cross-slot page sharing — see `hlo.rs`).
    pub cached_tokens: usize,
}

/// One chunk of a streaming (chunked) prefill: covers
/// `prompt[start..start + len]` for `slot`.
///
/// Chunked prefill splits a prompt's uncovered suffix across several
/// engine dispatches so a long cold few-shot header streams in over
/// multiple scheduling rounds instead of stalling the decoding batch for
/// one monolithic prefill (Sarathi-style chunked prefill). The cursor
/// protocol, validated by every engine:
///
/// * the first chunk for a slot must have `start == cached_tokens` (the
///   radix-covered prefix needs no compute and is skipped);
/// * each subsequent chunk must continue exactly where the previous one
///   ended (`start` == tokens filled so far), with the same `prompt`,
///   `seed` and `cached_tokens`;
/// * the chunk with `start + len == prompt.len()` completes the prefill
///   and makes the slot decodable;
/// * `len == 0` with `start == prompt.len()` is an *install-only* entry —
///   a fully cached prompt (`cached_tokens == prompt.len()`) that needs
///   slot state but no prompt compute, e.g. a sibling branch forking from
///   its request's already-resident shared prefix.
///
/// Entries for different slots batch into one dispatch (one cost charge),
/// exactly like [`PrefillEntry`] batches in [`Engine::prefill`].
#[derive(Debug, Clone)]
pub struct PrefillChunkEntry {
    pub slot: SlotId,
    /// The full serving prompt. Every chunk carries it (engines validate
    /// continuation chunks against the first), shared rather than owned —
    /// a header streamed over k chunks must not copy its tokens k times.
    pub prompt: Arc<[Token]>,
    /// Per-branch RNG stream seed (sampling determinism).
    pub seed: u64,
    /// Leading prompt tokens whose KV is already resident (see
    /// [`PrefillEntry::cached_tokens`]); chunks only ever cover the
    /// uncovered suffix `prompt[cached_tokens..]`.
    pub cached_tokens: usize,
    /// First prompt position this chunk covers.
    pub start: usize,
    /// Tokens covered by this chunk (0 = install-only).
    pub len: usize,
}

impl PrefillChunkEntry {
    /// Does this chunk complete the slot's prefill?
    pub fn completes(&self) -> bool {
        self.start + self.len == self.prompt.len()
    }
}

/// Host-side state of one in-flight chunk stream. Both engines keep a
/// `Vec<Option<ChunkStream>>` per slot and validate every entry through
/// [`ChunkStream::validate`], so the cursor protocol lives in exactly one
/// place and cannot drift between implementations.
#[derive(Debug)]
pub(crate) struct ChunkStream {
    pub(crate) prompt: Arc<[Token]>,
    pub(crate) seed: u64,
    pub(crate) cached: usize,
    pub(crate) filled: usize,
}

impl ChunkStream {
    /// Validate `e` as the next chunk for a slot whose stream state is
    /// `stream` (`None` = no stream in flight), against the engine's
    /// prompt bucket, per the [`PrefillChunkEntry`] protocol.
    ///
    /// Continuation identity is checked cheaply (prompt length, seed,
    /// cached prefix, cursor) — an O(prompt) content compare per chunk
    /// would make streaming quadratic in the prompt; content equality is
    /// debug-asserted, and the completing chunk's prompt is what the
    /// engine ultimately installs.
    pub(crate) fn validate(
        stream: Option<&ChunkStream>,
        e: &PrefillChunkEntry,
        prompt_bucket: usize,
    ) -> Result<()> {
        if e.prompt.len() > prompt_bucket {
            anyhow::bail!(
                "prompt length {} exceeds bucket {prompt_bucket}",
                e.prompt.len()
            );
        }
        if e.cached_tokens > e.prompt.len() {
            anyhow::bail!(
                "cached_tokens {} exceeds prompt length {}",
                e.cached_tokens,
                e.prompt.len()
            );
        }
        if e.start + e.len > e.prompt.len() {
            anyhow::bail!(
                "chunk [{}, {}) overruns a {}-token prompt (slot {})",
                e.start,
                e.start + e.len,
                e.prompt.len(),
                e.slot
            );
        }
        match stream {
            None => {
                if e.start != e.cached_tokens {
                    anyhow::bail!(
                        "chunk stream for slot {} starts at {} but the \
                         cached prefix is {} tokens",
                        e.slot,
                        e.start,
                        e.cached_tokens
                    );
                }
            }
            Some(p) => {
                if p.prompt.len() != e.prompt.len()
                    || p.seed != e.seed
                    || p.cached != e.cached_tokens
                {
                    anyhow::bail!(
                        "chunk stream identity changed mid-prefill (slot {})",
                        e.slot
                    );
                }
                debug_assert_eq!(
                    p.prompt, e.prompt,
                    "chunk stream prompt content changed mid-prefill"
                );
                if e.start != p.filled {
                    anyhow::bail!(
                        "chunk cursor {} != {} tokens filled (slot {})",
                        e.start,
                        p.filled,
                        e.slot
                    );
                }
            }
        }
        Ok(())
    }

    /// Stream state after a (validated) non-completing first chunk
    /// (shares the entry's prompt — no token copy).
    pub(crate) fn begin(e: &PrefillChunkEntry) -> ChunkStream {
        ChunkStream {
            prompt: Arc::clone(&e.prompt),
            seed: e.seed,
            cached: e.cached_tokens,
            filled: e.start + e.len,
        }
    }
}

/// A fork to install into a slot: prompt + a teacher-forced prefix the
/// branch continues from (Rebase tree expansion). Forced prefixes must end
/// at a derivation-step boundary.
#[derive(Debug, Clone)]
pub struct ReplayEntry {
    pub slot: SlotId,
    pub prompt: Vec<Token>,
    pub forced: Vec<Token>,
    pub seed: u64,
}

/// Outcome of a decode round.
#[derive(Debug, Clone, Default)]
pub struct ChunkResult {
    /// Newly generated tokens per slot, in slot order of the `active`
    /// argument. A branch that completes mid-round ends with EOS and emits
    /// nothing further.
    pub emitted: Vec<(SlotId, Vec<Token>)>,
    /// Engine compute seconds (wall for HLO, modeled for sim).
    pub cost: f64,
}

/// Batched decode engine over fixed KV slots.
pub trait Engine {
    fn caps(&self) -> EngineCaps;

    /// (Re)initialize slots with prompts. Returns compute cost (seconds).
    fn prefill(&mut self, entries: &[PrefillEntry]) -> Result<f64>;

    /// Stream one batch of prefill chunks (see [`PrefillChunkEntry`] for
    /// the cursor protocol). A slot becomes decodable once its completing
    /// chunk lands; decoding a mid-prefill slot is an error. Returns
    /// compute cost (seconds).
    ///
    /// The default implementation rejects chunking, so engines that only
    /// serve monolithic prefills (`prefill_chunk_tokens = 0` schedules,
    /// scripted test engines) need not implement it.
    fn prefill_chunk(&mut self, entries: &[PrefillChunkEntry]) -> Result<f64> {
        let _ = entries;
        anyhow::bail!("chunked prefill unsupported by {}", self.describe())
    }

    /// Run up to `steps` decode steps for `active` slots, writing the
    /// round's result into `out` (any previous contents are replaced).
    /// Slots not listed are frozen. A slot that emits EOS stops generating
    /// within the round.
    ///
    /// This is the hot-path entry point: a caller that keeps one
    /// [`ChunkResult`] alive across rounds lets the engine recycle the
    /// per-slot token buffers instead of reallocating them every round
    /// (the scheduler decodes once per round for the lifetime of a serve).
    fn decode_into(
        &mut self,
        active: &[SlotId],
        steps: usize,
        temp: f32,
        out: &mut ChunkResult,
    ) -> Result<()>;

    /// Convenience wrapper over [`Engine::decode_into`] allocating a fresh
    /// result (fine for tests and one-shot probes).
    fn decode(&mut self, active: &[SlotId], steps: usize, temp: f32)
        -> Result<ChunkResult> {
        let mut out = ChunkResult::default();
        self.decode_into(active, steps, temp, &mut out)?;
        Ok(out)
    }

    /// Install forks: prefill the prompt then teacher-force a prefix, so
    /// the slot continues generation from mid-trajectory. This is how
    /// tree-search baselines expand a node without KV-fork support — and
    /// the replay cost is exactly the inefficiency the paper observes for
    /// Rebase on long responses.
    fn replay(&mut self, entries: &[ReplayEntry]) -> Result<f64>;

    /// Mark a slot reusable without further decoding (prune/early-stop).
    fn release(&mut self, slot: SlotId);

    /// Human-readable identity for logs/metrics.
    fn describe(&self) -> String;
}
