//! Baseline serving methods.
//!
//! Vanilla and Self-Consistency are degenerate [`Policy`] configurations
//! of the main SART scheduler (same continuous-batching loop, fair
//! comparison — see `crate::coordinator`). Rebase, the tree-search
//! baseline, has a structurally different scheduler implemented in
//! [`rebase`].
//!
//! [`Policy`]: crate::coordinator::Policy

pub mod rebase;

pub use rebase::{RebaseConfig, RebaseScheduler};
